"""Batched LM serving: the wave-batched engine over a smoke-size model.

    PYTHONPATH=src python examples/serve_lm.py --requests 6 --slots 3
"""
import argparse
import time

import jax

from repro.configs import get_arch
from repro.models.transformer import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_arch("qwen3-4b").smoke_config
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, num_slots=args.slots, max_len=64)
    for uid in range(args.requests):
        eng.submit(
            Request(uid=uid, prompt=[1 + uid, 2 + uid, 3],
                    max_new_tokens=args.new_tokens)
        )
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.output) for r in done)
    print(f"{len(done)} requests in {eng.waves} waves, "
          f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s on 1 CPU core)")
    for r in done[:4]:
        print(f"  req {r.uid}: prompt {r.prompt} -> {r.output}")


if __name__ == "__main__":
    main()
