"""Train GAT on a synthetic Cora-shaped graph (full-batch node classes).

    PYTHONPATH=src python examples/gnn_cora.py --steps 100

Labels are planted by a hidden linear model over features so accuracy is
measurable (random = 1/7)."""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.graphs import full_graph
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--nodes", type=int, default=2708)
    ap.add_argument("--edges", type=int, default=10556)
    args = ap.parse_args()

    arch = get_arch("gat-cora")
    cfg = arch.config_for("full_graph_sm")
    g = full_graph(args.nodes, args.edges, cfg.in_dim, num_classes=cfg.num_classes)
    # plant learnable structure: labels = argmax of a hidden projection
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(cfg.in_dim, cfg.num_classes)).astype(np.float32)
    g["labels"] = np.argmax(g["node_feats"] @ w_true, -1).astype(np.int32)
    g = {k: jnp.asarray(v) if isinstance(v, np.ndarray) else v for k, v in g.items()}

    params = arch.module.init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=5e-3, weight_decay=0.0, warmup_steps=5)
    opt = init_opt_state(params, opt_cfg)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(
            lambda p: arch.module.loss_fn(p, cfg, g)
        )(params)
        params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
        return params, opt, loss

    for i in range(args.steps):
        params, opt, loss = step(params, opt)
        if i % max(args.steps // 10, 1) == 0:
            logits = arch.module.forward(params, cfg, g)
            acc = float(jnp.mean(jnp.argmax(logits, -1) == g["labels"]))
            print(f"step {i:4d}  loss {float(loss):.4f}  acc {acc:.3f}")
    logits = arch.module.forward(params, cfg, g)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == g["labels"]))
    print(f"final accuracy {acc:.3f} (random = {1/cfg.num_classes:.3f})")
    assert acc > 2.5 / cfg.num_classes, "model failed to beat random"


if __name__ == "__main__":
    main()
