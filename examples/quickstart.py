"""Quickstart: the paper's two algorithms through the public API.

    PYTHONPATH=src python examples/quickstart.py [--n 2000000]

Generates a KISS-random linked list and graph (as in the paper's
experiments), ranks the list with both Wylie pointer jumping and the
parallel random-splitter algorithm (SoA vs AoS packing -- the 48/64-bit
experiment), labels components with Shiloach-Vishkin, and verifies
everything against the serial oracles.
"""
import argparse
import time

import numpy as np

from repro.core import (
    num_components,
    random_splitter_rank,
    shiloach_vishkin,
    sv_round_bound,
    tree_analytics,
    wylie_rank,
)
from repro.core.serial import serial_connected_components, serial_list_rank, canonicalize_labels
from repro.ops.kiss import random_forest, random_linked_list
from repro.trees.reference import serial_tree_reference


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2_000_000)
    ap.add_argument("--splitters", type=int, default=4096)
    args = ap.parse_args()

    print(f"== list ranking, n={args.n:,} ==")
    succ = random_linked_list(args.n, seed=1)

    t0 = time.perf_counter()
    r_wylie = np.asarray(wylie_rank(succ))
    t_wylie = time.perf_counter() - t0
    print(f"wylie (O(n log n) work):          {t_wylie*1e3:8.1f} ms")

    for pm, label in (("soa", "SoA ('48-bit')"), ("aos", "AoS ('64-bit')")):
        t0 = time.perf_counter()
        r_split, stats = random_splitter_rank(
            succ, args.splitters, seed=2, pack_mode=pm, with_stats=True
        )
        r_split = np.asarray(r_split)
        dt = time.perf_counter() - t0
        print(
            f"random splitter {label}: {dt*1e3:8.1f} ms  "
            f"(p={args.splitters}, max sub-list {stats.sublist_lengths.max()}, "
            f"mean {stats.expected_mean:.0f})"
        )
        assert (r_split == r_wylie).all()

    if args.n <= 2_000_000:
        ref = serial_list_rank(succ)
        assert (r_wylie == ref).all()
        print("verified against serial traversal")

    print("\n== connected components ==")
    n = min(args.n, 500_000)
    edges = random_forest(n, num_components=40, seed=3)
    t0 = time.perf_counter()
    labels, rounds = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    dt = time.perf_counter() - t0
    print(
        f"shiloach-vishkin: {dt*1e3:8.1f} ms  rounds={int(rounds)} "
        f"(bound {sv_round_bound(n)})  components={num_components(labels)}"
    )
    ref = canonicalize_labels(serial_connected_components(edges, n))
    assert (canonicalize_labels(np.asarray(labels)) == ref).all()
    print("verified against union-find")

    print("\n== euler-tour tree analytics (the two primitives composed) ==")
    t0 = time.perf_counter()
    ta = tree_analytics(edges[:, 0], edges[:, 1], n)
    dt = time.perf_counter() - t0
    depth = np.asarray(ta.depth)
    sizes = np.asarray(ta.subtree_size)
    roots = np.asarray(ta.parent) == np.arange(n)
    print(
        f"forest -> tour -> computations: {dt*1e3:8.1f} ms  "
        f"(trees={ta.forest.num_trees}, arcs={ta.tour.num_arcs}, "
        f"max depth={depth.max()}, largest tree={sizes[roots].max()})"
    )
    ref = serial_tree_reference(ta.forest.edge_u, ta.forest.edge_v, n)
    assert (depth == ref["depth"]).all() and (
        np.asarray(ta.parent) == ref["parent"]
    ).all()
    print("verified against serial Euler walk")


if __name__ == "__main__":
    main()
