"""End-to-end LM training driver: ~100M-param decoder, synthetic KISS data,
checkpoint/auto-resume, straggler watchdog, optional grad compression.

    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 50

Kill it mid-run and re-launch: it resumes from the last checkpoint.
"""
import argparse
import logging

import jax
import jax.numpy as jnp

from repro.data.lm import lm_batch
from repro.models.transformer import TransformerConfig, init_params, loss_fn
from repro.models.common import count_params
from repro.train.loop import LoopConfig, train
from repro.train.optimizer import AdamWConfig

PRESETS = {
    # ~112M params: the "train a ~100M model" example driver
    "100m": dict(
        num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32000, batch=4, seq=256,
    ),
    "10m": dict(
        num_layers=6, d_model=256, num_heads=8, num_kv_heads=4,
        head_dim=32, d_ff=1024, vocab_size=8000, batch=8, seq=128,
    ),
    "tiny": dict(
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=512, vocab_size=1000, batch=8, seq=64,
    ),
}


def main():
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = TransformerConfig(
        name=f"lm-{args.preset}",
        num_layers=p["num_layers"], d_model=p["d_model"],
        num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
        head_dim=p["head_dim"], d_ff=p["d_ff"], vocab_size=p["vocab_size"],
        dtype="float32", remat=False,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"model: {count_params(params)/1e6:.1f}M params")

    def data():
        step = 0
        while True:
            raw = lm_batch(p["batch"], p["seq"], cfg.vocab_size, seed=7, step=step)
            yield {k: jnp.asarray(v) for k, v in raw.items()}
            step += 1

    opt_cfg = AdamWConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 2),
        total_steps=args.steps,
    )
    loop_cfg = LoopConfig(
        total_steps=args.steps,
        checkpoint_every=max(args.steps // 5, 10),
        checkpoint_dir=args.checkpoint_dir,
        log_every=max(args.steps // 30, 1),
        grad_compression=args.grad_compression,
        num_microbatches=args.microbatches,
    )
    _, out = train(
        params, lambda prm, b: loss_fn(prm, cfg, b), data(), opt_cfg, loop_cfg
    )
    h = out["history"]
    print(
        f"steps {h[0]['step']}..{h[-1]['step']}  "
        f"loss {h[0]['loss']:.3f} -> {out['final_loss']:.3f}  "
        f"slow steps flagged: {len(out['slow_steps'])}"
    )


if __name__ == "__main__":
    main()
