"""xDeepFM: brief training then batched CTR serving + 1-vs-1M retrieval.

    PYTHONPATH=src python examples/recsys_serving.py --steps 60
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data.recsys import recsys_batch
from repro.models.recsys.xdeepfm import (
    XDeepFMConfig,
    init_params,
    loss_fn,
    serve_retrieval,
    serve_step,
)
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=512)
    args = ap.parse_args()

    cfg = XDeepFMConfig(
        n_fields=16, vocab_per_field=50_000, embed_dim=10,
        cin_layers=(64, 64), mlp_layers=(128, 128),
        retrieval_dim=32, n_candidates=100_000,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=2e-3, weight_decay=0.0, warmup_steps=5)
    opt = init_opt_state(params, opt_cfg)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
        params, opt, _ = adamw_update(grads, opt, params, opt_cfg)
        return params, opt, loss

    for i in range(args.steps):
        raw = recsys_batch(args.batch, cfg.n_fields, cfg.vocab_per_field,
                           seed=1, step=i)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        params, opt, loss = step(params, opt, batch)
        if i % max(args.steps // 6, 1) == 0:
            print(f"step {i:4d}  bce {float(loss):.4f}")

    # --- online serving (p99-style small batch) ---
    serve = jax.jit(lambda p, b: serve_step(p, cfg, b))
    raw = recsys_batch(256, cfg.n_fields, cfg.vocab_per_field, seed=2)
    b = {"sparse_ids": jnp.asarray(raw["sparse_ids"])}
    scores = serve(params, b)
    jax.block_until_ready(scores)
    t0 = time.perf_counter()
    for _ in range(20):
        jax.block_until_ready(serve(params, b))
    dt = (time.perf_counter() - t0) / 20
    print(f"serve batch=256: {dt*1e3:.2f} ms/batch "
          f"({256/dt:,.0f} scores/s), score range "
          f"[{float(scores.min()):.3f}, {float(scores.max()):.3f}]")

    # --- retrieval: one query against n_candidates ---
    q = {"sparse_ids": b["sparse_ids"][:1]}
    t0 = time.perf_counter()
    _scores, (vals, idx) = serve_retrieval(params, cfg, q, top_k=10)
    jax.block_until_ready(vals)
    dt = time.perf_counter() - t0
    print(f"retrieval over {cfg.n_candidates:,} candidates: {dt*1e3:.1f} ms; "
          f"top-3 ids {list(map(int, idx[:3]))}")


if __name__ == "__main__":
    main()
