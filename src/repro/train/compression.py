"""Gradient compression: int8 quantization with error feedback.

For bandwidth-bound data-parallel training, gradients are quantized to int8
with a per-tensor scale before the all-reduce and the quantization error is
carried into the next step (error feedback keeps SGD/Adam convergence; see
1-bit Adam / EF-SGD literature). The quantize/dequantize pair is exact
enough that tests assert convergence parity on a quadratic problem.

Usage: wrap grads between value_and_grad and the optimizer:
    grads, ef = compress_decompress(grads, ef)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, error_feedback):
    """Simulated compressed all-reduce: returns (decompressed grads, new EF).

    On a real fleet the int8 payload is what crosses the wire (psum over
    int32 accumulators); numerically the result equals this local
    quantize->dequantize, which is what tests validate.
    """

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), corrected - deq

    out = jax.tree.map(one, grads, error_feedback)
    new_g = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e
