"""Training substrate: optimizer, loop, checkpointing, fault tolerance."""
