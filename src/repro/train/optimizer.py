"""AdamW with configurable moment dtype and ZeRO-shardable state.

The optimizer state is a plain pytree mirroring the params, so pjit can give
the moments *finer* sharding than the params (ZeRO-1): the update then
compiles to reduce-scatter(grads) -> sharded update -> all-gather(params)
automatically. Moment dtype bf16 halves optimizer memory for the 671B
config (see DESIGN.md section 4).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer memory
    warmup_steps: int = 100
    total_steps: int = 10_000


def init_opt_state(params, cfg: AdamWConfig) -> dict[str, Any]:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def _lr_at(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cosine = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cosine)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = _lr_at(cfg, step.astype(jnp.float32))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m32.astype(mdt),
            v32.astype(mdt),
        )

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"step": step, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
