"""Fault-tolerant training loop: auto-resume, async checkpoints, straggler
watchdog, optional gradient compression and microbatch accumulation."""
from __future__ import annotations

import logging
from dataclasses import dataclass
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.obs import trace
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import compress_decompress, init_error_feedback
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

log = logging.getLogger("repro.train")


@dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str | None = None
    keep_checkpoints: int = 3
    log_every: int = 10
    # straggler watchdog: warn when a step exceeds ema_factor x EMA
    watchdog_factor: float = 3.0
    grad_compression: bool = False
    num_microbatches: int = 1


def make_train_step(
    loss_fn: Callable,
    opt_cfg: AdamWConfig,
    *,
    num_microbatches: int = 1,
    grad_compression: bool = False,
):
    """Build a (params, opt_state, ef, batch) -> (params, opt_state, ef,
    metrics) step with optional gradient accumulation.

    With num_microbatches > 1, the batch's leading axis is split and grads
    are accumulated in a lax.scan -- the activation-memory lever that lets
    the big configs fit (DESIGN.md section 4).
    """

    def accumulate(params, batch):
        if num_microbatches == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def micro(i, batch):
            return jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // num_microbatches),
                    x.shape[0] // num_microbatches, 0,
                ),
                batch,
            )

        def body(carry, i):
            loss_acc, grad_acc = carry
            loss, grads = jax.value_and_grad(loss_fn)(params, micro(i, batch))
            return (
                loss_acc + loss,
                jax.tree.map(jnp.add, grad_acc, grads),
            ), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(
            body, (jnp.float32(0), zeros), jnp.arange(num_microbatches)
        )
        scale = 1.0 / num_microbatches
        return loss * scale, jax.tree.map(lambda g: g * scale, grads)

    def step(params, opt_state, ef, batch):
        loss, grads = accumulate(params, batch)
        if grad_compression:
            grads, ef = compress_decompress(grads, ef)
        params, opt_state, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, ef, metrics

    return step


class StragglerWatchdog:
    """EMA step-time monitor. On a real fleet this feeds the coordinator's
    slow-host eviction; here it records and warns (unit-tested logic)."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.2):
        self.factor = factor
        self.alpha = alpha
        self.ema: float | None = None
        self.slow_steps: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        if slow:
            self.slow_steps.append((step, dt))
            log.warning("straggler: step %d took %.3fs (ema %.3fs)", step, dt, self.ema)
        self.ema = dt if self.ema is None else (1 - self.alpha) * self.ema + self.alpha * dt
        return slow


def train(
    params,
    loss_fn: Callable,
    data_iter: Iterator[Any],
    opt_cfg: AdamWConfig,
    loop_cfg: LoopConfig,
    *,
    jit_kwargs: dict | None = None,
) -> tuple[Any, dict]:
    """Run the loop; auto-resumes from the newest checkpoint if present."""
    opt_state = init_opt_state(params, opt_cfg)
    ef = init_error_feedback(params) if loop_cfg.grad_compression else None

    step_fn = make_train_step(
        loss_fn,
        opt_cfg,
        num_microbatches=loop_cfg.num_microbatches,
        grad_compression=loop_cfg.grad_compression,
    )
    if loop_cfg.grad_compression:
        jitted = jax.jit(step_fn, **(jit_kwargs or {}))
    else:
        jitted = jax.jit(
            lambda p, o, b: _drop_ef(step_fn, p, o, b), **(jit_kwargs or {})
        )

    mgr = None
    start_step = 0
    if loop_cfg.checkpoint_dir:
        mgr = CheckpointManager(loop_cfg.checkpoint_dir, keep=loop_cfg.keep_checkpoints)
        latest = mgr.latest_step()
        if latest is not None:
            state = mgr.restore(latest, {"params": params, "opt_state": opt_state})
            params = jax.tree.map(jnp.asarray, state["params"])
            opt_state = jax.tree.map(jnp.asarray, state["opt_state"])
            start_step = latest
            log.info("resumed from checkpoint step %d", latest)

    watchdog = StragglerWatchdog(loop_cfg.watchdog_factor)
    history: list[dict] = []
    for step in range(start_step, loop_cfg.total_steps):
        batch = next(data_iter)
        # timer=True: the span times (and blocks, device=True) even with
        # tracing disabled -- the straggler watchdog needs dt always.
        with trace.span(
            "train.step", device=True, timer=True, step=step,
        ) as sp:
            if loop_cfg.grad_compression:
                params, opt_state, ef, metrics = jitted(
                    params, opt_state, ef, batch
                )
            else:
                params, opt_state, metrics = jitted(params, opt_state, batch)
            sp.block_on(metrics["loss"])
        dt = sp.duration
        # The span close already paid the sync (the watchdog times full
        # steps); reading the scalar afterwards is free.
        loss = float(metrics["loss"])  # repro-lint: disable=host-sync
        watchdog.observe(step, dt)
        if step % loop_cfg.log_every == 0:
            log.info("step %d loss %.4f (%.3fs)", step, loss, dt)
        history.append({"step": step, "loss": loss, "dt": dt})
        if mgr and (step + 1) % loop_cfg.checkpoint_every == 0:
            mgr.save(step + 1, {"params": params, "opt_state": opt_state})
    if mgr:
        mgr.save(loop_cfg.total_steps, {"params": params, "opt_state": opt_state},
                 blocking=True)
    return params, {
        "history": history,
        "slow_steps": watchdog.slow_steps,
        "final_loss": history[-1]["loss"] if history else None,
    }


def _drop_ef(step_fn, p, o, b):
    p2, o2, _ef, m = step_fn(p, o, None, b)
    return p2, o2, m
