"""Fault-tolerant checkpointing: atomic, async, keep-k, mesh-portable.

Layout: <dir>/step_<n>/  one .npy per leaf (path-encoded filename) plus
meta.json with the treedef and step. Writes go to step_<n>.tmp and are
renamed only when complete, so a preemption mid-save never corrupts the
latest checkpoint. An async writer thread keeps the train loop hot; the
loop joins it before the next save (bounded queue of 1).

Checkpoints store full (unsharded) arrays per leaf, so restoring onto a
*different* mesh is just device_put with the new sharding -- this is the
elastic-scaling path (train/elastic.py). A multi-host deployment would
write per-shard files keyed by shard index; the format reserves that in
meta.json ("sharding": "replicated" today).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "__"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out[name] = np.asarray(jax.device_get(leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- write ----------------------------------------------------------
    def save(self, step: int, state: dict[str, Any], blocking: bool = False):
        """state: pytree dict (e.g. {"params": ..., "opt_state": ...})."""
        self.wait()  # at most one in-flight save
        arrays = _flatten(state)
        treedef = jax.tree_util.tree_structure(state)
        meta = {
            "step": int(step),
            "treedef": str(treedef),
            "sharding": "replicated",
            "leaves": list(arrays.keys()),
        }

        def _write():
            final = os.path.join(self.directory, f"step_{step:09d}")
            if os.path.exists(final):  # idempotent re-save after resume
                return
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for name, arr in arrays.items():
                np.save(os.path.join(tmp, name + ".npy"), arr)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"))

    # -- read -----------------------------------------------------------
    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: dict[str, Any]) -> dict[str, Any]:
        """Restore into the structure of `like` (a pytree of arrays or
        ShapeDtypeStructs). Returns numpy-leaved pytree; caller device_puts
        with whatever sharding the current mesh wants (elastic restore)."""
        d = os.path.join(self.directory, f"step_{step:09d}")
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, leaf in flat:
            name = _SEP.join(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in path
            )
            arr = np.load(os.path.join(d, name + ".npy"))
            expected = tuple(leaf.shape)
            if tuple(arr.shape) != expected:
                raise ValueError(
                    f"checkpoint leaf {name}: shape {arr.shape} != {expected}"
                )
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)
