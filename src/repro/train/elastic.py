"""Elastic scaling: reshard a training state onto a different mesh.

Checkpoints store full arrays (checkpoint.py), so growing/shrinking the
fleet is: restore -> device_put with the new mesh's NamedShardings ->
continue. The only validation needed is divisibility of sharded dims by the
new axis sizes; we check and fall back to replication per-leaf otherwise
(with a warning), which is always correct.
"""
from __future__ import annotations

import logging

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import Mesh

log = logging.getLogger("repro.elastic")


def _axis_size(mesh: Mesh, dim) -> int:
    if dim is None:
        return 1
    if isinstance(dim, str):
        return mesh.shape[dim]
    out = 1
    for a in dim:
        out *= mesh.shape[a]
    return out


def fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop axis names absent from mesh; replicate dims that don't divide."""
    parts = []
    for i, dim in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if dim is None:
            parts.append(None)
            continue
        names = (dim,) if isinstance(dim, str) else tuple(dim)
        names = tuple(a for a in names if a in mesh.axis_names)
        if not names:
            parts.append(None)
            continue
        size = 1
        for a in names:
            size *= mesh.shape[a]
        if shape[i] % size:
            log.warning(
                "elastic: dim %d of shape %s not divisible by %s=%d; replicating",
                i, shape, names, size,
            )
            parts.append(None)
        else:
            parts.append(names if len(names) > 1 else names[0])
    return P(*parts)


def reshard_state(state, spec_tree, mesh: Mesh):
    """state: numpy/jax pytree; spec_tree: PartitionSpec pytree (same
    structure). Returns device arrays sharded for `mesh`."""

    def put(x, spec):
        fitted = fit_spec(spec, tuple(x.shape), mesh)
        return jax.device_put(x, NamedSharding(mesh, fitted))

    return jax.tree.map(
        put, state, spec_tree,
    )
