"""LM serving engine: continuous-batching decode over the KV-cache API."""
from repro.serve.engine import Request, ServeEngine

__all__ = ["Request", "ServeEngine"]
