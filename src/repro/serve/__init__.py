"""Serving engines: wave-batched LM decode and graph-analytics serving
over one shared wave scheduler (``serve/waves.py``) with fault
containment (quarantine + bisection, bounded retry, graceful
degradation — ``docs/serving.md``) and a deterministic fault-injection
harness (``serve/faults.py``)."""
from repro.serve.engine import OVERFLOW_POLICIES, Request, ServeEngine
from repro.serve.faults import (
    FaultPlan,
    InjectedEngineError,
    InjectedFault,
    SimulatedOOM,
    TransientFault,
    classify_failure,
    is_resource_exhausted,
)
from repro.serve.graph import (
    KINDS,
    GraphRequest,
    GraphResult,
    GraphServeEngine,
    WaveRecord,
)
from repro.serve.waves import FAILURE_POLICIES, HealthRecord, WaveScheduler

__all__ = [
    "Request",
    "ServeEngine",
    "OVERFLOW_POLICIES",
    "GraphRequest",
    "GraphResult",
    "GraphServeEngine",
    "WaveRecord",
    "KINDS",
    "WaveScheduler",
    "HealthRecord",
    "FAILURE_POLICIES",
    "FaultPlan",
    "InjectedFault",
    "InjectedEngineError",
    "TransientFault",
    "SimulatedOOM",
    "classify_failure",
    "is_resource_exhausted",
]
