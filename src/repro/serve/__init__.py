"""Serving engines: wave-batched LM decode and graph-analytics serving
over one shared wave scheduler (``serve/waves.py``)."""
from repro.serve.engine import OVERFLOW_POLICIES, Request, ServeEngine
from repro.serve.graph import (
    KINDS,
    GraphRequest,
    GraphResult,
    GraphServeEngine,
    WaveRecord,
)
from repro.serve.waves import WaveScheduler

__all__ = [
    "Request",
    "ServeEngine",
    "OVERFLOW_POLICIES",
    "GraphRequest",
    "GraphResult",
    "GraphServeEngine",
    "WaveRecord",
    "KINDS",
    "WaveScheduler",
]
