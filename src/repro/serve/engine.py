"""Wave-batched LM serving engine over the transformer KV-cache API.

Batched request scheduling adapted to static JAX shapes: the engine owns a
fixed (num_slots, max_len) KV cache; up to ``num_slots`` requests are
admitted per WAVE, prefilled token-by-token through the same jitted
``serve_step`` used for decode (one compilation total), and the wave
retires when every member finishes (EOS / token budget / cache end).
Early-finishing slots idle masked -- the branch-free analogue of the
paper's lockstep walk: all lanes step together, finished lanes burn no
semantics. The outer queue -> wave -> finished loop is the shared
``serve/waves.WaveScheduler`` (the graph-analytics engine in
``serve/graph.py`` runs the same scheduler under a different capacity
model).

Capacity contract (validated at ``submit``, never silently violated by
the wave loop): a prompt of P tokens occupies cache rows 0..P-1 during
prefill, the first output token is predicted off row P-1, and each
further token must be fed back through a fresh row -- so P <= max_len
is required to emit anything at all, and the most a request can ever
get is ``max_len - P + 1`` tokens (the run that writes the final cache
row). Overlong prompts either raise (``on_overflow="error"``) or keep
their last ``max_len`` tokens with ``req.truncated`` set
(``on_overflow="truncate"``).

Per-slot-position continuous batching (vLLM-style slot reuse mid-wave)
needs a vector-position cache API; recorded in DESIGN.md section Next. The
wave scheduler is exact: each slot's cache rows only ever contain its own
request's tokens.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.components import check_choice
from repro.models.transformer import init_kv_cache, serve_step
from repro.obs import trace
from repro.serve.waves import WaveScheduler

Array = jax.Array

OVERFLOW_POLICIES = ("error", "truncate")


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    output: list[int] = field(default_factory=list)
    done: bool = False
    truncated: bool = False  # prompt clipped by on_overflow="truncate"
    failed: bool = False  # quarantined by the containment layer
    error: str | None = None  # captured failure, when failed


class ServeEngine(WaveScheduler):
    def __init__(
        self,
        params,
        cfg,
        *,
        num_slots: int = 4,
        max_len: int = 256,
        on_overflow: str = "error",
        max_retries: int = 1,
        on_failure: str = "quarantine",
        fault_plan=None,
    ):
        check_choice("on_overflow", on_overflow, OVERFLOW_POLICIES)
        super().__init__(
            max_retries=max_retries, on_failure=on_failure,
            fault_plan=fault_plan,
        )
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.on_overflow = on_overflow
        self._step = jax.jit(lambda p, c, t, i: serve_step(p, cfg, c, t, i))

    def submit(self, req: Request):
        """Admit a request, enforcing the cache-capacity contract.

        ``max_new_tokens <= 0`` requests finish immediately (empty
        output) instead of burning a wave slot; prompts longer than
        ``max_len`` could never emit a token, so they raise (or are
        truncated to their last ``max_len`` tokens under
        ``on_overflow="truncate"``) rather than exhausting the wave
        loop with ``done=False`` -- the silent-drop failure mode.
        """
        if not req.prompt:
            raise ValueError(f"request {req.uid}: empty prompt")
        if req.max_new_tokens <= 0:
            self._register(req)  # delivered by the next run(); uid in flight
            req.done = True
            self.finished.append(req)
            return
        if len(req.prompt) > self.max_len:
            if self.on_overflow == "error":
                raise ValueError(
                    f"request {req.uid}: prompt length {len(req.prompt)} "
                    f"exceeds max_len={self.max_len} (no room to emit a "
                    "token); shorten it or use on_overflow='truncate'"
                )
            req.prompt = list(req.prompt[-self.max_len:])
            req.truncated = True
        super().submit(req)

    # ------------------------------------------------------------------
    def _next_wave(self) -> list[Request]:
        wave = self.queue[: self.num_slots]
        self.queue = self.queue[self.num_slots:]
        return wave

    def _degrade(self, wave: list[Request], exc: Exception) -> list | None:
        """OOM-shaped failure: permanently halve the KV-cache width
        (the (num_slots, max_len) allocation) and re-pack this wave
        into narrower sub-waves. At one slot there is nothing left to
        shrink, so the request quarantines."""
        if self.num_slots <= 1 or len(wave) <= 1:
            return None
        self.num_slots = max(1, self.num_slots // 2)
        k = self.num_slots
        return [wave[i:i + k] for i in range(0, len(wave), k)]

    def _run_wave(self, wave: list[Request]):
        if self.fault_plan is not None:
            self.fault_plan.check_wave(wave)
            self.fault_plan.check_slots(self.num_slots)
        cache = init_kv_cache(self.cfg, self.num_slots, self.max_len)
        pending = [list(r.prompt) for r in wave]
        active = [True] * len(wave)
        pos = 0
        # One span per wave, not per token: the lockstep loop already
        # syncs every step (np.asarray on the logits), so a span per
        # token would add trace events, not information.
        with trace.span(
            "serve.wave.decode", requests=len(wave), slots=self.num_slots,
        ) as sp:
            while any(active) and pos < self.max_len:
                tokens = np.zeros((self.num_slots, 1), np.int32)
                for s, r in enumerate(wave):
                    if pending[s]:
                        tokens[s, 0] = pending[s][0]
                    elif r.output:
                        tokens[s, 0] = r.output[-1]
                    else:
                        tokens[s, 0] = r.prompt[-1]
                logits, cache = self._step(
                    self.params, cache, jnp.asarray(tokens), jnp.int32(pos)
                )
                nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
                for s, r in enumerate(wave):
                    if not active[s]:
                        continue
                    if pending[s]:
                        pending[s].pop(0)
                        if pending[s]:
                            continue  # still prefilling; prediction unused
                    tok = int(nxt[s])
                    r.output.append(tok)
                    if (
                        len(r.output) >= r.max_new_tokens
                        or (r.eos_id is not None and tok == r.eos_id)
                        # continuing needs row pos + 1 for the fed-back
                        # token: retire only once that row would fall off
                        # the cache, so the final row is usable like any
                        # other.
                        or pos + 2 > self.max_len
                    ):
                        r.done = True
                        active[s] = False
                pos += 1
            sp.tag(steps=pos)
        self.metrics.inc("serve.lm.waves")
        self.metrics.inc("serve.lm.steps", pos)
        self.metrics.inc(
            "serve.lm.tokens", sum(len(r.output) for r in wave)
        )

    def run(self) -> list[Request]:
        """Process the whole queue; returns the requests that reached a
        terminal state during THIS call (``done``, or ``failed`` under
        injected/real faults) in completion order -- zero-budget
        requests finish at submit and deliver with the next run."""
        return super().run()
