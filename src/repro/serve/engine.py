"""Wave-batched LM serving engine over the transformer KV-cache API.

Batched request scheduling adapted to static JAX shapes: the engine owns a
fixed (num_slots, max_len) KV cache; up to ``num_slots`` requests are
admitted per WAVE, prefilled token-by-token through the same jitted
``serve_step`` used for decode (one compilation total), and the wave
retires when every member finishes (EOS / token budget). Early-finishing
slots idle masked -- the branch-free analogue of the paper's lockstep walk:
all lanes step together, finished lanes burn no semantics.

Per-slot-position continuous batching (vLLM-style slot reuse mid-wave)
needs a vector-position cache API; recorded in DESIGN.md section Next. The
wave scheduler is exact: each slot's cache rows only ever contain its own
request's tokens.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import init_kv_cache, serve_step

Array = jax.Array


@dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    output: list[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, params, cfg, *, num_slots: int = 4, max_len: int = 256):
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._step = jax.jit(lambda p, c, t, i: serve_step(p, cfg, c, t, i))
        self.waves = 0

    def submit(self, req: Request):
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _run_wave(self, wave: list[Request]):
        cache = init_kv_cache(self.cfg, self.num_slots, self.max_len)
        pending = [list(r.prompt) for r in wave]
        active = [True] * len(wave)
        pos = 0
        while any(active) and pos < self.max_len:
            tokens = np.zeros((self.num_slots, 1), np.int32)
            for s, r in enumerate(wave):
                if pending[s]:
                    tokens[s, 0] = pending[s][0]
                elif r.output:
                    tokens[s, 0] = r.output[-1]
                else:
                    tokens[s, 0] = r.prompt[-1]
            logits, cache = self._step(
                self.params, cache, jnp.asarray(tokens), jnp.int32(pos)
            )
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
            for s, r in enumerate(wave):
                if not active[s]:
                    continue
                if pending[s]:
                    pending[s].pop(0)
                    if pending[s]:
                        continue  # still prefilling; prediction unused
                tok = int(nxt[s])
                r.output.append(tok)
                if (
                    len(r.output) >= r.max_new_tokens
                    or (r.eos_id is not None and tok == r.eos_id)
                    or pos + 2 >= self.max_len
                ):
                    r.done = True
                    active[s] = False
            pos += 1
        self.finished.extend(wave)
        self.waves += 1

    def run(self) -> list[Request]:
        """Process the whole queue; returns finished requests in order."""
        while self.queue:
            wave = self.queue[: self.num_slots]
            self.queue = self.queue[self.num_slots :]
            self._run_wave(wave)
        return self.finished
