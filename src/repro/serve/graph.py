"""Wave-batched graph-analytics serving over the ``repro.core`` engines.

The target workload is the ROADMAP's "many small molecule graphs per
call": a stream of independent little CC / spanning-forest / tree-
analytics requests that would each waste an accelerator dispatch (and,
worse, a compilation per odd shape) if issued alone. The engine applies
the paper's central lesson -- keep device work branch-free and
shape-static so irregular graph inputs never force recompilation -- to
serving:

* requests queue up and are admitted in FIFO order into WAVES under a
  node/edge budget (``serve/waves.WaveScheduler``, the same outer loop
  as the LM token engine);
* each wave is packed into ONE disjoint-union graph by node/edge offset
  packing -- request i's nodes become ``[node_off[i], node_off[i] +
  n_i)`` -- then padded to a power-of-two **capacity bucket**
  (``core/frontier.next_pow2`` on nodes and edges; pad nodes are
  isolated, pad edges are inert (0, 0) self-loops, and the analytics
  stage pads its forest-edge buffer to the node capacity so the tour
  ranks at the fixed ``2 * node_cap`` arc capacity of
  ``trees/tour.tour_capacity``'s convention);
* the packed union runs through the existing engines as one batched
  device program per wave stage -- ``connected_components`` /
  ``spanning_forest`` / ``tree_analytics`` with ``dedup=False`` so
  shapes stay bucket-static -- and results are unpacked per request by
  offset.

**Bit-exactness.** CC, spanning forests, and Euler-tour analytics over
a disjoint union decompose per component: every SV hook compares labels
only within a component, labels are per-request node ids shifted by the
request's node offset (min node id is offset-shifted), the recorded
hook edges of request i are exactly its solo hook edges shifted, and
the tour's stable source-sort preserves each request's arc order. Pad
nodes are isolated self-components, pad self-loop edges can never hook,
and ``record_hooks`` / extra converged rounds are label-neutral -- so
every unpacked result is bit-identical to issuing the request alone
with the same engine knobs (asserted in ``tests/test_serve_graph.py``;
per-request ``rounds`` is the one quantity that does NOT decompose --
the union runs to the slowest member -- so it is reported per wave, not
per request).

**Compile accounting.** All device programs in a wave are keyed only by
the wave's ``(stage, node_cap, edge_cap)`` bucket, so the jit caches
compile once per bucket and every later wave in that bucket reuses
them. ``engine="auto"`` resolves to ``"dense"`` on a single device: the
auto dispatch's Afforest sampling policy keys on edge density, which
packing changes, and its frontier ladder adds data-dependent inner
bucket compiles -- both would break the serve path's bit-exactness and
compile-count guarantees. Any explicitly pinned engine is honoured
(the frontier/sharded engines stay bit-exact; their host-driven ladders
add at most log2(edge_cap) bounded extra compiles per bucket).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np

from repro.core.components import check_choice
from repro.core.frontier import next_pow2
from repro.obs import trace
from repro.serve.waves import WaveScheduler

# Request kinds. The first three form a pipeline-stage chain -- each
# stage subsumes the ones before it, so a mixed wave runs the deepest
# stage any member needs (record_hooks and the tour stages are
# label-neutral by construction). "sssp" and "pagerank" are OUTSIDE
# the chain: each runs a different device program (relax-min over
# weighted edges; add-monoid mass push), so ``_next_wave`` packs them
# only with their own kind -- stage promotion never mixes families.
KINDS = ("cc", "forest", "analytics", "sssp", "pagerank")
_STAGE = {
    k: i for i, k in enumerate(KINDS) if k not in ("sssp", "pagerank")
}


def _family(kind: str) -> str:
    """Wave-packing family: kinds that can share one device program."""
    return kind if kind in ("sssp", "pagerank") else "cc-chain"


@dataclass
class GraphResult:
    """Per-request outputs, unpacked to request-local node ids.

    ``labels``/``num_components`` are filled for every kind in the
    cc-chain family; ``edge_u``/``edge_v`` (the spanning forest, in
    solo edge order) from kind ``"forest"`` up; the tree-analytics
    arrays only for ``"analytics"``. Kind ``"sssp"`` instead fills
    ``dist``/``pred``/``sources``: one row per source, ``+inf`` /
    ``-1`` for unreachable nodes. Kind ``"pagerank"`` fills only
    ``scores``: per-node float32 PageRank mass at the engine's fixed
    iteration count (``pagerank_iters``).
    """

    labels: np.ndarray | None = None
    num_components: int = 0
    edge_u: np.ndarray | None = None
    edge_v: np.ndarray | None = None
    parent: np.ndarray | None = None
    depth: np.ndarray | None = None
    subtree_size: np.ndarray | None = None
    preorder: np.ndarray | None = None
    postorder: np.ndarray | None = None
    dist: np.ndarray | None = None  # (num_sources, n) float32
    pred: np.ndarray | None = None  # (num_sources, n) int32 parent tree
    sources: np.ndarray | None = None  # the request's source nodes
    scores: np.ndarray | None = None  # (n,) float32 pagerank mass


@dataclass
class GraphRequest:
    uid: int
    src: np.ndarray
    dst: np.ndarray
    num_nodes: int
    kind: str = "analytics"
    # weighted-kind inputs: per-edge weights (None = unit) for sssp /
    # pagerank and the sssp source nodes (None = [0]); rejected on
    # kinds that cannot consume them.
    weights: np.ndarray | None = None
    sources: np.ndarray | None = None
    result: GraphResult | None = None
    done: bool = False
    failed: bool = False  # quarantined by the containment layer
    error: str | None = None  # captured failure, when failed

    @property
    def num_edges(self) -> int:
        return int(len(self.src))


@dataclass
class WaveRecord:
    """Deterministic per-wave accounting (benchmarks/graph_serve)."""

    requests: int
    stage: str
    num_nodes: int  # live union nodes
    num_edges: int  # live union edges
    node_cap: int
    edge_cap: int
    new_bucket: bool  # first wave in this (stage, node_cap, edge_cap)
    rounds: int  # SV/relax rounds of the union run (max over members)
    src_cap: int = 0  # sssp waves: padded source-row capacity

    def publish(
        self, registry=None, prefix: str = "serve.graph.wave"
    ) -> None:
        """Publish into the metrics registry (``repro.obs.metrics``):
        counters accumulate across waves, so ``.requests`` is the
        engine's served-request total and ``.new_bucket`` its bucket
        compiles."""
        from repro.obs.metrics import publish_stats

        publish_stats(self, prefix, registry)


class GraphServeEngine(WaveScheduler):
    """Admit many small graph requests; serve each wave as one padded
    batched engine call. See the module docstring for the packing /
    bucketing / exactness model and ``docs/serving.md`` for knobs.

    * ``max_requests`` (default 16), ``max_nodes`` (4096), ``max_edges``
      (16384) -- wave admission budget; a single request beyond the
      node/edge budget is rejected at ``submit`` (never silently
      dropped later).
    * ``min_nodes`` (64) / ``min_edges`` (128) -- bucket floor, so tiny
      waves share one small-bucket compilation instead of one per size.
    * ``max_sources`` (8) -- per-request source budget for
      ``kind="sssp"`` requests; a wave's source rows pack into a
      ``src_cap`` power-of-two bucket dimension (see
      ``_run_sssp_wave``). sssp waves map ``engine="auto"`` to
      ``"dense"`` like CC waves and reject ``mesh=`` /
      ``engine="sharded_frontier"`` at submit.
    * ``damping`` (0.85) / ``pagerank_iters`` (None =
      ``pagerank_iter_bound(damping, DEFAULT_TOL)``) -- the
      engine-wide ``kind="pagerank"`` knobs. PageRank serving always
      runs the DENSE fixed-iteration engine at exactly
      ``pagerank_iters`` iterations: a tolerance-driven stop would
      run every wave to its slowest member's iteration count, making
      a request's scores depend on its wave-mates. Fixed iterations
      keep batched == solo bit-exact (see ``_run_pagerank_wave``).
    * ``engine=`` / ``rank_engine=`` / ``kernel_impl=`` /
      ``num_splitters=`` / ``mesh=`` and any extra engine kwargs
      (``hook_impl=``, ``exchange=``, ``min_bucket=``, ...) dispatch
      exactly as in ``repro.core`` (full matrix: ``docs/engines.md``),
      except ``engine="auto"`` resolves to ``"dense"`` on one device
      (see module docstring) and the sampling pre-pass
      (``sample_rounds``) is rejected: it re-roots components by edge
      density, which packing changes -- it would break batched == solo.
    * ``max_retries=`` / ``on_failure=`` (``"quarantine"`` default,
      ``"raise"``) / ``fault_plan=`` -- the containment knobs
      (``serve/waves.py``; failure semantics in ``docs/serving.md``).
      An OOM-shaped wave failure permanently caps the packing budget to
      half the failing bucket and re-packs smaller waves; a request is
      only failed when it exhausts the device alone.
    """

    def __init__(
        self,
        *,
        max_requests: int = 16,
        max_nodes: int = 4096,
        max_edges: int = 16384,
        min_nodes: int = 64,
        min_edges: int = 128,
        max_sources: int = 8,
        damping: float = 0.85,
        pagerank_iters: int | None = None,
        engine: str = "auto",
        rank_engine: str = "auto",
        kernel_impl: str = "auto",
        num_splitters: int | None = None,
        mesh=None,
        max_retries: int = 1,
        on_failure: str = "quarantine",
        fault_plan=None,
        **engine_kwargs,
    ):
        import repro.core as core
        from repro.core.list_ranking import KERNEL_IMPLS
        from repro.core.pagerank import DEFAULT_TOL, pagerank_iter_bound
        from repro.trees.compute import RANK_ENGINES

        check_choice("engine", engine, core._CC_ENGINES)
        check_choice("rank_engine", rank_engine, RANK_ENGINES)
        check_choice("kernel_impl", kernel_impl, KERNEL_IMPLS)
        bad = {
            "sample_rounds", "seed", "dedup", "record_hooks", "with_stats",
        } & set(engine_kwargs)
        if bad:
            raise ValueError(
                f"{sorted(bad)} are not servable knobs: the serve path "
                "fixes dedup/record_hooks itself and the sampling "
                "pre-pass would break batched == solo bit-exactness"
            )
        super().__init__(
            max_retries=max_retries, on_failure=on_failure,
            fault_plan=fault_plan,
        )
        self.max_requests = max_requests
        self.max_nodes = max_nodes
        self.max_edges = max_edges
        self.min_nodes = min_nodes
        self.min_edges = min_edges
        self.max_sources = max_sources  # per-request sssp source budget
        # PageRank serve knobs are engine-wide (wave-uniform): every
        # request in a pagerank wave runs the same damping at the same
        # fixed iteration count, so the resolved count is pinned HERE.
        # pagerank_iter_bound also validates damping in (0, 1).
        self.damping = float(damping)
        default_iters = pagerank_iter_bound(self.damping, DEFAULT_TOL)
        self.pagerank_iters = (
            default_iters if pagerank_iters is None else int(pagerank_iters)
        )
        if self.pagerank_iters < 1:
            raise ValueError("pagerank_iters must be >= 1")
        # Degradation caps (permanent, only ever lowered): the packing
        # budget after OOM-shaped failures; see _degrade.
        self._node_budget = max_nodes
        self._edge_budget = max_edges
        if engine == "auto" and mesh is None and jax.device_count() == 1:
            engine = "dense"
        self.engine = engine
        self.rank_engine = rank_engine
        self.kernel_impl = kernel_impl
        self.num_splitters = num_splitters
        self.mesh = mesh
        self.engine_kwargs = dict(engine_kwargs)
        self.wave_records: list[WaveRecord] = []
        self._buckets: set[tuple[str, int, int]] = set()

    # -- deterministic counters (guarded by benchmarks/run.py --check) --
    @property
    def bucket_compiles(self) -> int:
        """Distinct (stage, node_cap, edge_cap) buckets instantiated --
        each is one set of jit-cache entries, reused by every later
        wave in the bucket."""
        return len(self._buckets)

    @property
    def requests_per_wave(self) -> float:
        recs = self.wave_records
        return sum(r.requests for r in recs) / len(recs) if recs else 0.0

    @property
    def node_pad_waste(self) -> float:
        """Padded node slots that carried no request, as a fraction."""
        recs = self.wave_records
        cap = sum(r.node_cap for r in recs)
        return 1.0 - sum(r.num_nodes for r in recs) / cap if cap else 0.0

    @property
    def edge_pad_waste(self) -> float:
        recs = self.wave_records
        cap = sum(r.edge_cap for r in recs)
        return 1.0 - sum(r.num_edges for r in recs) / cap if cap else 0.0

    # ------------------------------------------------------------------
    def submit(self, req: GraphRequest):
        """Validate and enqueue. Rejections happen HERE, loudly -- a
        request that could never fit a wave must not reach the wave
        loop (the LM engine's overlong-prompt lesson)."""
        check_choice("kind", req.kind, KINDS)
        if req.num_nodes < 1:
            raise ValueError(f"request {req.uid}: num_nodes must be >= 1")
        req.src = np.asarray(req.src, np.int32).ravel()
        req.dst = np.asarray(req.dst, np.int32).ravel()
        if req.src.shape != req.dst.shape:
            raise ValueError(
                f"request {req.uid}: src/dst length mismatch "
                f"({req.src.shape} vs {req.dst.shape})"
            )
        if req.num_nodes > self.max_nodes or req.num_edges > self.max_edges:
            raise ValueError(
                f"request {req.uid}: {req.num_nodes} nodes / "
                f"{req.num_edges} edges exceeds the wave budget "
                f"(max_nodes={self.max_nodes}, max_edges={self.max_edges})"
            )
        if req.num_edges and (
            int(min(req.src.min(), req.dst.min())) < 0
            or int(max(req.src.max(), req.dst.max())) >= req.num_nodes
        ):
            raise ValueError(
                f"request {req.uid}: edge endpoints outside "
                f"[0, {req.num_nodes})"
            )
        if req.kind == "sssp":
            self._validate_sssp(req)
        elif req.kind == "pagerank":
            self._validate_pagerank(req)
        elif req.weights is not None or req.sources is not None:
            raise ValueError(
                f"request {req.uid}: weights/sources are only consumed "
                "by the sssp/pagerank kinds"
            )
        super().submit(req)

    def _validate_sssp(self, req: GraphRequest) -> None:
        """Normalize + validate the sssp-only request fields, loudly."""
        if self.mesh is not None or self.engine == "sharded_frontier":
            raise ValueError(
                f"request {req.uid}: sssp waves run the single-device "
                "relax engines; drop mesh= / engine='sharded_frontier'"
            )
        extra = set(self.engine_kwargs) - {"min_bucket"}
        if extra:
            raise ValueError(
                f"request {req.uid}: {sorted(extra)} are not sssp "
                "engine knobs (only min_bucket= carries over)"
            )
        if req.weights is None:
            w = np.ones(req.num_edges, np.float32)  # unit weights: BFS
        else:
            w = np.asarray(req.weights, np.float32).ravel()
        if w.shape != req.src.shape:
            raise ValueError(
                f"request {req.uid}: weights length {w.shape} != edge "
                f"count {req.src.shape}"
            )
        if req.num_edges and (not np.isfinite(w).all() or bool((w < 0).any())):
            raise ValueError(
                f"request {req.uid}: sssp weights must be finite and >= 0"
            )
        req.weights = w
        if req.sources is None:
            s = np.zeros(1, np.int32)
        else:
            s = np.atleast_1d(np.asarray(req.sources, np.int32)).ravel()
        if not 1 <= len(s) <= self.max_sources:
            raise ValueError(
                f"request {req.uid}: {len(s)} sources exceeds the "
                f"per-request budget (1..max_sources={self.max_sources})"
            )
        if int(s.min()) < 0 or int(s.max()) >= req.num_nodes:
            raise ValueError(
                f"request {req.uid}: sources outside [0, {req.num_nodes})"
            )
        req.sources = s

    def _validate_pagerank(self, req: GraphRequest) -> None:
        """Normalize + validate the pagerank-only request fields."""
        if self.mesh is not None or self.engine == "sharded_frontier":
            raise ValueError(
                f"request {req.uid}: pagerank waves run the single-"
                "device dense engine; drop mesh= / "
                "engine='sharded_frontier'"
            )
        if self.engine_kwargs:
            raise ValueError(
                f"request {req.uid}: {sorted(self.engine_kwargs)} are "
                "not pagerank engine knobs (the dense fixed-iteration "
                "engine takes only damping= / pagerank_iters=)"
            )
        if req.sources is not None:
            raise ValueError(
                f"request {req.uid}: sources is an sssp-only field "
                "(pagerank scores every node)"
            )
        if req.weights is None:
            w = np.ones(req.num_edges, np.float32)  # unit weights
        else:
            w = np.asarray(req.weights, np.float32).ravel()
        if w.shape != req.src.shape:
            raise ValueError(
                f"request {req.uid}: weights length {w.shape} != edge "
                f"count {req.src.shape}"
            )
        if req.num_edges and (not np.isfinite(w).all() or bool((w < 0).any())):
            raise ValueError(
                f"request {req.uid}: pagerank weights must be finite "
                "and >= 0"
            )
        req.weights = w

    def _next_wave(self) -> list[GraphRequest]:
        """FIFO greedy packing under the node/edge budget (the
        degradation caps, when an OOM has lowered them). A wave stays
        within one packing FAMILY (cc-chain vs sssp): the families run
        different device programs, so mixing them would force both
        into one wave's single batched call. FIFO order is preserved
        inside the wave; a family boundary closes the wave (no
        reordering past it, so completion order stays deterministic)."""
        wave: list[GraphRequest] = []
        nodes = edges = 0
        while self.queue and len(wave) < self.max_requests:
            r = self.queue[0]
            if wave and _family(r.kind) != _family(wave[0].kind):
                break
            if wave and (
                nodes + r.num_nodes > self._node_budget
                or edges + r.num_edges > self._edge_budget
            ):
                break
            wave.append(self.queue.pop(0))
            nodes += r.num_nodes
            edges += r.num_edges
        return wave

    def _wave_caps(self, wave: list[GraphRequest]) -> tuple[int, int]:
        """The capacity bucket a wave maps to (same math as _run_wave)."""
        n_union = sum(r.num_nodes for r in wave)
        m_union = sum(r.num_edges for r in wave)
        node_cap = max(self.min_nodes, next_pow2(n_union))
        edge_cap = max(self.min_edges, next_pow2(max(m_union, 1)))
        return node_cap, edge_cap

    def _degrade(
        self, wave: list[GraphRequest], exc: Exception
    ) -> list[list[GraphRequest]] | None:
        """OOM-shaped failure: permanently cap the packing budget to
        half the failing bucket and re-pack this wave under it. A
        singleton wave cannot shrink (its own bucket IS its size), so
        it returns None and quarantines; lone requests larger than the
        capped budget become singleton sub-waves and meet the same
        fate if they still exhaust the device."""
        if len(wave) == 1:
            return None
        node_cap, edge_cap = self._wave_caps(wave)
        self._node_budget = min(
            self._node_budget, max(self.min_nodes, node_cap // 2)
        )
        self._edge_budget = min(
            self._edge_budget, max(self.min_edges, edge_cap // 2)
        )
        subs: list[list[GraphRequest]] = []
        cur: list[GraphRequest] = []
        nodes = edges = 0
        for r in wave:
            if cur and (
                nodes + r.num_nodes > self._node_budget
                or edges + r.num_edges > self._edge_budget
            ):
                subs.append(cur)
                cur, nodes, edges = [], 0, 0
            cur.append(r)
            nodes += r.num_nodes
            edges += r.num_edges
        if cur:
            subs.append(cur)
        if len(subs) == 1:  # budget already below the floor: halve by count
            mid = len(wave) // 2
            subs = [wave[:mid], wave[mid:]]
        return subs

    def _run_wave(self, wave: list[GraphRequest]):
        from repro.core import connected_components
        from repro.trees import spanning_forest, tree_analytics

        if self.fault_plan is not None:
            self.fault_plan.check_wave(wave)

        if wave[0].kind == "sssp":  # family-pure by _next_wave
            return self._run_sssp_wave(wave)
        if wave[0].kind == "pagerank":
            return self._run_pagerank_wave(wave)

        stage = KINDS[max(_STAGE[r.kind] for r in wave)]
        node_off = np.cumsum([0] + [r.num_nodes for r in wave])
        n_union = int(node_off[-1])
        m_union = sum(r.num_edges for r in wave)
        node_cap = max(self.min_nodes, next_pow2(n_union))
        edge_cap = max(self.min_edges, next_pow2(max(m_union, 1)))
        if self.fault_plan is not None:
            self.fault_plan.check_bucket(node_cap)
        with trace.span(
            "serve.wave.pack", requests=len(wave), stage=stage,
            node_cap=node_cap, edge_cap=edge_cap,
        ):
            src = np.zeros((edge_cap,), np.int32)  # pad: inert self-loops
            dst = np.zeros((edge_cap,), np.int32)
            eo = 0
            for r, o in zip(wave, node_off):
                src[eo:eo + r.num_edges] = r.src + o
                dst[eo:eo + r.num_edges] = r.dst + o
                eo += r.num_edges

        bucket = (stage, node_cap, edge_cap)
        new_bucket = bucket not in self._buckets

        kw = dict(
            self.engine_kwargs, engine=self.engine, mesh=self.mesh,
            dedup=False,
        )
        if self.fault_plan is not None and self.fault_plan.wants_nonconverge(
            wave
        ):
            # Remove the round budget so the core engines' REAL
            # ConvergenceError sentinel fires for this wave.
            kw["max_rounds"] = 0
        # The engine span covers the batched device program AND the
        # np.asarray materializations -- those reads are the wave's
        # existing host sync, so the span closes on an already-synced
        # boundary (no block_on needed).
        with trace.span(
            "serve.wave.engine", stage=stage, requests=len(wave),
            node_cap=node_cap, edge_cap=edge_cap, new_bucket=new_bucket,
        ) as esp:
            extras = None
            if stage == "cc":
                labels, rounds = connected_components(
                    src, dst, node_cap, **kw
                )
                labels = np.asarray(labels)
                edge_u = edge_v = None
            elif stage == "forest":
                forest = spanning_forest(src, dst, node_cap, **kw)
                labels, rounds = forest.labels, forest.rounds
                edge_u, edge_v = forest.edge_u, forest.edge_v
            else:
                ta = tree_analytics(
                    src, dst, node_cap,
                    rank_engine=self.rank_engine,
                    kernel_impl=self.kernel_impl,
                    num_splitters=self.num_splitters,
                    pad_edges_to=node_cap,
                    **kw,
                )
                labels, rounds = ta.forest.labels, ta.forest.rounds
                edge_u, edge_v = ta.forest.edge_u, ta.forest.edge_v
                extras = (
                    np.asarray(ta.parent),
                    np.asarray(ta.depth),
                    np.asarray(ta.subtree_size),
                    np.asarray(ta.computations.preorder),
                    np.asarray(ta.computations.postorder),
                )
            labels = np.asarray(labels)
            esp.tag(rounds=int(rounds))

        with trace.span("serve.wave.unpack", requests=len(wave)):
            self._unpack(wave, node_off, labels, edge_u, edge_v, extras)

        # Bucket accounting only for waves that ran to completion: a
        # wave that failed above (injected fault, OOM, engine error)
        # never instantiated the bucket's compiled programs.
        self._buckets.add(bucket)
        rec = WaveRecord(
            requests=len(wave), stage=stage,
            num_nodes=n_union, num_edges=m_union,
            node_cap=node_cap, edge_cap=edge_cap,
            new_bucket=new_bucket, rounds=int(rounds),
        )
        self.wave_records.append(rec)
        rec.publish(self.metrics)

    def _run_sssp_wave(self, wave: list[GraphRequest]):
        """The sssp-family wave: one batched multi-source
        ``shortest_paths`` call over the disjoint union. Every
        request's sources become rows of the packed distance array
        (offset-shifted), padded to a ``src_cap`` power-of-two row
        count; pad edges are +inf-weight self-loops (inert under
        relax-min, never parents) and pad source rows target a pad
        node when one exists (an isolated node: the row converges
        immediately). Disjoint union ⇒ request i's rows are its solo
        rows bit-exactly: no finite-weight path crosses an offset
        boundary, so other requests' columns stay +inf / -1 and are
        sliced away at unpack. ``fault_plan.check_wave`` already ran
        in ``_run_wave``."""
        from repro.core import shortest_paths

        stage = "sssp"
        node_off = np.cumsum([0] + [r.num_nodes for r in wave])
        n_union = int(node_off[-1])
        m_union = sum(r.num_edges for r in wave)
        node_cap = max(self.min_nodes, next_pow2(n_union))
        edge_cap = max(self.min_edges, next_pow2(max(m_union, 1)))
        row_off = np.cumsum([0] + [len(r.sources) for r in wave])
        src_cap = next_pow2(int(row_off[-1]))
        if self.fault_plan is not None:
            self.fault_plan.check_bucket(node_cap)
        with trace.span(
            "serve.wave.pack", requests=len(wave), stage=stage,
            node_cap=node_cap, edge_cap=edge_cap, src_cap=src_cap,
        ):
            src = np.zeros((edge_cap,), np.int32)  # pad: self-loops...
            dst = np.zeros((edge_cap,), np.int32)
            wts = np.full((edge_cap,), np.inf, np.float32)  # ...at +inf
            pad_src = n_union if n_union < node_cap else 0
            srcs = np.full((src_cap,), pad_src, np.int32)
            eo = 0
            for r, o, ro in zip(wave, node_off, row_off):
                src[eo:eo + r.num_edges] = r.src + o
                dst[eo:eo + r.num_edges] = r.dst + o
                wts[eo:eo + r.num_edges] = r.weights
                eo += r.num_edges
                srcs[ro:ro + len(r.sources)] = r.sources + o

        bucket = (stage, node_cap, edge_cap, src_cap)
        new_bucket = bucket not in self._buckets

        # "auto" resolves to "dense" for the same reason as CC serving:
        # the frontier ladder's data-dependent inner buckets would break
        # the wave's compile-count guarantee. A pinned "frontier" is
        # honoured (bit-exact; bounded ladder compiles per bucket).
        engine = "frontier" if self.engine == "frontier" else "dense"
        kw = dict(self.engine_kwargs)  # only min_bucket= survives submit
        if engine != "frontier":
            kw.pop("min_bucket", None)
        if self.fault_plan is not None and self.fault_plan.wants_nonconverge(
            wave
        ):
            kw["max_rounds"] = 0  # fire the REAL relax-bound sentinel
        with trace.span(
            "serve.wave.engine", stage=stage, requests=len(wave),
            node_cap=node_cap, edge_cap=edge_cap, src_cap=src_cap,
            new_bucket=new_bucket, engine=engine,
        ) as esp:
            dist, pred, rounds = shortest_paths(
                src, dst, wts, node_cap, sources=srcs, engine=engine, **kw
            )
            dist = np.asarray(dist)
            pred = np.asarray(pred)
            esp.tag(rounds=int(rounds))

        with trace.span("serve.wave.unpack", requests=len(wave)):
            for r, o, ro in zip(wave, node_off, row_off):
                hi = o + r.num_nodes
                p = pred[ro:ro + len(r.sources), o:hi]
                r.result = GraphResult(
                    dist=dist[ro:ro + len(r.sources), o:hi],
                    # unreachable stays -1; reachable parents shift back
                    pred=np.where(p >= 0, p - o, -1).astype(np.int32),
                    sources=r.sources.copy(),
                )
                r.done = True

        self._buckets.add(bucket)
        rec = WaveRecord(
            requests=len(wave), stage=stage,
            num_nodes=n_union, num_edges=m_union,
            node_cap=node_cap, edge_cap=edge_cap,
            new_bucket=new_bucket, rounds=int(rounds), src_cap=src_cap,
        )
        self.wave_records.append(rec)
        rec.publish(self.metrics)

    def _run_pagerank_wave(self, wave: list[GraphRequest]):
        """The pagerank-family wave: one dense fixed-iteration
        ``pagerank`` call over the disjoint union. Each request keeps
        its SOLO teleport vector in its node slice (``1/n_i`` uniform
        mass -- the same float64-literal rounding the solo default
        uses), pad nodes get teleport 0, and pad edges are
        weight-0.0 self-loops: they push zero mass and add zero
        degree, and ``x + 0.0f == x`` bitwise for the non-negative
        scores/degrees PageRank produces. Mass never crosses an
        offset boundary in a disjoint union and the packed edge-slot
        order restricted to one request is its solo order (forward
        arcs then backward arcs, pads between them contributing
        +0.0), so the deterministic scatter-add accumulates each
        node's mass in exactly its solo sequence: every unpacked
        ``scores`` slice is bit-identical to the solo dense run at
        ``pagerank_iters`` iterations (asserted in
        ``tests/test_serve_graph.py``). ``fault_plan.check_wave``
        already ran in ``_run_wave``."""
        from repro.core.pagerank import pagerank

        stage = "pagerank"
        node_off = np.cumsum([0] + [r.num_nodes for r in wave])
        n_union = int(node_off[-1])
        m_union = sum(r.num_edges for r in wave)
        node_cap = max(self.min_nodes, next_pow2(n_union))
        edge_cap = max(self.min_edges, next_pow2(max(m_union, 1)))
        if self.fault_plan is not None:
            self.fault_plan.check_bucket(node_cap)
        with trace.span(
            "serve.wave.pack", requests=len(wave), stage=stage,
            node_cap=node_cap, edge_cap=edge_cap,
        ):
            src = np.zeros((edge_cap,), np.int32)  # pad: self-loops...
            dst = np.zeros((edge_cap,), np.int32)
            wts = np.zeros((edge_cap,), np.float32)  # ...of weight 0
            tel = np.zeros((node_cap,), np.float32)
            eo = 0
            for r, o in zip(wave, node_off):
                src[eo:eo + r.num_edges] = r.src + o
                dst[eo:eo + r.num_edges] = r.dst + o
                wts[eo:eo + r.num_edges] = r.weights
                eo += r.num_edges
                tel[o:o + r.num_nodes] = np.full(
                    r.num_nodes, 1.0 / r.num_nodes, np.float32
                )

        bucket = (stage, node_cap, edge_cap)
        new_bucket = bucket not in self._buckets

        kw = {}
        if self.fault_plan is not None and self.fault_plan.wants_nonconverge(
            wave
        ):
            # Cap the iteration budget below the fixed count so the
            # dense engine's REAL ConvergenceError sentinel fires.
            kw["max_rounds"] = 0
        with trace.span(
            "serve.wave.engine", stage=stage, requests=len(wave),
            node_cap=node_cap, edge_cap=edge_cap, new_bucket=new_bucket,
            engine="dense",
        ) as esp:
            scores, iters = pagerank(
                src, dst, wts, node_cap,
                damping=self.damping, teleport=tel,
                num_iters=self.pagerank_iters, engine="dense", **kw,
            )
            scores = np.asarray(scores)
            esp.tag(rounds=int(iters))

        with trace.span("serve.wave.unpack", requests=len(wave)):
            for r, o in zip(wave, node_off):
                r.result = GraphResult(
                    scores=scores[o:o + r.num_nodes].copy()
                )
                r.done = True

        self._buckets.add(bucket)
        rec = WaveRecord(
            requests=len(wave), stage=stage,
            num_nodes=n_union, num_edges=m_union,
            node_cap=node_cap, edge_cap=edge_cap,
            new_bucket=new_bucket, rounds=int(iters),
        )
        self.wave_records.append(rec)
        rec.publish(self.metrics)

    def _unpack(self, wave, node_off, labels, edge_u, edge_v, extras):
        """Slice the packed union's outputs back to request-local ids."""
        from repro.core import num_components

        if extras is not None:
            parent, depth, size, pre, post = extras
        for r, o in zip(wave, node_off):
            hi = o + r.num_nodes
            lab = labels[o:hi] - o
            res = GraphResult(
                labels=lab.astype(np.int32),
                num_components=num_components(lab),
            )
            # fill only the fields the request's OWN kind asked for --
            # stage promotion must not leak wave-mate-dependent extras
            if edge_u is not None and _STAGE[r.kind] >= _STAGE["forest"]:
                # request i's forest edges are the hook slots of its own
                # node range, already in solo (hooked-tree id) order
                m = (edge_u >= o) & (edge_u < hi)
                res.edge_u = (edge_u[m] - o).astype(np.int32)
                res.edge_v = (edge_v[m] - o).astype(np.int32)
            if extras is not None and r.kind == "analytics":
                res.parent = (parent[o:hi] - o).astype(np.int32)
                res.depth = depth[o:hi]
                res.subtree_size = size[o:hi]
                res.preorder = pre[o:hi]
                res.postorder = post[o:hi]
            r.result = res
            r.done = True

    def run(self) -> list[GraphRequest]:
        """Process the whole queue; returns the requests that reached a
        terminal state during THIS call, in completion order:
        ``result`` populated (``done``) or quarantined (``failed`` with
        ``error`` set; only under injected/real faults -- see
        ``docs/serving.md``)."""
        return super().run()
