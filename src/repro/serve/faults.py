"""Deterministic fault injection for the serving stack.

The containment machinery in ``serve/waves.py`` (quarantine +
bisection, bounded retry, graceful degradation) is only trustworthy if
every path is exercised deterministically -- waiting for a real XLA
OOM or a real invariant break in CI would test nothing. A ``FaultPlan``
is a seeded, fully deterministic description of which faults to inject
where; both serving engines accept one (``fault_plan=``) behind a
no-op default, consult it at the few natural failure points, and raise
ordinary exceptions that then flow through the SAME classification /
bisection / degradation code real failures do:

* **poison** (``poison_uids``): an ``InjectedEngineError`` whenever a
  wave contains the uid -- the "request that trips an invariant only
  when packed" case; bisection must isolate exactly this request.
* **transient** (``transient_uids``: uid -> failure count): a
  ``TransientFault`` for the first N attempts of any wave containing
  the uid, success afterwards -- exercises the bounded retry policy.
* **simulated OOM** (``oom_node_caps`` for graph buckets,
  ``oom_slots_at`` for the LM cache width): a ``SimulatedOOM`` that is
  resource-exhaustion-shaped, so the scheduler degrades (caps the
  bucket, re-packs smaller waves) instead of quarantining.
* **non-convergence** (``nonconverge_uids``): the graph engine forces
  ``max_rounds=0`` on waves containing the uid, so the REAL
  ``ConvergenceError`` sentinel in the core engines fires -- nothing
  here fakes the error; the injection only removes the round budget.
* **malformed submits** (``malformed_uids`` + ``malform``): a
  test-stream-side corruption helper; the engines' ``submit``
  validation must reject the request loudly before it ever reaches a
  wave (the containment layer never sees it).

Classification (``classify_failure`` / ``is_resource_exhausted``)
covers real failures too: any ``MemoryError`` or an error message
carrying XLA's ``RESOURCE_EXHAUSTED`` marker degrades; everything else
non-transient is poison.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


class InjectedFault(RuntimeError):
    """Base class for every fault the harness raises on purpose."""


class InjectedEngineError(InjectedFault):
    """Deterministic poison: raised whenever a wave contains the uid."""


class TransientFault(InjectedFault):
    """Clears after a bounded number of retries of the same request."""


class SimulatedOOM(InjectedFault, MemoryError):
    """Resource-exhaustion-shaped: classified like a real XLA OOM."""


# Substrings that mark a real resource-exhaustion failure. XLA raises
# XlaRuntimeError("RESOURCE_EXHAUSTED: ...") on device OOM.
_RESOURCE_MARKERS = ("RESOURCE_EXHAUSTED", "out of memory")


def is_resource_exhausted(exc: BaseException) -> bool:
    """OOM-shaped? (simulated, MemoryError, or an XLA OOM message)."""
    if isinstance(exc, MemoryError):
        return True
    msg = str(exc)
    return any(marker in msg for marker in _RESOURCE_MARKERS)


def classify_failure(exc: BaseException) -> str:
    """``"transient"`` | ``"resource"`` | ``"poison"``.

    Transient failures are retried in place (bounded by
    ``max_retries``); resource failures degrade (smaller waves);
    everything else is poison and gets bisected out.
    """
    if isinstance(exc, TransientFault):
        return "transient"
    if is_resource_exhausted(exc):
        return "resource"
    return "poison"


@dataclass
class FaultPlan:
    """A deterministic injection schedule. Default-constructed (or
    ``None``) injects nothing -- the no-op default both engines ship
    with. ``transient_uids`` is the plan's only mutable state: each
    injected transient failure decrements its counter, so a plan
    instance describes one engine run (build a fresh plan per engine).
    """

    poison_uids: frozenset = frozenset()
    transient_uids: dict = field(default_factory=dict)  # uid -> failures
    oom_node_caps: frozenset = frozenset()  # graph bucket node_caps
    oom_slots_at: int | None = None  # LM: OOM when num_slots >= this
    nonconverge_uids: frozenset = frozenset()  # graph: force max_rounds=0
    malformed_uids: frozenset = frozenset()  # corrupted before submit

    @classmethod
    def random(
        cls,
        seed: int,
        uids,
        *,
        p_poison: float = 0.1,
        p_transient: float = 0.1,
        max_transient: int = 1,
        p_nonconverge: float = 0.0,
    ) -> "FaultPlan":
        """Seeded random plan over ``uids`` -- same seed, same plan."""
        rng = np.random.default_rng(seed)
        uids = list(uids)
        draws = rng.random((len(uids), 3))
        poison, transient, nonconv = set(), {}, set()
        for uid, (a, b, c) in zip(uids, draws):
            if a < p_poison:
                poison.add(uid)
            elif c < p_nonconverge:
                nonconv.add(uid)
            elif b < p_transient:
                transient[uid] = int(rng.integers(1, max_transient + 1))
        return cls(
            poison_uids=frozenset(poison),
            transient_uids=transient,
            nonconverge_uids=frozenset(nonconv),
        )

    # -- engine-side checkpoints ------------------------------------
    def check_wave(self, wave) -> None:
        """Top of ``_run_wave``: transient (counted) then poison."""
        for r in wave:
            left = self.transient_uids.get(r.uid, 0)
            if left > 0:
                self.transient_uids[r.uid] = left - 1
                raise TransientFault(
                    f"injected transient fault (request {r.uid}, "
                    f"{left - 1} failures left)"
                )
        poisoned = [r.uid for r in wave if r.uid in self.poison_uids]
        if poisoned:
            raise InjectedEngineError(
                f"injected engine error (poison uids {poisoned})"
            )

    def check_bucket(self, node_cap: int) -> None:
        """Graph engine, after the wave's capacity bucket is chosen."""
        if node_cap in self.oom_node_caps:
            raise SimulatedOOM(
                "injected RESOURCE_EXHAUSTED on bucket "
                f"node_cap={node_cap}"
            )

    def check_slots(self, num_slots: int) -> None:
        """LM engine, before the (num_slots, max_len) cache allocates."""
        if self.oom_slots_at is not None and num_slots >= self.oom_slots_at:
            raise SimulatedOOM(
                "injected RESOURCE_EXHAUSTED on KV cache width "
                f"num_slots={num_slots}"
            )

    def wants_nonconverge(self, wave) -> bool:
        return any(r.uid in self.nonconverge_uids for r in wave)

    # -- test-stream-side helper -------------------------------------
    def malform(self, req):
        """Corrupt a graph request so ``submit`` must reject it (edge
        endpoint outside ``[0, num_nodes)``). Returns the request."""
        bad = np.asarray([req.num_nodes + 7], np.int32)
        req.src = np.concatenate([np.asarray(req.src, np.int32), bad])
        req.dst = np.concatenate([np.asarray(req.dst, np.int32), bad])
        return req
