"""Shared wave/slot machinery for the serving engines.

Both serving engines — the LM token engine (``serve/engine.py``) and the
graph-analytics engine (``serve/graph.py``) — run the same outer loop:
requests queue up, a WAVE of them is admitted under a static capacity,
the whole wave runs as one shape-static batched device program, and the
wave retires together (the branch-free analogue of the paper's lockstep
walk: all lanes step together, finished lanes burn no semantics). This
module owns that loop so the two engines only differ in (a) how a wave
is formed under their capacity model and (b) what running a wave means.

Subclasses implement:

* ``_next_wave()`` — pop the next wave off ``self.queue`` (FIFO; a
  subclass may stop early when its capacity budget fills, but must make
  progress whenever the queue is nonempty);
* ``_run_wave(wave)`` — execute the wave and write per-request results
  onto the request objects (``done`` flags included);
* ``_degrade(wave, exc)`` (optional) — given a resource-exhausted wave,
  permanently shrink the engine's capacity and return smaller re-packed
  sub-waves (None = cannot degrade further).

``submit`` is overridable for admission-time validation — the one place
a request can be rejected loudly instead of being silently dropped by
an exhausted wave loop later.

**Fault containment.** A ``_run_wave`` failure never escapes ``run()``
under the default ``on_failure="quarantine"`` policy; see
``docs/serving.md`` for the full model. In short:

* **transient** failures (``serve/faults.classify_failure``) re-run the
  same wave up to ``max_retries`` times;
* **resource-exhaustion** (OOM-shaped) failures degrade: the subclass
  permanently caps its capacity and the wave re-packs into smaller
  sub-waves (``_degrade``) — requests only fail when a single request
  alone still exhausts the device;
* everything else is **poison** and is bisected out: probe one half
  (one wave run); a failing probe provably still contains a poison, a
  passing probe proves the poison is in the other half — so ceil(log2
  K) probes isolate it, the deferred "presumed healthy" siblings re-run
  together as one wave, and a single poison in a K-request wave costs
  at most ceil(log2 K) + 1 extra wave runs while every survivor
  completes bit-exact (subsets of a wave decompose exactly on both
  engines).

Each ``run()`` call appends a ``HealthRecord`` whose counters are
deterministic under a deterministic ``FaultPlan`` (guarded by
``benchmarks/run.py --check`` like the wave counters), returns ONLY the
requests that reached a terminal state during THIS call (``done`` or
``failed`` — never re-delivering an earlier run's results), and frees
their uids for reuse.

``on_failure="raise"`` restores fail-fast: the first ``_run_wave``
error propagates (no retry, no bisection, no degradation).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.components import check_choice
from repro.obs import trace
from repro.obs.metrics import Registry
from repro.serve.faults import classify_failure, is_resource_exhausted

FAILURE_POLICIES = ("quarantine", "raise")


@dataclass
class HealthRecord:
    """Per-``run()`` containment counters (deterministic under a
    deterministic fault plan; guarded like the wave counters).

    ``wave_runs`` counts every ``_run_wave`` attempt (success or
    failure) including retries, bisection probes, and degraded
    re-packs; ``completed``/``failed`` partition the requests the run
    delivered; ``quarantined`` counts requests isolated as poison
    (== ``failed`` unless a subclass fails requests another way);
    ``retried`` counts transient re-runs, ``bisections`` poison-hunt
    episodes, and ``degraded`` capacity-capping events."""

    run: int
    completed: int = 0
    failed: int = 0
    retried: int = 0
    quarantined: int = 0
    degraded: int = 0
    bisections: int = 0
    wave_runs: int = 0

    def publish(self, registry=None, prefix: str = "serve.health") -> None:
        """Publish the counters (``run`` excluded -- it is an id, not a
        quantity) into the metrics registry (``repro.obs.metrics``)."""
        from repro.obs.metrics import publish_stats

        publish_stats(self, prefix, registry, exclude=("run",))


class WaveScheduler:
    """Queue -> waves -> finished, with fault containment and a
    per-run wave counter."""

    def __init__(
        self,
        *,
        max_retries: int = 1,
        on_failure: str = "quarantine",
        fault_plan=None,
    ):
        check_choice("on_failure", on_failure, FAILURE_POLICIES)
        self.queue: list = []
        self.finished: list = []
        self.waves = 0
        self.max_retries = max_retries
        self.on_failure = on_failure
        self.fault_plan = fault_plan
        self.health_records: list[HealthRecord] = []
        self.health: HealthRecord | None = None
        self._delivered = 0  # prefix of self.finished already returned
        self._inflight: set = set()  # uids submitted but not delivered
        # Per-engine registry (NOT the process-global one): each run()
        # publishes its HealthRecord and subclasses publish their wave
        # records here, so an engine's snapshot() is a deterministic
        # function of its own request stream + fault plan alone.
        self.metrics = Registry()

    # -- admission ----------------------------------------------------
    def submit(self, req) -> None:
        """Admit a request to the queue. Subclasses validate here."""
        self._register(req)
        self.queue.append(req)

    def _register(self, req) -> None:
        """Claim the request's uid (results and health records are
        keyed by uid; duplicates would alias silently). Subclass
        ``submit`` paths that bypass the queue register here too."""
        uid = getattr(req, "uid", None)
        if uid is None:
            return
        if uid in self._inflight:
            raise ValueError(
                f"request {uid}: uid already in flight; wait for run() "
                "to deliver it or pick a fresh uid"
            )
        self._inflight.add(uid)

    def _next_wave(self) -> list:
        """Pop the next wave (nonempty while the queue is) off the queue."""
        raise NotImplementedError

    def _run_wave(self, wave: list) -> None:
        raise NotImplementedError

    # -- containment ----------------------------------------------------
    def _attempt(self, wave: list) -> Exception | None:
        """Run a wave with bounded transient retries. Returns None on
        success (the wave is retired) or the terminal exception."""
        retries = 0
        while True:
            self.health.wave_runs += 1
            # First attempt is a "serve.wave" span, re-runs are
            # "serve.retry" child attempts; a failing attempt carries
            # its failure classification as a span tag.
            name = "serve.wave" if retries == 0 else "serve.retry"
            with trace.span(name, requests=len(wave), retry=retries) as sp:
                try:
                    self._run_wave(wave)
                except Exception as exc:
                    if self.on_failure == "raise":
                        raise
                    failure = classify_failure(exc)
                    sp.tag(failure=failure, error=type(exc).__name__)
                    if failure == "transient" and retries < self.max_retries:
                        retries += 1
                        self.health.retried += 1
                        continue
                    return exc
            self.finished.extend(wave)
            self.waves += 1
            return None

    def _process_wave(self, wave: list) -> None:
        """Retire a wave through retry -> degrade -> bisect."""
        exc = self._attempt(wave)
        if exc is None:
            return
        if is_resource_exhausted(exc):
            subs = self._degrade(wave, exc)
            if subs is not None:
                self.health.degraded += 1
                with trace.span(
                    "serve.degrade", requests=len(wave), subs=len(subs),
                    failure=classify_failure(exc),
                ):
                    for sub in subs:
                        self._process_wave(sub)
                return
        if len(wave) == 1:
            self._quarantine(wave[0], exc)
            return
        self._bisect(wave, exc)

    def _bisect(self, wave: list, exc: Exception) -> None:
        """Isolate the poison request(s) of a failed multi-request wave.

        Invariant: ``suspect`` provably contains a poison (a wave fails
        iff it contains one, and failures are deterministic). Probing
        the first half either shrinks ``suspect`` to it (probe failed)
        or proves the poison is in the other half (probe passed and
        retired). The singleton left after ceil(log2 K) probes is
        quarantined WITHOUT a solo run — guilt by the invariant — and
        the deferred siblings re-run as one wave (recursing here if
        they hide another poison)."""
        self.health.bisections += 1
        suspect, stash = list(wave), []
        with trace.span(
            "serve.bisect", suspects=len(wave),
            failure=classify_failure(exc),
        ) as bsp:
            while len(suspect) > 1:
                mid = len(suspect) // 2
                probe, rest = suspect[:mid], suspect[mid:]
                with trace.span("serve.bisect.probe", size=len(probe)):
                    e = self._attempt(probe)
                if e is None:
                    suspect = rest
                else:
                    suspect, exc = probe, e
                    stash = rest + stash
            bsp.tag(isolated=getattr(suspect[0], "uid", None))
        self._quarantine(suspect[0], exc)
        if stash:
            self._process_wave(stash)

    def _degrade(self, wave: list, exc: Exception) -> list | None:
        """Hook: permanently shrink capacity after an OOM-shaped
        failure and return re-packed sub-waves, or None if this wave
        cannot run any smaller (base: no capacity model to shrink)."""
        return None

    def _quarantine(self, req, exc: Exception) -> None:
        """Terminal failure: deliver the request as ``failed`` with the
        captured error instead of stranding it in the queue."""
        req.failed = True
        req.error = f"{type(exc).__name__}: {exc}"
        self.health.quarantined += 1
        self.finished.append(req)
        trace.event(
            "serve.quarantine", uid=getattr(req, "uid", None),
            failure=classify_failure(exc), error=type(exc).__name__,
        )

    # -- the outer loop -------------------------------------------------
    def run(self) -> list:
        """Process the whole queue; returns the requests that reached a
        terminal state (``done`` or ``failed``) during THIS call, in
        completion order (requests finished at submit time first).
        Earlier runs' deliveries are never returned again."""
        self.health = HealthRecord(run=len(self.health_records))
        self.health_records.append(self.health)
        with trace.span(
            "serve.run", run=self.health.run, queued=len(self.queue),
        ) as sp:
            while self.queue:
                wave = self._next_wave()
                if not wave:  # defensive: a stuck _next_wave would spin
                    raise RuntimeError("_next_wave returned an empty wave")
                self._process_wave(wave)
            new = self.finished[self._delivered:]
            self._delivered = len(self.finished)
            for r in new:
                self._inflight.discard(getattr(r, "uid", None))
                if getattr(r, "failed", False):
                    self.health.failed += 1
                else:
                    self.health.completed += 1
            sp.tag(
                completed=self.health.completed, failed=self.health.failed,
                wave_runs=self.health.wave_runs,
            )
        # One publish per run(): the containment counters land in the
        # engine's own registry under serve.health.* (the unified
        # namespace benchmarks/run.py --check pins).
        self.health.publish(self.metrics)
        return new
