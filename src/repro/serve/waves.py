"""Shared wave/slot machinery for the serving engines.

Both serving engines — the LM token engine (``serve/engine.py``) and the
graph-analytics engine (``serve/graph.py``) — run the same outer loop:
requests queue up, a WAVE of them is admitted under a static capacity,
the whole wave runs as one shape-static batched device program, and the
wave retires together (the branch-free analogue of the paper's lockstep
walk: all lanes step together, finished lanes burn no semantics). This
module owns that loop so the two engines only differ in (a) how a wave
is formed under their capacity model and (b) what running a wave means.

Subclasses implement:

* ``_next_wave()`` — pop the next wave off ``self.queue`` (FIFO; a
  subclass may stop early when its capacity budget fills, but must make
  progress whenever the queue is nonempty);
* ``_run_wave(wave)`` — execute the wave and write per-request results
  onto the request objects (``done`` flags included).

``submit`` is overridable for admission-time validation — the one place
a request can be rejected loudly instead of being silently dropped by
an exhausted wave loop later.
"""
from __future__ import annotations


class WaveScheduler:
    """Queue -> waves -> finished, with a per-run wave counter."""

    def __init__(self):
        self.queue: list = []
        self.finished: list = []
        self.waves = 0

    def submit(self, req) -> None:
        """Admit a request to the queue. Subclasses validate here."""
        self.queue.append(req)

    def _next_wave(self) -> list:
        """Pop the next wave (nonempty while the queue is) off the queue."""
        raise NotImplementedError

    def _run_wave(self, wave: list) -> None:
        raise NotImplementedError

    def run(self) -> list:
        """Process the whole queue; returns finished requests in
        completion order (requests finished at submit time first)."""
        while self.queue:
            wave = self._next_wave()
            if not wave:  # defensive: a stuck _next_wave would spin
                raise RuntimeError("_next_wave returned an empty wave")
            self._run_wave(wave)
            self.finished.extend(wave)
            self.waves += 1
        return self.finished
