"""CSR neighbor sampler for sampled GNN training (minibatch_lg shape).

A real fanout sampler, not a stub: host-side numpy over CSR, emitting fixed
(fanout-padded) neighbor blocks so the device graph is static-shaped. Padding
uses self-loops so downstream segment reductions stay branch-free
(guideline G3): a padded edge contributes the node's own feature which is
then removed by subtracting the known pad count -- or simply kept for mean
aggregators, matching GraphSAGE's with-replacement sampling semantics.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.ops.kiss import KissRng


def edges_to_csr(edges: np.ndarray, num_nodes: int) -> tuple[np.ndarray, np.ndarray]:
    """Symmetrized CSR (indptr, indices) from an (m,2) edge list."""
    src = np.concatenate([edges[:, 0], edges[:, 1]])
    dst = np.concatenate([edges[:, 1], edges[:, 0]])
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, dst.astype(np.int32)


@dataclass
class SampledBlock:
    """One hop of sampled neighborhood.

    dst_nodes: (b,) destination node ids for this hop.
    src_nodes: (b * fanout,) sampled neighbor ids (with replacement; isolated
        nodes fall back to self-loops).
    dst_index: (b * fanout,) position of each sampled edge's destination in
        dst_nodes -- i.e. the segment ids for the aggregation.
    """

    dst_nodes: np.ndarray
    src_nodes: np.ndarray
    dst_index: np.ndarray


class NeighborSampler:
    def __init__(self, indptr: np.ndarray, indices: np.ndarray, seed: int = 0):
        self.indptr = indptr
        self.indices = indices
        self._rng = KissRng(seed, n_streams=8192)

    def sample_hop(self, nodes: np.ndarray, fanout: int) -> SampledBlock:
        b = len(nodes)
        deg = (self.indptr[nodes + 1] - self.indptr[nodes]).astype(np.int64)
        draws = self._rng.uniform_ints((b, fanout), 1 << 31)
        # Uniform with replacement; degree-0 nodes become self-loops.
        safe_deg = np.maximum(deg, 1)
        offs = draws % safe_deg[:, None]
        gather = np.minimum(
            self.indptr[nodes][:, None] + offs, max(len(self.indices) - 1, 0)
        )
        src = (
            self.indices[gather]
            if len(self.indices)
            else np.broadcast_to(nodes[:, None], (b, fanout)).copy()
        )
        src = np.where(deg[:, None] == 0, nodes[:, None], src)
        dst_index = np.repeat(np.arange(b, dtype=np.int32), fanout)
        return SampledBlock(
            dst_nodes=nodes.astype(np.int32),
            src_nodes=src.reshape(-1).astype(np.int32),
            dst_index=dst_index,
        )

    def sample_multihop(
        self, seed_nodes: np.ndarray, fanouts: list[int]
    ) -> list[SampledBlock]:
        """GraphSAGE-style layered sampling: hop h expands hop h-1's sources."""
        blocks: list[SampledBlock] = []
        frontier = seed_nodes
        for fanout in fanouts:
            blk = self.sample_hop(frontier, fanout)
            blocks.append(blk)
            frontier = blk.src_nodes
        return blocks
