"""EmbeddingBag built from take + segment_sum (JAX has no native one).

The recsys hot path: multi-hot categorical features index huge embedding
tables. The lookup is exactly the paper's irregular-gather regime; the
bag-reduce is the concurrent-write phase, resolved by segment reduction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.ops.segment import segment_max, segment_mean, segment_sum

Array = jax.Array


def embedding_bag(
    table: Array,
    indices: Array,
    bag_ids: Array,
    num_bags: int,
    *,
    mode: str = "sum",
    weights: Array | None = None,
    indices_are_sorted: bool = False,
) -> Array:
    """Gather ``table[indices]`` and reduce rows sharing ``bag_ids``.

    Args:
        table: (vocab, dim) embedding table.
        indices: (nnz,) row indices into the table (flattened multi-hot).
        bag_ids: (nnz,) which output bag each index belongs to; padding
            entries should use ``bag_ids >= num_bags`` which XLA scatter
            drops, keeping the kernel branch-free (guideline G3).
        num_bags: number of output rows.
        mode: sum | mean | max.
        weights: optional (nnz,) per-sample weights (sum mode only).
    """
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        if mode != "sum":
            raise ValueError("per-sample weights require mode='sum'")
        rows = rows * weights[:, None]
    if mode == "sum":
        return segment_sum(
            rows, bag_ids, num_bags, indices_are_sorted=indices_are_sorted
        )
    if mode == "mean":
        return segment_mean(
            rows, bag_ids, num_bags, indices_are_sorted=indices_are_sorted
        )
    if mode == "max":
        out = segment_max(
            rows, bag_ids, num_bags, indices_are_sorted=indices_are_sorted
        )
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(f"unknown mode {mode!r}")


def multi_field_lookup(
    tables: list[Array],
    field_indices: Array,
) -> Array:
    """Dense one-index-per-field lookup (xDeepFM's 39 sparse fields).

    Args:
        tables: list of (vocab_f, dim) tables, one per field.
        field_indices: (batch, n_fields) int32.

    Returns:
        (batch, n_fields, dim) stacked field embeddings.
    """
    cols = [
        jnp.take(t, field_indices[:, f], axis=0) for f, t in enumerate(tables)
    ]
    return jnp.stack(cols, axis=1)
