"""Edge-index message passing: gather -> compute -> segment-reduce.

This is the GNN instantiation of the paper's irregular-access regime. JAX has
no sparse message-passing primitive (BCOO only), so per the assignment this
is built from ``jnp.take`` + ``jax.ops.segment_*``.

Guideline G1 (coalescing) appears as the ``sort_edges_by_dst`` preprocessing:
sorting the edge list by destination makes the scatter side of the reduction
contiguous, which turns the XLA scatter into (mostly) sequential accumulation
and lets the Pallas ``segment_sum`` kernel stream blocks.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.ops.segment import segment_max, segment_mean, segment_sum

Array = jax.Array

_REDUCERS: dict[str, Callable[..., Array]] = {
    "sum": segment_sum,
    "mean": segment_mean,
    "max": segment_max,
}


def sort_edges_by_dst(src: Array, dst: Array) -> tuple[Array, Array, Array]:
    """Sort the edge list by destination node (coalescing, guideline G1).

    Returns (src_sorted, dst_sorted, perm). perm can reorder edge features.
    """
    perm = jnp.argsort(dst)
    return src[perm], dst[perm], perm


def gather_messages(node_feats: Array, src: Array) -> Array:
    """Gather source-node features along edges (the irregular read)."""
    return jnp.take(node_feats, src, axis=0)


def scatter_reduce(
    messages: Array,
    dst: Array,
    num_nodes: int,
    *,
    reducer: str = "sum",
    indices_are_sorted: bool = False,
) -> Array:
    """Reduce edge messages into destination nodes (the irregular write)."""
    try:
        fn = _REDUCERS[reducer]
    except KeyError:
        raise ValueError(f"unknown reducer {reducer!r}") from None
    out = fn(
        messages, dst, num_nodes, indices_are_sorted=indices_are_sorted
    )
    if reducer == "max":
        # Isolated nodes produce -inf; zero them branch-free (guideline G3).
        out = jnp.where(jnp.isfinite(out), out, 0.0)
    return out


def mpnn_aggregate(
    node_feats: Array,
    src: Array,
    dst: Array,
    num_nodes: int,
    *,
    message_fn: Callable[[Array], Array] | None = None,
    edge_feats: Array | None = None,
    reducer: str = "sum",
    indices_are_sorted: bool = False,
) -> Array:
    """One message-passing sweep: h'_i = reduce_{j->i} msg(h_j [, e_ji])."""
    msgs = gather_messages(node_feats, src)
    if edge_feats is not None:
        msgs = jnp.concatenate([msgs, edge_feats], axis=-1)
    if message_fn is not None:
        msgs = message_fn(msgs)
    return scatter_reduce(
        msgs,
        dst,
        num_nodes,
        reducer=reducer,
        indices_are_sorted=indices_are_sorted,
    )
