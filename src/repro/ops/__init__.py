"""Irregular-access substrate shared by every model family.

This package is the generalization of the paper's PRAM->GPU guidelines to
TPU/JAX: segment reductions, packing layouts, edge-index message passing,
embedding bags, neighbor sampling, and sort-based dispatch ("coalescing at a
coarse grain").
"""
from repro.ops.segment import (
    segment_sum,
    segment_max,
    segment_min,
    segment_mean,
    segment_softmax,
    segment_count,
)
from repro.ops.packing import pack_aos, unpack_aos, pack_word64, unpack_word64
from repro.ops.scatter_gather import gather_messages, scatter_reduce, mpnn_aggregate
from repro.ops.embedding_bag import embedding_bag
from repro.ops.sorted_dispatch import sort_by_key, grouped_offsets
from repro.ops.kiss import KissRng, random_linked_list, random_graph, random_forest

__all__ = [
    "segment_sum",
    "segment_max",
    "segment_min",
    "segment_mean",
    "segment_softmax",
    "segment_count",
    "pack_aos",
    "unpack_aos",
    "pack_word64",
    "unpack_word64",
    "gather_messages",
    "scatter_reduce",
    "mpnn_aggregate",
    "embedding_bag",
    "sort_by_key",
    "grouped_offsets",
    "KissRng",
    "random_linked_list",
    "random_graph",
    "random_forest",
]
