"""Marsaglia-Zaman KISS random number generator (paper section 3.2).

The paper uses KISS both inside the GPU kernels (splitter selection) and to
generate all experimental inputs, because it needs only 32/64-bit integer
ops. We reproduce it exactly: a lag-1 multiply-with-carry pair + xorshift +
LCG, all uint32. A vectorized variant gives every "PRAM thread" its own
stream, as on the GPU.

Data generators for the paper's experiment families (random linked lists,
k-ary tree graphs, random graphs of density d, list graphs) live here too so
benchmarks and tests share one input distribution.
"""
from __future__ import annotations

import numpy as np

_M32 = np.uint64(0xFFFFFFFF)


class KissRng:
    """Scalar/vector KISS99 over numpy uint32 state.

    state per stream: (z, w, jsr, jcong). All arithmetic mod 2^32.
    """

    def __init__(self, seed: int, n_streams: int = 1):
        # Seed-expand with splitmix-style mixing so distinct seeds/streams
        # decorrelate; the generator itself is pure KISS.
        base = (int(seed) * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
        s = np.arange(n_streams, dtype=np.uint64) + np.uint64(base)
        def mix(x: np.ndarray, c: int) -> np.ndarray:
            x = (x + np.uint64(c)) & np.uint64(0xFFFFFFFFFFFFFFFF)
            x ^= x >> np.uint64(30)
            x = (x * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(
                0xFFFFFFFFFFFFFFFF
            )
            x ^= x >> np.uint64(27)
            return x

        self.z = ((mix(s, 1) & _M32) | np.uint64(1)).astype(np.uint32)
        self.w = ((mix(s, 2) & _M32) | np.uint64(1)).astype(np.uint32)
        self.jsr = ((mix(s, 3) & _M32) | np.uint64(1)).astype(np.uint32)
        self.jcong = (mix(s, 4) & _M32).astype(np.uint32)

    def next_u32(self) -> np.ndarray:
        """One KISS step per stream -> uint32 array of shape (n_streams,)."""
        with np.errstate(over="ignore"):
            z = self.z.astype(np.uint64)
            w = self.w.astype(np.uint64)
            z = (np.uint64(36969) * (z & np.uint64(65535)) + (z >> np.uint64(16)))
            w = (np.uint64(18000) * (w & np.uint64(65535)) + (w >> np.uint64(16)))
            self.z = (z & _M32).astype(np.uint32)
            self.w = (w & _M32).astype(np.uint32)
            mwc = ((z << np.uint64(16)) + w) & _M32

            jsr = self.jsr
            jsr = jsr ^ (jsr << np.uint32(17))
            jsr = jsr ^ (jsr >> np.uint32(13))
            jsr = jsr ^ (jsr << np.uint32(5))
            self.jsr = jsr

            jcong = (
                np.uint64(69069) * self.jcong.astype(np.uint64) + np.uint64(1234567)
            ) & _M32
            self.jcong = jcong.astype(np.uint32)

            return ((mwc ^ jcong) + jsr.astype(np.uint64) & _M32).astype(np.uint32)

    def uniform_ints(self, shape: tuple[int, ...], bound: int) -> np.ndarray:
        """Uniform ints in [0, bound) of the requested shape (row-major)."""
        total = int(np.prod(shape))
        n = self.z.shape[0]
        steps = -(-total // n)
        out = np.empty(steps * n, dtype=np.uint32)
        for i in range(steps):
            out[i * n : (i + 1) * n] = self.next_u32()
        return (out[:total] % np.uint32(bound)).astype(np.int64).reshape(shape)


# ---------------------------------------------------------------------------
# Experiment input families (paper sections 3.3 / 4).
# ---------------------------------------------------------------------------


def random_linked_list(n: int, seed: int = 0) -> np.ndarray:
    """succ[] for a random list over n nodes; node 0 is the head.

    Random order is derived from KISS keys (argsort), matching the paper's
    "completely random" lists whose traversal defeats coalescing. The last
    node satisfies succ[last] = last.
    """
    rng = KissRng(seed, n_streams=min(n, 8192))
    keys = rng.uniform_ints((n - 1,), 1 << 31) if n > 1 else np.empty(0)
    order = np.empty(n, dtype=np.int64)
    order[0] = 0
    if n > 1:
        rest = 1 + np.argsort(keys, kind="stable")
        order[1:] = rest
    succ = np.empty(n, dtype=np.int32)
    succ[order[:-1]] = order[1:]
    succ[order[-1]] = order[-1]
    return succ


def list_graph(n: int, num_lists: int, seed: int = 0) -> np.ndarray:
    """Edge list (m, 2) of `num_lists` disjoint random chains over n nodes."""
    rng = KissRng(seed, n_streams=min(n, 8192))
    keys = rng.uniform_ints((n,), 1 << 31)
    order = np.argsort(keys, kind="stable")
    pieces = np.array_split(order, num_lists)
    edges = [np.stack([p[:-1], p[1:]], axis=1) for p in pieces if len(p) > 1]
    return np.concatenate(edges, axis=0).astype(np.int32)


def tree_graph(n: int, k: int, seed: int = 0) -> np.ndarray:
    """Edge list of a random tree with max branching factor k.

    Built as a complete k-ary tree under a KISS-random relabeling, which is
    the paper's "random trees of degree k" family (diameter ~ log_k n).
    """
    rng = KissRng(seed, n_streams=min(n, 8192))
    keys = rng.uniform_ints((n,), 1 << 31)
    relabel = np.argsort(keys, kind="stable").astype(np.int32)
    child = np.arange(1, n, dtype=np.int64)
    parent = (child - 1) // k
    return np.stack([relabel[parent], relabel[child]], axis=1).astype(np.int32)


def random_graph(n: int, density: float, seed: int = 0) -> np.ndarray:
    """Edge list of an Erdos-Renyi-style graph with edge density `density`.

    m = density * n * (n-1) / 2 endpoints drawn i.i.d. from KISS (possible
    duplicate/self edges, as in the paper's generator; connectivity treats
    them harmlessly).
    """
    m = max(1, int(density * n * (n - 1) / 2))
    rng = KissRng(seed, n_streams=8192)
    ends = rng.uniform_ints((m, 2), n)
    return ends.astype(np.int32)


def random_forest(
    n: int, num_components: int, avg_degree: int = 3, seed: int = 0
) -> np.ndarray:
    """Random components: spanning chains + extra random intra-comp edges."""
    rng = KissRng(seed, n_streams=8192)
    keys = rng.uniform_ints((n,), 1 << 31)
    order = np.argsort(keys, kind="stable")
    comps = np.array_split(order, num_components)
    edges = []
    for ci, nodes in enumerate(comps):
        if len(nodes) < 2:
            continue
        edges.append(np.stack([nodes[:-1], nodes[1:]], axis=1))
        extra = max(0, (avg_degree - 2) * len(nodes) // 2)
        if extra:
            idx = KissRng(seed * 7919 + ci, 4096).uniform_ints(
                (extra, 2), len(nodes)
            )
            edges.append(nodes[idx])
    return np.concatenate(edges, axis=0).astype(np.int32)


def giant_dust_graph(
    n: int, giant_frac: float = 0.9, seed: int = 0
) -> np.ndarray:
    """One giant component plus dust: a single KISS-random chain over
    ``giant_frac`` of the nodes (worst-case diameter, so SV needs its
    full O(log n) rounds on it), the rest isolated singletons. The
    skewed-component-size family connectivity studies use to show
    sampling / frontier skipping wins (most edges stop mattering after
    the giant's labels coalesce)."""
    g = max(2, int(n * giant_frac))
    return list_graph(g, 1, seed=seed)  # nodes [g, n) stay isolated dust
