"""Field packing layouts (paper guideline G5, the 48-bit vs 64-bit study).

The paper packs the per-node (mark, rank) pair into a single 64-bit union so
each list node costs one memory transaction instead of two. On TPU the
transaction unit is the DMA'd row, so the same idea becomes a layout choice:

* **SoA** ("48-bit analogue"): separate ``owner[n]`` / ``rank[n]`` arrays.
  Following a pointer costs two independent HBM gathers.
* **AoS** ("64-bit analogue"): one ``(n, 2)`` int32 array; a row gather
  fetches both fields in one 8-byte contiguous access.
* **word64**: true bit packing into one int64 word (requires x64 mode);
  closest to the paper's union trick, kept for the packing benchmark.

These helpers are deliberately dtype-strict: the roofline term for the
gather-dominated kernels is computed directly from these layouts' byte
counts (benchmarks/table2_packing.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def pack_aos(rank: Array, owner: Array) -> Array:
    """Interleave two int32 fields into an (n, 2) array-of-structs."""
    if rank.shape != owner.shape:
        raise ValueError(f"shape mismatch {rank.shape} vs {owner.shape}")
    return jnp.stack([rank.astype(jnp.int32), owner.astype(jnp.int32)], axis=-1)


def unpack_aos(packed: Array) -> tuple[Array, Array]:
    return packed[..., 0], packed[..., 1]


def gather_aos(packed: Array, idx: Array) -> tuple[Array, Array]:
    """One row gather -> both fields (the single 64-bit transaction)."""
    row = jnp.take(packed, idx, axis=0)
    return row[..., 0], row[..., 1]


def pack_word64(rank: Array, owner: Array) -> Array:
    """Pack (rank, owner) into one int64 word: rank in high 32, owner low 32.

    Mirrors the paper's 64-bit union. Requires ``jax_enable_x64``; callers
    that run in default 32-bit mode should use the AoS layout instead.
    """
    if jnp.int64 != jnp.result_type(jnp.int64):  # pragma: no cover - env guard
        raise RuntimeError("pack_word64 requires jax_enable_x64")
    r = rank.astype(jnp.uint64)
    o = owner.astype(jnp.uint32).astype(jnp.uint64)
    return ((r << 32) | o).astype(jnp.int64)


def unpack_word64(packed: Array) -> tuple[Array, Array]:
    u = packed.astype(jnp.uint64)
    rank = (u >> 32).astype(jnp.int32)
    owner = (u & jnp.uint64(0xFFFFFFFF)).astype(jnp.int32)
    return rank, owner


def bytes_per_node(pack_mode: str) -> dict[str, int]:
    """Analytic per-node traffic of one RS3 walk step (paper section 3.3).

    Returns bytes moved per list node per iteration for the sub-list walking
    kernel, used by the Table-2/Fig-3 reproduction to predict the inflection
    ordering between layouts.
    """
    if pack_mode == "soa":
        # read succ(4) + read owner(4) + write owner(4) + write rank(4)
        return {"read": 8, "write": 8}
    if pack_mode == "aos":
        # read succ(4) + row read (8) + row write (8)
        return {"read": 12, "write": 8}
    if pack_mode == "word64":
        # read succ(4) + word read (8) + word write (8)
        return {"read": 12, "write": 8}
    raise ValueError(f"unknown pack_mode {pack_mode!r}")
