"""Sort-based grouping: coalescing (guideline G1) at coarse grain.

On a GPU, coalescing happens per half-warp memory transaction. On TPU the
same economics apply one level up: ragged groups (tokens->experts, edges->
nodes, bag items->tables) become efficient when physically grouped, because
then every downstream op is a dense contiguous block instead of a scatter.

This module is used by the MoE dispatch (tokens sorted by expert id before
the all_to_all) and by the GNN/embedding paths (edges/bags sorted by
destination/segment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def sort_by_key(keys: Array, *values: Array) -> tuple[Array, ...]:
    """Stable argsort by key; returns (sorted_keys, perm, *sorted_values)."""
    perm = jnp.argsort(keys, stable=True)
    return (keys[perm], perm) + tuple(v[perm] for v in values)


def grouped_offsets(sorted_keys: Array, num_groups: int) -> tuple[Array, Array]:
    """Counts and exclusive-prefix offsets per group for sorted keys."""
    counts = jax.ops.segment_sum(
        jnp.ones_like(sorted_keys, dtype=jnp.int32), sorted_keys, num_groups
    )
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)]
    )
    return counts, offsets


def position_in_group(keys: Array, num_groups: int) -> Array:
    """For each element, its 0-based arrival position within its key group.

    Branch-free (guideline G3): computed as rank-within-key via cumulative
    one-hot sums. Cost O(n * num_groups) flops but fully dense/vectorizable;
    used for capacity assignment in MoE dispatch where num_groups = experts.
    """
    onehot = jax.nn.one_hot(keys, num_groups, dtype=jnp.int32)
    cum = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.sum(cum * onehot, axis=-1)


def take_grouped(
    values: Array,
    keys: Array,
    num_groups: int,
    capacity: int,
    *,
    fill_value=0,
) -> tuple[Array, Array, Array]:
    """Pack `values` into a dense (num_groups, capacity, ...) buffer.

    Elements beyond `capacity` in their group are dropped (MoE token
    dropping / bounded sub-list semantics). Returns (buffer, slot, kept)
    where slot[i] is the row each element landed in and kept[i] marks
    non-dropped elements. Scatter uses OOB-drop semantics so the whole
    routine is branch-free.
    """
    pos = position_in_group(keys, num_groups)
    kept = pos < capacity
    flat_slot = keys * capacity + pos
    flat_slot = jnp.where(kept, flat_slot, num_groups * capacity)  # OOB drop
    buf = jnp.full(
        (num_groups * capacity,) + values.shape[1:], fill_value, values.dtype
    )
    buf = buf.at[flat_slot].set(values, mode="drop")
    return buf.reshape((num_groups, capacity) + values.shape[1:]), flat_slot, kept
