"""Segment reductions: the TPU-side primitive for PRAM scatter phases.

The paper's CRCW concurrent-write phases (hooking in Shiloach-Vishkin,
ownership marking in random-splitter list ranking) become deterministic
reduce-by-key operations here. ``jax.ops.segment_*`` lowers to XLA scatter
with a combiner, which is the TPU analogue of the GPU memory-partition
arbiters resolving concurrent writes (paper section 2.2) -- except the
resolution is a deterministic min/max/sum instead of "arbitrary".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def segment_sum(
    data: Array,
    segment_ids: Array,
    num_segments: int,
    *,
    indices_are_sorted: bool = False,
) -> Array:
    return jax.ops.segment_sum(
        data, segment_ids, num_segments, indices_are_sorted=indices_are_sorted
    )


def segment_max(
    data: Array,
    segment_ids: Array,
    num_segments: int,
    *,
    indices_are_sorted: bool = False,
) -> Array:
    return jax.ops.segment_max(
        data, segment_ids, num_segments, indices_are_sorted=indices_are_sorted
    )


def segment_min(
    data: Array,
    segment_ids: Array,
    num_segments: int,
    *,
    indices_are_sorted: bool = False,
) -> Array:
    return jax.ops.segment_min(
        data, segment_ids, num_segments, indices_are_sorted=indices_are_sorted
    )


def segment_count(segment_ids: Array, num_segments: int) -> Array:
    """Number of elements per segment (degree counting)."""
    return jax.ops.segment_sum(
        jnp.ones(segment_ids.shape, jnp.int32), segment_ids, num_segments
    )


def segment_mean(
    data: Array,
    segment_ids: Array,
    num_segments: int,
    *,
    indices_are_sorted: bool = False,
) -> Array:
    total = segment_sum(
        data, segment_ids, num_segments, indices_are_sorted=indices_are_sorted
    )
    count = segment_count(segment_ids, num_segments)
    count = jnp.maximum(count, 1).astype(total.dtype)
    return total / count.reshape(count.shape + (1,) * (total.ndim - 1))


def segment_softmax(
    logits: Array,
    segment_ids: Array,
    num_segments: int,
    *,
    indices_are_sorted: bool = False,
) -> Array:
    """Numerically stable softmax within each segment (GAT edge softmax).

    Branch-free masking per paper guideline G3: empty segments and padding
    rows are handled through where/maximum arithmetic, never control flow.
    """
    seg_max = segment_max(
        logits, segment_ids, num_segments, indices_are_sorted=indices_are_sorted
    )
    # Empty segments produce -inf maxima; neutralize so gather stays finite.
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    shifted = logits - seg_max[segment_ids]
    expd = jnp.exp(shifted)
    seg_den = segment_sum(
        expd, segment_ids, num_segments, indices_are_sorted=indices_are_sorted
    )
    seg_den = jnp.maximum(seg_den, jnp.finfo(expd.dtype).tiny)
    return expd / seg_den[segment_ids]


# ---------------------------------------------------------------------------
# Edge-parallel (sharded) variants: inside shard_map blocks where edges are
# sharded and node arrays are replicated, partial per-shard reductions are
# combined with psum/pmax over the edge axes. This is the paper's
# concurrent-write arbitration lifted to the collective level.
# ---------------------------------------------------------------------------


def segment_sum_dist(
    data: Array,
    segment_ids: Array,
    num_segments: int,
    axes: tuple[str, ...] = (),
    *,
    indices_are_sorted: bool = False,
) -> Array:
    out = segment_sum(
        data, segment_ids, num_segments, indices_are_sorted=indices_are_sorted
    )
    return jax.lax.psum(out, axes) if axes else out


def segment_max_dist(
    data: Array,
    segment_ids: Array,
    num_segments: int,
    axes: tuple[str, ...] = (),
) -> Array:
    out = segment_max(data, segment_ids, num_segments)
    return jax.lax.pmax(out, axes) if axes else out


def segment_softmax_dist(
    logits: Array,
    segment_ids: Array,
    num_segments: int,
    axes: tuple[str, ...] = (),
) -> tuple[Array, Array]:
    """Edge-sharded segment softmax.

    Returns (numerator_per_edge, denominator_per_segment); the caller
    divides after aggregating weighted messages so only two collectives
    (pmax + psum) are needed per attention layer.
    """
    seg_max = segment_max_dist(logits, segment_ids, num_segments, axes)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    expd = jnp.exp(logits - seg_max[segment_ids])
    seg_den = segment_sum_dist(expd, segment_ids, num_segments, axes)
    seg_den = jnp.maximum(seg_den, jnp.finfo(expd.dtype).tiny)
    return expd, seg_den
