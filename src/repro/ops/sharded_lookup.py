"""Distributed row gather from a row-sharded table (embedding lookup).

The distributed form of the paper's irregular read: each shard gathers the
rows it owns (branch-free mask, guideline G3) and a psum combines the
partials -- the collective-level analogue of the memory-partition arbiters.
This is written explicitly (shard_map) rather than left to GSPMD so the
collective schedule is deterministic and visible in the roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import Mesh, shard_map

Array = jax.Array


def sharded_row_gather(
    table: Array,  # (rows, dim), sharded P(row_axis, None)
    idx: Array,  # any int shape, sharded batch_spec (or replicated)
    mesh: Mesh | None,
    row_axis: str | None = "model",
    idx_spec: P = P(),
) -> Array:
    """Returns table[idx] with shape idx.shape + (dim,)."""
    if mesh is None or mesh.empty or row_axis not in mesh.axis_names:
        return jnp.take(table, idx, axis=0)
    if mesh.shape[row_axis] == 1:
        return jnp.take(table, idx, axis=0)

    def block(tbl, ids):
        i = jax.lax.axis_index(row_axis)
        per = tbl.shape[0]
        loc = ids.astype(jnp.int32) - i * per
        ok = jnp.logical_and(loc >= 0, loc < per)
        vals = jnp.take(tbl, jnp.clip(loc, 0, per - 1), axis=0)
        vals = jnp.where(ok[..., None], vals, 0)
        return jax.lax.psum(vals, row_axis)

    parts = tuple(idx_spec)
    out_spec = P(*(parts + (None,) * (idx.ndim - len(parts)) + (None,)))
    return shard_map(
        block,
        mesh=mesh,
        in_specs=(P(row_axis, None), idx_spec),
        out_specs=out_spec,
        check_vma=False,
    )(table, idx)
