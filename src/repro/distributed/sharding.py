"""Logical-axis sharding rules and path-based PartitionSpec assignment.

Models annotate activations/params with *logical* axes (batch, heads, d_ff,
vocab, expert, nodes, edges, table_rows). A ``ShardingRules`` table maps
those to physical mesh axes; the same model code then runs on the single-pod
(data, model) mesh, the multi-pod (pod, data, model) mesh, or a 1-device
test mesh without edits.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field, replace

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import Mesh


@dataclass(frozen=True)
class ShardingRules:
    """Logical axis -> mesh axis (None = replicate)."""

    batch: tuple[str, ...] | str | None = ("pod", "data")
    seq: str | None = None  # sequence sharding for long-context decode
    heads: str | None = "model"
    d_ff: str | None = "model"
    vocab: str | None = "model"
    expert: str | None = "model"
    edges: tuple[str, ...] | str | None = ("pod", "data", "model")
    nodes: str | None = None  # GNN node tensors replicated by default
    table_rows: str | None = "model"  # recsys embedding-table rows
    stage: str | None = None  # pipeline axis, usually "pod"

    def for_mesh(self, mesh: Mesh) -> "ShardingRules":
        """Drop references to axes the mesh does not have."""

        def fix(ax):
            if ax is None:
                return None
            if isinstance(ax, str):
                return ax if ax in mesh.axis_names else None
            kept = tuple(a for a in ax if a in mesh.axis_names)
            return kept if kept else None

        kw = {k: fix(getattr(self, k)) for k in self.__dataclass_fields__}
        return ShardingRules(**kw)


# Default rule tables per model family; hillclimbs override these.
LM_RULES = ShardingRules()
LM_DECODE_RULES = replace(ShardingRules(), batch=("pod", "data"))
LM_LONG_DECODE_RULES = replace(ShardingRules(), batch=None, seq="data")
GNN_RULES = ShardingRules(batch=("pod", "data"))
RECSYS_RULES = ShardingRules()


def spec_for(rules: ShardingRules, *logical_axes: str | None) -> P:
    """Build a PartitionSpec from logical axis names (None = replicated dim)."""
    parts = []
    for ax in logical_axes:
        if ax is None:
            parts.append(None)
            continue
        phys = getattr(rules, ax)
        parts.append(phys)
    return P(*parts)


def constrain(x: jax.Array, mesh: Mesh, rules: ShardingRules, *axes) -> jax.Array:
    """with_sharding_constraint via logical axes; no-op without a mesh."""
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(rules.for_mesh(mesh), *axes))
    )


@dataclass
class PathRules:
    """Ordered (regex -> PartitionSpec) table matched against param paths.

    First match wins; unmatched leaves are replicated. Used to derive the
    in_shardings pytree for pjit from an init-shape pytree.
    """

    rules: list[tuple[str, P]] = field(default_factory=list)

    def spec_tree(self, shapes: dict) -> dict:
        flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
        specs = []
        for path, _leaf in flat:
            name = "/".join(str(getattr(k, "key", k)) for k in path)
            for pat, spec in self.rules:
                if re.search(pat, name):
                    specs.append(spec)
                    break
            else:
                specs.append(P())
        return jax.tree_util.tree_unflatten(treedef, specs)


def named_sharding_tree(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def drop_missing_axes(spec_tree, mesh: Mesh):
    """Remove mesh-absent axis names from every PartitionSpec in a tree."""

    def fix_spec(s: P) -> P:
        parts = []
        for dim in s:
            if dim is None:
                parts.append(None)
            elif isinstance(dim, str):
                parts.append(dim if dim in mesh.axis_names else None)
            else:
                kept = tuple(a for a in dim if a in mesh.axis_names)
                parts.append(kept if kept else None)
        return P(*parts)

    return jax.tree.map(fix_spec, spec_tree, is_leaf=lambda x: isinstance(x, P))
