"""Edge-partitioned multi-device graph engine (shard_map).

The single-device kernels in ``repro.core`` treat the whole TPU as one
PRAM; this module scales the paper's two headline algorithms across a
1-D device mesh using the partitioning scheme Gunrock-style systems use:
**edges are partitioned, labels are replicated**, and each round ends
with one associative label exchange.

* ``sharded_shiloach_vishkin`` -- each device min-hooks over its own
  edge shard into its replica of the label array ``D``; a ``pmin``
  exchange after SV2 (fused with a ``pmax`` of the activity stamps
  ``Q``) and another after SV3 make the merged replica bit-identical to
  the single-device min-CRCW scatter, because a min-scatter distributes
  over shard unions:  min_shards(min-scatter(shard)) ==
  min-scatter(all edges).  Short-cuts (SV1a/SV4) touch only replicated
  state and run redundantly with zero communication.  The round
  structure -- and therefore the paper's log_{3/2} n + 2 bound -- is
  unchanged; only WHO walks each edge moved.

  ``exchange="sparse"`` replaces the O(n) full-array merges with the
  **sparse frontier exchange**: each device all-gathers only the
  (index, label) pairs its own scatter changed this round, in a
  fixed-capacity buffer (default n/8), and every replica re-applies the
  union onto the shared pre-scatter base -- the same distributivity
  argument, restricted to the changed support, so still bit-exact. A
  pmax'd overflow count flips all replicas together to the dense pmin
  path when a round's frontier exceeds capacity (early rounds), cutting
  late-round exchange volume from O(n) to O(capacity);
  ``with_stats=True`` returns the measured per-round volumes.

* ``sharded_random_splitter_rank`` -- RS3's sub-list walks are
  partitioned over devices by splitter block (device d walks lanes
  [d*p/nd, (d+1)*p/nd)); each device scatter-writes (local_rank, owner)
  for the nodes its sub-lists cover, and since sub-lists partition the
  node set exactly one device writes each node: a single ``pmax``
  merges the stores losslessly.  RS4 all-gathers the p-lane splitter
  list (p is VMEM-sized by construction) and ranks it redundantly on
  every device -- the multi-device analogue of the paper's single-block
  ``__syncthreads`` fast path.  RS5's streaming aggregation is sharded
  back out over node blocks, so the output materialises already
  edge-partitioned (out_spec P(axis)).  ``kernel_impl`` routes RS4/RS5
  through the Pallas kernels (``kernels/pointer_jump``,
  ``kernels/splitter_aggregate``) inside each shard -- "auto" compiles
  them on real TPUs and keeps plain XLA elsewhere.

Both functions are bit-exact against their single-device counterparts
(asserted by ``tests/multidev_scripts.py sharded_cc / sharded_rank``),
and both report their per-round exchange volume so
``benchmarks/multidev_scaling.py`` can plot communication vs devices.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.compat import Mesh, is_tracer
from repro.core.components import (
    HOOK_IMPLS,
    ConvergenceError,
    _maybe_dedup,
    check_choice,
    init_hooks,
    sv_compress,
    sv_round_bound,
    sv_round_fns,
    sv_run,
)
from repro.core.list_ranking import (
    KERNEL_IMPLS,
    SplitterStats,
    _splitter_list_rank,
    aos_walk_fns,
    max_splitters_for_linear_work,
    select_splitters,
)
from repro.core.operators import compact_frontier, run_bucket_ladder
from repro.core.pram import lockstep_walk
from repro.obs import trace

Array = jax.Array

GRAPH_AXIS = "graph"

# Valid cross-device label-exchange modes for the sharded CC engines.
# The frontier-compacted sharded engine defaults to "sparse" (volumes
# are measured per round; late-round frontiers are tiny), the dense
# sharded engine to "dense" (it re-walks every edge anyway).
EXCHANGES = ("dense", "sparse")


def graph_mesh(num_devices: int | None = None, axis: str = GRAPH_AXIS) -> Mesh:
    """1-D mesh over the first ``num_devices`` devices (default: all)."""
    devs = jax.devices()
    nd = num_devices if num_devices is not None else len(devs)
    if nd > len(devs):
        raise ValueError(f"asked for {nd} devices, have {len(devs)}")
    return compat.make_mesh((nd,), (axis,), devices=devs[:nd])


def _resolve_axis(mesh: Mesh, axis: str) -> str:
    """Accept any 1-D mesh regardless of its axis name.

    The engine partitions along a single axis; a user-built 1-D mesh
    named anything (e.g. "data") works as-is, while multi-axis meshes
    must name which axis carries the edges.
    """
    if axis in mesh.axis_names:
        return axis
    if len(mesh.axis_names) == 1:
        return mesh.axis_names[0]
    raise ValueError(
        f"sharded graph engine needs a 1-D mesh or axis={axis!r} present; "
        f"got mesh axes {mesh.axis_names}"
    )


def _pad_to(x: jnp.ndarray, size: int, fill) -> jnp.ndarray:
    if x.shape[0] == size:
        return x
    return jnp.concatenate(
        [x, jnp.full((size - x.shape[0],), fill, x.dtype)]
    )


# ---------------------------------------------------------------------------
# Sharded Shiloach-Vishkin connected components
# ---------------------------------------------------------------------------


def _dense_merge_fns(axis, n):
    """The replicated-label exchanges: full pmin/pmax every round."""

    def merge_labels(d, base, aux, s):
        words, frontier = aux
        cnt = jnp.sum((d != base).astype(jnp.int32))
        aux = (words.at[s].add(n), frontier.at[s].max(jax.lax.pmax(cnt, axis)))
        return jax.lax.pmin(d, axis), aux

    def merge_stamps(q, base, aux, s):
        words, frontier = aux
        return jax.lax.pmax(q, axis), (words.at[s].add(n), frontier)

    return merge_labels, merge_stamps


def _sparse_merge_fns(axis, n, capacity):
    """Sparse frontier exchange: each device publishes only the (index,
    label) pairs its own min-scatter changed this round, in a
    fixed-capacity buffer; every replica applies the all-gathered pairs
    onto the common pre-scatter base. Because a min-scatter distributes
    over edge-shard unions, ``base.at[union of idx].min(vals)`` is
    bit-identical to ``pmin`` of the full arrays -- whenever every
    device's change count fits the buffer. One pmax'd scalar decides
    overflow uniformly across replicas, so all devices fall back to the
    dense pmin path together (``lax.cond`` stays collective-safe)."""
    C = capacity

    def publish_min(d, base, changed):
        idx = jnp.nonzero(changed, size=C, fill_value=n)[0].astype(jnp.int32)
        vals = jnp.where(idx < n, d[jnp.minimum(idx, n - 1)], n)
        idx_all = jax.lax.all_gather(idx, axis, axis=0, tiled=True)
        vals_all = jax.lax.all_gather(vals, axis, axis=0, tiled=True)
        return base.at[idx_all].min(vals_all, mode="drop")

    def merge_labels(d, base, aux, s):
        words, frontier = aux
        changed = d != base
        cnt_max = jax.lax.pmax(jnp.sum(changed.astype(jnp.int32)), axis)
        overflow = cnt_max > C
        merged = jax.lax.cond(
            overflow,
            lambda _: jax.lax.pmin(d, axis),
            lambda _: publish_min(d, base, changed),
            operand=None,
        )
        # 2C words (idx, label) when sparse, n when dense; +1 for the
        # pmax'd overflow count either way.
        aux = (
            words.at[s].add(jnp.where(overflow, n, 2 * C) + 1),
            frontier.at[s].max(cnt_max),
        )
        return merged, aux

    def merge_stamps(q, base, aux, s):
        words, frontier = aux
        changed = q != base
        cnt_max = jax.lax.pmax(jnp.sum(changed.astype(jnp.int32)), axis)
        overflow = cnt_max > C

        def sparse(_):
            idx = jnp.nonzero(changed, size=C, fill_value=n)[0].astype(
                jnp.int32
            )
            idx_all = jax.lax.all_gather(idx, axis, axis=0, tiled=True)
            # Every SV2 stamp this round is the same value s, so indices
            # alone carry the exchange (C words, not 2C).
            return base.at[idx_all].set(s, mode="drop")

        merged = jax.lax.cond(
            overflow, lambda _: jax.lax.pmax(q, axis), sparse, operand=None
        )
        aux = (words.at[s].add(jnp.where(overflow, n, C) + 1), frontier)
        return merged, aux

    return merge_labels, merge_stamps


@partial(
    jax.jit,
    static_argnames=(
        "num_nodes", "max_rounds", "mesh", "axis", "exchange", "capacity",
        "record_hooks",
    ),
)
def _sharded_sv(a, b, *, num_nodes, max_rounds, mesh, axis, exchange,
                capacity, record_hooks=False):
    n = num_nodes
    bound = max_rounds if max_rounds is not None else sv_round_bound(n)

    def block(a_loc, b_loc):
        # The round body itself lives in core.components.sv_run;
        # this engine only chooses who walks which edges and inserts the
        # two per-round exchanges: the label merge after each min-scatter
        # (exchange 1 fused with the activity-stamp merge -- monotone
        # round numbers, so max == "any device set it"), exchange 2 for
        # the SV3 hooks. Short-cuts run redundantly on replicated state.
        # ``exchange="sparse"`` swaps the full-array pmin/pmax for the
        # frontier-compacted (index, label) exchange.
        if exchange == "sparse":
            ml, mq = _sparse_merge_fns(axis, n, capacity)
        else:
            ml, mq = _dense_merge_fns(axis, n)
        aux0 = (jnp.zeros(bound + 2, jnp.int32), jnp.zeros(bound + 2, jnp.int32))
        # Hook recording merges with pmin: candidate winning-edge arrays
        # use sentinel n, so the per-phase two-step (u then v) pmin
        # reconstructs the lexicographically-min global winner even when
        # the winning edge lives on another device's shard.
        mh = (lambda arr: jax.lax.pmin(arr, axis)) if record_hooks else None
        return sv_run(
            a_loc, b_loc, n, bound,
            merge_labels=ml, merge_stamps=mq,
            aux0=aux0, return_aux=True,
            record_hooks=record_hooks, merge_hooks=mh,
        )

    # sv_run returns (D, rounds, converged[, hooks], aux) -- converged
    # is the replicated fixpoint sentinel (see ConvergenceError).
    out_specs = (P(), P(), P(), (P(), P()))
    if record_hooks:
        out_specs = (P(), P(), P(), (P(), P()), (P(), P()))
    return compat.shard_map(
        block,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=out_specs,
        check_vma=False,
    )(a, b)


@dataclass
class CCExchangeStats:
    """Measured per-round exchange volume (``benchmarks/multidev_scaling``).

    ``words_per_round[r]`` is the int32 words one device sent in round
    r+1 across all three exchanges; ``frontier_per_round[r]`` is the
    largest per-device changed-label count pmax'd that round (the sparse
    payload the fixed-capacity buffer must hold to stay off the dense
    fallback)."""

    words_per_round: np.ndarray
    frontier_per_round: np.ndarray
    exchange: str
    capacity: int | None

    def publish(self, registry=None, prefix: str = "cc.sharded") -> None:
        """Publish into the metrics registry (``repro.obs.metrics``)."""
        from repro.obs.metrics import publish_stats

        publish_stats(self, prefix, registry)


def default_sparse_capacity(num_nodes: int) -> int:
    """Per-device (index, label) buffer: n/8 keeps a no-overflow round's
    label exchange at n/4 words vs the dense path's n."""
    return max(64, num_nodes // 8)


def sharded_shiloach_vishkin(
    src: Array | np.ndarray,
    dst: Array | np.ndarray,
    num_nodes: int,
    *,
    mesh: Mesh | None = None,
    axis: str = GRAPH_AXIS,
    max_rounds: int | None = None,
    exchange: str = "dense",
    sparse_capacity: int | None = None,
    dedup: bool = True,
    record_hooks: bool = False,
    with_stats: bool = False,
):
    """Multi-device connected components; bit-exact vs single-device.

    Edges (both orientations, as in the paper's 2m walk, minus
    self-loops and duplicates) are partitioned across the mesh; labels
    are replicated and merged twice per round. ``exchange="sparse"``
    sends only the (index, label) pairs each device changed (capacity
    ``sparse_capacity``, default n/8, dense fallback on overflow) --
    bit-exact either way. Returns (labels, rounds) exactly like
    ``shiloach_vishkin``, plus the ``(hook_u, hook_v)`` spanning-forest
    record when ``record_hooks`` (labels/rounds unchanged; the hook
    arrays are pmin-merged so they match the single-device record
    bit-exactly), plus a ``CCExchangeStats`` when ``with_stats``.
    """
    check_choice("exchange", exchange, EXCHANGES)
    mesh = mesh if mesh is not None else graph_mesh(axis=axis)
    axis = _resolve_axis(mesh, axis)
    nd = mesh.shape[axis]
    src, dst = _maybe_dedup(src, dst, dedup)  # no-op under a jit trace
    src = jnp.asarray(src).astype(jnp.int32)
    dst = jnp.asarray(dst).astype(jnp.int32)
    a = jnp.concatenate([src, dst])
    b = jnp.concatenate([dst, src])
    # Pad the edge shard to a device multiple with (0, 0) self-loops --
    # inert under both hook conditions (SV2 needs Db < Da, SV3 Da != Db).
    m2 = int(a.shape[0])
    mp = max(-(-m2 // nd) * nd, nd)
    a, b = _pad_to(a, mp, 0), _pad_to(b, mp, 0)
    capacity = (
        sparse_capacity if sparse_capacity is not None
        else default_sparse_capacity(num_nodes)
    )
    # Whole-run device span: blocks on the replicated labels at close,
    # the sync the sentinel read below pays anyway; nothing registers
    # under an outer jit trace, so the engine stays traceable.
    with trace.span(
        "cc.sharded", device=True, n=num_nodes, devices=nd,
        exchange=exchange,
    ) as sp:
        res = _sharded_sv(
            a, b, num_nodes=num_nodes, max_rounds=max_rounds, mesh=mesh,
            axis=axis, exchange=exchange, capacity=capacity,
            record_hooks=record_hooks,
        )
        if record_hooks:
            labels, rounds, converged, hooks, (words, frontier) = res
            out = (labels, rounds, hooks)
        else:
            labels, rounds, converged, (words, frontier) = res
            out = (labels, rounds)
        if not is_tracer(converged):
            sp.block_on(labels)
    if not is_tracer(converged):
        # Intentional terminal sync: the fixpoint sentinel must be read
        # before wrong labels can escape (labels are replicated, so the
        # flag is device-agreed). Traced callers keep the documented
        # return-at-bound behavior.
        if not bool(converged):  # repro-lint: disable=host-sync
            bound = (
                max_rounds if max_rounds is not None
                else sv_round_bound(num_nodes)
            )
            raise ConvergenceError(
                f"sharded_shiloach_vishkin hit max_rounds={bound} "
                f"before the label fixpoint on {num_nodes} nodes; raise "
                "max_rounds (the proven bound is sv_round_bound(n)="
                f"{sv_round_bound(num_nodes)})"
            )
    if not with_stats:
        return out
    # Opt-in stats materialization: with_stats=True is an explicit ask to
    # read the per-round traces back to host, after the loop converged.
    r = int(rounds)  # repro-lint: disable=host-sync
    stats = CCExchangeStats(
        words_per_round=np.asarray(words)[1 : r + 1],  # repro-lint: disable=host-sync
        frontier_per_round=np.asarray(frontier)[1 : r + 1],  # repro-lint: disable=host-sync
        exchange=exchange,
        capacity=capacity if exchange == "sparse" else None,
    )
    return out + (stats,)


def cc_exchange_words_per_round(
    num_nodes: int, *, stats: CCExchangeStats | None = None
):
    """int32 words a device sends per SV round.

    Without ``stats``: the dense replicated-label model,
    pmin(D2)+pmax(Q)+pmin(D3) = 3n, as a scalar. With ``stats`` (from
    ``sharded_shiloach_vishkin(..., with_stats=True)``): the measured
    per-round volumes, as an array -- for the sparse exchange this drops
    to O(frontier buffer) once the per-round change counts fit capacity.
    """
    if stats is not None:
        return stats.words_per_round
    return 3 * num_nodes


# ---------------------------------------------------------------------------
# Sharded frontier-compacted Shiloach-Vishkin (per-shard edge frontiers)
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=(
        "num_nodes", "bound", "shrink_at", "mesh", "axis", "exchange",
        "capacity", "hook_impl", "record_hooks",
    ),
)
def _sharded_frontier_level(
    a, b, D, Q, aux, s, *, num_nodes, bound, shrink_at, mesh, axis,
    exchange, capacity, hook_impl, record_hooks=False,
):
    """One bucket level of the sharded frontier engine: every device runs
    SV rounds over its own (compacted) edge shard at a fixed per-device
    buffer size, with the usual per-round label exchanges, until
    convergence, the round bound, or -- when ``shrink_at`` is set -- the
    globally largest per-device frontier drops to half the buffer.

    The shrink watermark is ``pmax`` of the per-shard live counts, read
    off the round body's own SV3 compare mask exactly like the
    single-device engine, and it rides in the loop carry so the
    ``while_loop`` predicate stays collective-free (every replica holds
    the identical pmax'd scalar -- the same uniformity argument as the
    sparse exchange's overflow cond). Node-indexed state (labels, stamps,
    hook records, exchange stats) is replicated and threads through
    levels untouched by compaction."""
    n = num_nodes

    def block(a_loc, b_loc, D, Q, aux, s):
        if exchange == "sparse":
            ml, mq = _sparse_merge_fns(axis, n, capacity)
        else:
            ml, mq = _dense_merge_fns(axis, n)
        mh = (lambda arr: jax.lax.pmin(arr, axis)) if record_hooks else None
        body = sv_round_fns(
            a_loc, b_loc, n, ml, mq, hook_impl=hook_impl,
            with_frontier=True, record_hooks=record_hooks, merge_hooks=mh,
        )
        m_loc = a_loc.shape[0]

        def wrapped(carry):
            D, Q, aux, s, changed, fmask, _live_max, rounds = carry
            D, Q, aux, s, changed, fmask = body(
                (D, Q, aux, s, changed, fmask)
            )
            live = jnp.sum(fmask.astype(jnp.int32))
            live_max = jax.lax.pmax(live, axis)
            return D, Q, aux, s, changed, fmask, live_max, rounds + 1

        def cond(carry):
            _D, _Q, _aux, s, changed, _fmask, live_max, _rounds = carry
            keep = jnp.logical_and(changed, s <= bound)
            if shrink_at is not None:
                keep = jnp.logical_and(keep, live_max > shrink_at)
            return keep

        init = (
            D, Q, aux, s, jnp.bool_(True), jnp.ones((m_loc,), jnp.bool_),
            jnp.int32(m_loc), jnp.int32(0),
        )
        D, Q, aux, s, changed, fmask, live_max, rounds = jax.lax.while_loop(
            cond, wrapped, init
        )
        return D, Q, aux, s, changed, fmask, live_max, rounds

    rep = jax.tree_util.tree_map(lambda _: P(), aux)
    return compat.shard_map(
        block,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(), P(), rep, P()),
        out_specs=(P(), P(), rep, P(), P(), P(axis), P(), P()),
        check_vma=False,
    )(a, b, D, Q, aux, s)


@partial(jax.jit, static_argnames=("size", "mesh", "axis"))
def _sharded_compact(a, b, fmask, *, size, mesh, axis):
    """Every device compacts its own edge shard into a ``size``-slot
    bucket (the global pmax'd live count's power-of-two ceiling) via the
    shard-local ``core.frontier.compact_frontier`` primitive -- zero
    cross-device traffic; shards stay where they are, only shrink."""
    return compat.shard_map(
        partial(compact_frontier, size=size),
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
        check_vma=False,
    )(a, b, fmask)


@dataclass
class ShardedFrontierStats:
    """Work + exchange accounting for the sharded frontier engine.

    ``edges_touched`` counts **per-device** edge-slot visits with the
    same rules as ``core.frontier.FrontierStats`` (two hook passes per
    round over the local bucket, one bucket write per compaction); the
    dense sharded engine's same-metric cost is ``2 * ceil(m2 / nd) *
    rounds`` per device. ``words_per_round`` / ``frontier_per_round``
    are the measured exchange volumes, as in ``CCExchangeStats``;
    ``capacities`` lists the frontier-driven sparse buffer size chosen
    at each level (empty for the dense exchange)."""

    rounds: int
    edges_touched: int  # per-device edge-slot visits (see docstring)
    m2: int  # global oriented edge count after dedup
    num_devices: int
    levels: list = field(default_factory=list)  # (per-device bucket, rounds)
    exchange: str = "sparse"
    capacities: list = field(default_factory=list)  # per-level sparse cap
    words_per_round: np.ndarray | None = None
    frontier_per_round: np.ndarray | None = None

    def publish(
        self, registry=None, prefix: str = "cc.sharded_frontier"
    ) -> None:
        """Publish into the metrics registry (``repro.obs.metrics``)."""
        from repro.obs.metrics import publish_stats

        publish_stats(self, prefix, registry)


def frontier_sparse_capacity(
    num_nodes: int, bucket: int, user_capacity: int | None = None
) -> int:
    """Per-device sparse-exchange buffer for one frontier level.

    Sized from the live frontier: a device's min-scatter changes at most
    one label slot per local edge, so ``bucket`` (the per-device frontier
    buffer) is a hard bound on its per-round change count -- once the
    frontier undercuts the fixed ``default_sparse_capacity`` the buffer
    shrinks with it and overflow becomes impossible. Early levels (bucket
    above the fixed default) keep the default capacity with the dense
    fallback live, exactly like the dense sharded engine's sparse mode.
    An explicit ``user_capacity`` is honoured verbatim at every level
    (that keeps the overflow path forceable in tests)."""
    if user_capacity is not None:
        return user_capacity
    return max(64, min(bucket, default_sparse_capacity(num_nodes)))


def sharded_frontier_shiloach_vishkin(
    src: Array | np.ndarray,
    dst: Array | np.ndarray,
    num_nodes: int,
    *,
    mesh: Mesh | None = None,
    axis: str = GRAPH_AXIS,
    max_rounds: int | None = None,
    exchange: str = "sparse",
    sparse_capacity: int | None = None,
    min_bucket: int = 1024,
    hook_impl: str = "xla",
    dedup: bool = True,
    record_hooks: bool = False,
    with_stats: bool = False,
):
    """Frontier-compacted CC on the mesh: the composition of the sharded
    engine (edges partitioned, labels replicated, per-round exchanges)
    with the frontier engine (each device compacts its OWN edge shard to
    the active frontier between bucket levels).

    Bit-exact in labels, round counts, AND recorded hook forests against
    both ``sharded_shiloach_vishkin`` and the single-device engines: the
    round body is the shared ``sv_round_fns``, compaction keeps every
    unequal-label edge (label equality is permanent, so no future hook
    winner is ever dropped), and the inert (0, 0) self-loop padding in
    part-full buckets is invisible to both hook conditions.

    ``exchange="sparse"`` is the DEFAULT here (unlike the dense sharded
    engine): per-round volumes are measured, and the sparse buffer is
    sized from the live frontier per level (``frontier_sparse_capacity``)
    -- once the frontier fits the per-device bucket, overflow to the
    dense path is impossible by construction. ``hook_impl`` routes each
    shard's SV2/SV3 hook phases through the fused ``kernels/edge_hook``
    Pallas kernel (shard-local labels+stamps stay VMEM-resident; the
    merges see identical arrays either way). Returns ``(labels, rounds)``
    plus the ``(hook_u, hook_v)`` record when ``record_hooks``, plus a
    ``ShardedFrontierStats`` when ``with_stats``.

    Like the single-device frontier engine, the level loop is
    host-driven (bucket sizes are compiled shapes), so this engine
    cannot run under an outer ``jax.jit`` trace -- ``engine="auto"``
    falls back to the fully-traceable dense sharded walk there.
    """
    n = num_nodes
    check_choice("exchange", exchange, EXCHANGES)
    check_choice("hook_impl", hook_impl, HOOK_IMPLS)
    mesh = mesh if mesh is not None else graph_mesh(axis=axis)
    axis = _resolve_axis(mesh, axis)
    nd = mesh.shape[axis]
    src, dst = _maybe_dedup(src, dst, dedup)
    src = jnp.asarray(src, jnp.int32).ravel()
    dst = jnp.asarray(dst, jnp.int32).ravel()
    a = jnp.concatenate([src, dst])
    b = jnp.concatenate([dst, src])
    m2 = int(a.shape[0])
    bucket = max(-(-m2 // nd), 1)  # per-device edge-buffer size
    a, b = _pad_to(a, nd * bucket, 0), _pad_to(b, nd * bucket, 0)

    bound = max_rounds if max_rounds is not None else sv_round_bound(n)
    D = jnp.arange(n, dtype=jnp.int32)
    Q = jnp.zeros(n, jnp.int32)
    s = jnp.int32(1)
    exa = (jnp.zeros(bound + 2, jnp.int32), jnp.zeros(bound + 2, jnp.int32))
    aux = (init_hooks(n), exa) if record_hooks else exa
    stats = ShardedFrontierStats(
        rounds=0, edges_touched=0, m2=m2, num_devices=nd, exchange=exchange,
    )

    fmask = None
    live_max = None
    # Spans attach at the per-LEVEL syncs the shared shrink ladder
    # already pays; tags reuse those reads (docs/observability.md). The
    # ladder is the same operators.run_bucket_ladder the single-device
    # engine drives; only the closures differ -- the level runs inside
    # shard_map and the live watermark is the pmax'd per-device count.
    with trace.span(
        "cc.sharded_frontier", n=n, m2=m2, devices=nd, exchange=exchange,
    ) as run_sp:

        def sv_level(bucket_now, shrink_at):
            nonlocal D, Q, aux, s, fmask, live_max
            capacity = (
                frontier_sparse_capacity(n, bucket_now, sparse_capacity)
                if exchange == "sparse" else 0
            )
            if exchange == "sparse":
                stats.capacities.append(capacity)
            with trace.span(
                "cc.sharded_frontier.level", bucket=bucket_now,
                capacity=capacity,
            ) as sp:
                D, Q, aux, s, changed, fmask, live_max, rounds = (
                    _sharded_frontier_level(
                        a, b, D, Q, aux, s,
                        num_nodes=n, bound=bound, shrink_at=shrink_at,
                        mesh=mesh, axis=axis, exchange=exchange,
                        capacity=capacity, hook_impl=hook_impl,
                        record_hooks=record_hooks,
                    )
                )
                # Per-device visit accounting mirrors the single-device
                # engine: SV2 + SV3 passes over the local bucket (the
                # Pallas hook kernel pays a third, mask, pass), plus the
                # compaction write below.
                passes = 2 if hook_impl == "xla" else 3
                # Per-level host syncs (not per-round): the inner SV
                # iteration stays on device and the host reads one round
                # count / convergence flag / live max per LEVEL to drive
                # the shared shrink ladder -- same level-synchronous
                # design as frontier.py.
                level_rounds = int(rounds)  # repro-lint: disable=host-sync
                stats.edges_touched += passes * level_rounds * bucket_now
                stats.levels.append((bucket_now, level_rounds))
                converged = not bool(changed)  # repro-lint: disable=host-sync
                sp.tag(rounds=level_rounds, converged=converged)
            over = not converged and int(s) > bound  # repro-lint: disable=host-sync
            return converged, over

        def live_edges():
            # Shrink: every shard drops to the power-of-two bucket
            # covering the LARGEST per-device live count (one shared
            # compiled shape).
            return int(live_max)  # repro-lint: disable=host-sync

        def charge_shrink(new_bucket):
            stats.edges_touched += new_bucket

        def shrink(new_bucket):
            nonlocal a, b
            a, b = _sharded_compact(
                a, b, fmask, size=new_bucket, mesh=mesh, axis=axis
            )

        def bound_hit():
            raise ConvergenceError(
                f"sharded frontier SV hit its round bound ({bound}) before"
                f" the label fixpoint on {n} nodes across {nd} devices; the"
                " labels at the bound are NOT components -- raise"
                " max_rounds (the proven bound is sv_round_bound(n)="
                f"{sv_round_bound(n)})"
            )

        run_bucket_ladder(
            bucket=bucket, min_bucket=min_bucket, run_level=sv_level,
            live_count=live_edges, compact=shrink, on_shrink=charge_shrink,
            on_nonconverged=bound_hit,
        )
        D = sv_compress(D, n)
        # Terminal readback: the loop above already synced on s per level.
        rounds_total = int(s) - 1  # repro-lint: disable=host-sync
        run_sp.tag(rounds=rounds_total, levels=len(stats.levels))
    stats.rounds = rounds_total
    out = (D, jnp.int32(rounds_total))
    if record_hooks:
        hooks, exa = aux
        out = out + (hooks,)
    else:
        exa = aux
    if not with_stats:
        return out
    # Opt-in stats materialization after convergence (with_stats=True).
    words, frontier = exa
    stats.words_per_round = np.asarray(words)[1 : rounds_total + 1]  # repro-lint: disable=host-sync
    stats.frontier_per_round = np.asarray(frontier)[1 : rounds_total + 1]  # repro-lint: disable=host-sync
    return out + (stats,)


# ---------------------------------------------------------------------------
# Sharded random-splitter list ranking
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=(
        "n", "p", "pp", "npad", "max_steps", "mesh", "axis", "kernel_impl"
    ),
)
def _sharded_rs(
    succ, spl_pad, *, n, p, pp, npad, max_steps, mesh, axis, kernel_impl
):
    nd = mesh.shape[axis]
    lanes_per = pp // nd

    def block(succ, spl_all):
        dev = jax.lax.axis_index(axis)
        # RS1/RS2 (replicated): stop set + ownership seed from the full
        # splitter list; every device computes the identical init.
        spl = spl_all[:p]
        all_lanes = jnp.arange(p, dtype=jnp.int32)
        is_stop = jnp.zeros((n,), jnp.bool_).at[spl].set(True)
        packed = jnp.full((n, 2), -1, jnp.int32)
        packed = packed.at[:, 0].set(0)
        packed = packed.at[spl, 1].set(all_lanes)

        # RS3 (partitioned by splitter block): device d walks global
        # lanes [d*lanes_per, (d+1)*lanes_per). Padded lanes (id >= p)
        # are masked inert.
        lanes = dev.astype(jnp.int32) * lanes_per + jnp.arange(
            lanes_per, dtype=jnp.int32
        )
        valid = lanes < p
        spl_loc = jax.lax.dynamic_slice(
            spl_all, (dev * lanes_per,), (lanes_per,)
        )
        state = dict(
            store=(packed,),
            cur=spl_loc,
            nxt=succ[spl_loc],
            dist=jnp.ones((lanes_per,), jnp.int32),
        )
        # Walk predicate + scatter are the single-device ones (shared
        # code); only the lane ids are offset and padded lanes masked.
        active_fn, step_fn = aos_walk_fns(succ, is_stop, lanes, valid=valid)
        final, steps, converged = lockstep_walk(
            state, active_fn, step_fn, max_steps=max_steps
        )
        (pk,) = final["store"]

        # Merge the stores: sub-lists partition the nodes, so each node
        # was written by exactly one device (local >= 1 over init 0,
        # owner >= 0 over init -1) -> pmax is a lossless union. ONE
        # n-sized exchange for the whole walk phase.
        local = jax.lax.pmax(pk[:, 0], axis)
        owner = jax.lax.pmax(pk[:, 1], axis)

        # RS4 (gathered): the p-lane splitter list fits one device's
        # VMEM; all-gather the per-lane walk results and rank the list
        # redundantly on every replica -- with kernel_impl="pallas" all
        # O(log p) jumping steps run inside ONE kernels/pointer_jump
        # call per device (the paper's single-block fast path).
        dist_full = jax.lax.all_gather(final["dist"], axis, axis=0, tiled=True)[:p]
        nxt_full = jax.lax.all_gather(final["nxt"], axis, axis=0, tiled=True)[:p]
        spsucc = owner[nxt_full]
        is_term = spsucc == all_lanes
        w_adj = dist_full - is_term.astype(jnp.int32)
        iters = max(1, math.ceil(math.log2(max(p, 2))))
        if kernel_impl != "xla":
            from repro.kernels.pointer_jump.ops import pointer_jump

            r, nxt_final = pointer_jump(
                spsucc, jnp.where(is_term, 0, w_adj),
                iters=iters, impl=kernel_impl,
            )
            rank_sp = r + w_adj[nxt_final]
        else:
            rank_sp = _splitter_list_rank(w_adj, spsucc, iters)

        # RS5 (sharded back out): each device aggregates its node block;
        # the ranks come out already partitioned over the mesh. The
        # pallas path streams the block through kernels/splitter_aggregate
        # with the splitter table pinned in VMEM.
        blk = npad // nd
        own_blk = jax.lax.dynamic_slice(
            _pad_to(owner, npad, 0), (dev * blk,), (blk,)
        )
        loc_blk = jax.lax.dynamic_slice(
            _pad_to(local, npad, 0), (dev * blk,), (blk,)
        )
        if kernel_impl != "xla":
            from repro.kernels.splitter_aggregate.ops import splitter_aggregate

            packed_blk = jnp.stack([loc_blk, own_blk], axis=-1)
            rank_blk = splitter_aggregate(packed_blk, rank_sp, impl=kernel_impl)
        else:
            rank_blk = rank_sp[own_blk] - loc_blk

        steps = jax.lax.pmax(steps, axis)  # global trip count
        # Fixpoint sentinel: converged only if EVERY device's lanes
        # finished -- pmin of the per-device flags is the global AND.
        converged = jax.lax.pmin(converged.astype(jnp.int32), axis)
        return rank_blk, dist_full, steps, converged

    return compat.shard_map(
        block,
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=(P(axis), P(), P(), P()),
        check_vma=False,
    )(succ, spl_pad)


def sharded_random_splitter_rank(
    succ: Array | np.ndarray,
    num_splitters: int | None = None,
    *,
    splitters: np.ndarray | None = None,
    head: int = 0,
    seed: int = 0,
    mesh: Mesh | None = None,
    axis: str = GRAPH_AXIS,
    max_steps: int | None = None,
    kernel_impl: str = "auto",
    with_stats: bool = False,
):
    """Multi-device list ranking; bit-exact vs ``random_splitter_rank``.

    Splitter selection (RS1/RS2) is identical to the single-device path
    (same KISS streams, same seed), so the two implementations rank the
    same sub-lists and produce identical integer ranks.

    ``kernel_impl`` routes the RS4/RS5 phases through the Pallas kernels
    (``kernels/pointer_jump``, ``kernels/splitter_aggregate``) inside
    each device's shard: "auto" compiles them on a real TPU backend and
    keeps the plain-XLA phases elsewhere; "pallas"/"pallas_interpret"
    force the kernel path (interpreted off-TPU). All routes are
    bit-exact -- the phases are integer-exact in any implementation.
    """
    from repro.kernels import on_tpu

    check_choice("kernel_impl", kernel_impl, KERNEL_IMPLS)
    if kernel_impl == "auto":
        kernel_impl = "pallas" if on_tpu() else "xla"
    mesh = mesh if mesh is not None else graph_mesh(axis=axis)
    axis = _resolve_axis(mesh, axis)
    nd = mesh.shape[axis]
    succ = jnp.asarray(succ).astype(jnp.int32)
    n = int(succ.shape[0])
    if splitters is None:
        p = num_splitters or min(4096, max_splitters_for_linear_work(n))
        p = min(p, n)
        splitters = select_splitters(n, p, seed=seed, head=head)
    splitters = np.asarray(splitters)
    p = len(splitters)
    pp = max(-(-p // nd) * nd, nd)  # lane padding (masked inert)
    npad = max(-(-n // nd) * nd, nd)  # node padding for the RS5 out shard
    spl_pad = _pad_to(jnp.asarray(splitters, jnp.int32), pp, 0)
    with trace.span(
        "rank.splitter.sharded", device=True, n=n, p=p, devices=nd,
    ) as sp:
        rank_pad, sublens, steps, converged = _sharded_rs(
            succ,
            spl_pad,
            n=n,
            p=p,
            pp=pp,
            npad=npad,
            max_steps=max_steps,
            mesh=mesh,
            axis=axis,
            kernel_impl=kernel_impl,
        )
        rank = rank_pad[:n]
        if not is_tracer(converged):
            sp.block_on(rank)
    if max_steps is not None and not is_tracer(converged):
        # Host-driven callers get the fixpoint guarantee; a traced
        # caller cannot raise on a device value and keeps the
        # return-at-bound behavior.
        if not bool(converged):  # repro-lint: disable=host-sync
            raise ConvergenceError(
                f"sharded_random_splitter_rank hit max_steps={max_steps}"
                f" with unfinished lanes ({p} splitters, {n} nodes); the"
                " ranks are NOT valid -- raise max_steps"
            )
    if not with_stats:
        return rank
    # Opt-in stats materialization after the walk finished.
    stats = SplitterStats(
        splitters=np.asarray(splitters),  # repro-lint: disable=host-sync
        sublist_lengths=np.asarray(sublens),  # repro-lint: disable=host-sync
        walk_steps=int(steps),  # repro-lint: disable=host-sync
        expected_mean=n / p,
    )
    return rank, stats


def rank_exchange_words(n: int, p: int, num_devices: int) -> int:
    """int32 words a device sends for one sharded ranking call:
    pmax(local)+pmax(owner) (2n) + two lane all-gathers (2p)."""
    del num_devices  # replicated-label scheme: volume is device-local
    return 2 * n + 2 * p
