"""Edge-partitioned multi-device graph engine (shard_map).

The single-device kernels in ``repro.core`` treat the whole TPU as one
PRAM; this module scales the paper's two headline algorithms across a
1-D device mesh using the partitioning scheme Gunrock-style systems use:
**edges are partitioned, labels are replicated**, and each round ends
with one associative label exchange.

* ``sharded_shiloach_vishkin`` -- each device min-hooks over its own
  edge shard into its replica of the label array ``D``; a ``pmin``
  exchange after SV2 (fused with a ``pmax`` of the activity stamps
  ``Q``) and another after SV3 make the merged replica bit-identical to
  the single-device min-CRCW scatter, because a min-scatter distributes
  over shard unions:  min_shards(min-scatter(shard)) ==
  min-scatter(all edges).  Short-cuts (SV1a/SV4) touch only replicated
  state and run redundantly with zero communication.  The round
  structure -- and therefore the paper's log_{3/2} n + 2 bound -- is
  unchanged; only WHO walks each edge moved.

* ``sharded_random_splitter_rank`` -- RS3's sub-list walks are
  partitioned over devices by splitter block (device d walks lanes
  [d*p/nd, (d+1)*p/nd)); each device scatter-writes (local_rank, owner)
  for the nodes its sub-lists cover, and since sub-lists partition the
  node set exactly one device writes each node: a single ``pmax``
  merges the stores losslessly.  RS4 all-gathers the p-lane splitter
  list (p is VMEM-sized by construction) and ranks it redundantly on
  every device -- the multi-device analogue of the paper's single-block
  ``__syncthreads`` fast path.  RS5's streaming aggregation is sharded
  back out over node blocks, so the output materialises already
  edge-partitioned (out_spec P(axis)).

Both functions are bit-exact against their single-device counterparts
(asserted by ``tests/multidev_scripts.py sharded_cc / sharded_rank``),
and both report their per-round exchange volume so
``benchmarks/multidev_scaling.py`` can plot communication vs devices.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.core.components import sv_round_bound, sv_run
from repro.core.list_ranking import (
    SplitterStats,
    _splitter_list_rank,
    aos_walk_fns,
    max_splitters_for_linear_work,
    select_splitters,
)
from repro.core.pram import lockstep_walk

Array = jax.Array

GRAPH_AXIS = "graph"


def graph_mesh(num_devices: int | None = None, axis: str = GRAPH_AXIS) -> Mesh:
    """1-D mesh over the first ``num_devices`` devices (default: all)."""
    devs = jax.devices()
    nd = num_devices if num_devices is not None else len(devs)
    if nd > len(devs):
        raise ValueError(f"asked for {nd} devices, have {len(devs)}")
    return compat.make_mesh((nd,), (axis,), devices=devs[:nd])


def _resolve_axis(mesh: Mesh, axis: str) -> str:
    """Accept any 1-D mesh regardless of its axis name.

    The engine partitions along a single axis; a user-built 1-D mesh
    named anything (e.g. "data") works as-is, while multi-axis meshes
    must name which axis carries the edges.
    """
    if axis in mesh.axis_names:
        return axis
    if len(mesh.axis_names) == 1:
        return mesh.axis_names[0]
    raise ValueError(
        f"sharded graph engine needs a 1-D mesh or axis={axis!r} present; "
        f"got mesh axes {mesh.axis_names}"
    )


def _pad_to(x: jnp.ndarray, size: int, fill) -> jnp.ndarray:
    if x.shape[0] == size:
        return x
    return jnp.concatenate(
        [x, jnp.full((size - x.shape[0],), fill, x.dtype)]
    )


# ---------------------------------------------------------------------------
# Sharded Shiloach-Vishkin connected components
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=("num_nodes", "max_rounds", "mesh", "axis"),
)
def _sharded_sv(a, b, *, num_nodes, max_rounds, mesh, axis):
    n = num_nodes
    bound = max_rounds if max_rounds is not None else sv_round_bound(n)

    def block(a_loc, b_loc):
        # The round body itself lives in core.components.sv_run;
        # this engine only chooses who walks which edges and inserts the
        # two per-round exchanges: pmin merges each min-scatter (exchange
        # 1 fused with a pmax of the activity stamps Q -- monotone round
        # numbers, so max == "any device set it"), exchange 2 merges the
        # SV3 hooks. Short-cuts run redundantly on replicated state.
        return sv_run(
            a_loc,
            b_loc,
            n,
            bound,
            merge_labels=lambda d: jax.lax.pmin(d, axis),
            merge_stamps=lambda q: jax.lax.pmax(q, axis),
        )

    return compat.shard_map(
        block,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(), P()),
        check_vma=False,
    )(a, b)


def sharded_shiloach_vishkin(
    src: Array | np.ndarray,
    dst: Array | np.ndarray,
    num_nodes: int,
    *,
    mesh: Mesh | None = None,
    axis: str = GRAPH_AXIS,
    max_rounds: int | None = None,
) -> tuple[Array, Array]:
    """Multi-device connected components; bit-exact vs single-device.

    Edges (both orientations, as in the paper's 2m walk) are partitioned
    across the mesh; labels are replicated and min-merged twice per
    round. Returns (labels, rounds) exactly like ``shiloach_vishkin``.
    """
    mesh = mesh if mesh is not None else graph_mesh(axis=axis)
    axis = _resolve_axis(mesh, axis)
    nd = mesh.shape[axis]
    src = jnp.asarray(src).astype(jnp.int32)
    dst = jnp.asarray(dst).astype(jnp.int32)
    a = jnp.concatenate([src, dst])
    b = jnp.concatenate([dst, src])
    # Pad the edge shard to a device multiple with (0, 0) self-loops --
    # inert under both hook conditions (SV2 needs Db < Da, SV3 Da != Db).
    m2 = int(a.shape[0])
    mp = max(-(-m2 // nd) * nd, nd)
    a, b = _pad_to(a, mp, 0), _pad_to(b, mp, 0)
    return _sharded_sv(
        a, b, num_nodes=num_nodes, max_rounds=max_rounds, mesh=mesh, axis=axis
    )


def cc_exchange_words_per_round(num_nodes: int) -> int:
    """int32 words a device sends per SV round: pmin(D2)+pmax(Q)+pmin(D3)."""
    return 3 * num_nodes


# ---------------------------------------------------------------------------
# Sharded random-splitter list ranking
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=("n", "p", "pp", "npad", "max_steps", "mesh", "axis"),
)
def _sharded_rs(succ, spl_pad, *, n, p, pp, npad, max_steps, mesh, axis):
    nd = mesh.shape[axis]
    lanes_per = pp // nd

    def block(succ, spl_all):
        dev = jax.lax.axis_index(axis)
        # RS1/RS2 (replicated): stop set + ownership seed from the full
        # splitter list; every device computes the identical init.
        spl = spl_all[:p]
        all_lanes = jnp.arange(p, dtype=jnp.int32)
        is_stop = jnp.zeros((n,), jnp.bool_).at[spl].set(True)
        packed = jnp.full((n, 2), -1, jnp.int32)
        packed = packed.at[:, 0].set(0)
        packed = packed.at[spl, 1].set(all_lanes)

        # RS3 (partitioned by splitter block): device d walks global
        # lanes [d*lanes_per, (d+1)*lanes_per). Padded lanes (id >= p)
        # are masked inert.
        lanes = dev.astype(jnp.int32) * lanes_per + jnp.arange(
            lanes_per, dtype=jnp.int32
        )
        valid = lanes < p
        spl_loc = jax.lax.dynamic_slice(
            spl_all, (dev * lanes_per,), (lanes_per,)
        )
        state = dict(
            store=(packed,),
            cur=spl_loc,
            nxt=succ[spl_loc],
            dist=jnp.ones((lanes_per,), jnp.int32),
        )
        # Walk predicate + scatter are the single-device ones (shared
        # code); only the lane ids are offset and padded lanes masked.
        active_fn, step_fn = aos_walk_fns(succ, is_stop, lanes, valid=valid)
        final, steps = lockstep_walk(
            state, active_fn, step_fn, max_steps=max_steps
        )
        (pk,) = final["store"]

        # Merge the stores: sub-lists partition the nodes, so each node
        # was written by exactly one device (local >= 1 over init 0,
        # owner >= 0 over init -1) -> pmax is a lossless union. ONE
        # n-sized exchange for the whole walk phase.
        local = jax.lax.pmax(pk[:, 0], axis)
        owner = jax.lax.pmax(pk[:, 1], axis)

        # RS4 (gathered): the p-lane splitter list fits one device's
        # VMEM; all-gather the per-lane walk results and rank the list
        # redundantly on every replica.
        dist_full = jax.lax.all_gather(final["dist"], axis, axis=0, tiled=True)[:p]
        nxt_full = jax.lax.all_gather(final["nxt"], axis, axis=0, tiled=True)[:p]
        spsucc = owner[nxt_full]
        is_term = spsucc == all_lanes
        w_adj = dist_full - is_term.astype(jnp.int32)
        iters = max(1, math.ceil(math.log2(max(p, 2))))
        rank_sp = _splitter_list_rank(w_adj, spsucc, iters)

        # RS5 (sharded back out): each device aggregates its node block;
        # the ranks come out already partitioned over the mesh.
        blk = npad // nd
        own_blk = jax.lax.dynamic_slice(
            _pad_to(owner, npad, 0), (dev * blk,), (blk,)
        )
        loc_blk = jax.lax.dynamic_slice(
            _pad_to(local, npad, 0), (dev * blk,), (blk,)
        )
        rank_blk = rank_sp[own_blk] - loc_blk

        steps = jax.lax.pmax(steps, axis)  # global trip count
        return rank_blk, dist_full, steps

    return compat.shard_map(
        block,
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=(P(axis), P(), P()),
        check_vma=False,
    )(succ, spl_pad)


def sharded_random_splitter_rank(
    succ: Array | np.ndarray,
    num_splitters: int | None = None,
    *,
    splitters: np.ndarray | None = None,
    head: int = 0,
    seed: int = 0,
    mesh: Mesh | None = None,
    axis: str = GRAPH_AXIS,
    max_steps: int | None = None,
    with_stats: bool = False,
):
    """Multi-device list ranking; bit-exact vs ``random_splitter_rank``.

    Splitter selection (RS1/RS2) is identical to the single-device path
    (same KISS streams, same seed), so the two implementations rank the
    same sub-lists and produce identical integer ranks.
    """
    mesh = mesh if mesh is not None else graph_mesh(axis=axis)
    axis = _resolve_axis(mesh, axis)
    nd = mesh.shape[axis]
    succ = jnp.asarray(succ).astype(jnp.int32)
    n = int(succ.shape[0])
    if splitters is None:
        p = num_splitters or min(4096, max_splitters_for_linear_work(n))
        p = min(p, n)
        splitters = select_splitters(n, p, seed=seed, head=head)
    splitters = np.asarray(splitters)
    p = len(splitters)
    pp = max(-(-p // nd) * nd, nd)  # lane padding (masked inert)
    npad = max(-(-n // nd) * nd, nd)  # node padding for the RS5 out shard
    spl_pad = _pad_to(jnp.asarray(splitters, jnp.int32), pp, 0)
    rank_pad, sublens, steps = _sharded_rs(
        succ,
        spl_pad,
        n=n,
        p=p,
        pp=pp,
        npad=npad,
        max_steps=max_steps,
        mesh=mesh,
        axis=axis,
    )
    rank = rank_pad[:n]
    if not with_stats:
        return rank
    stats = SplitterStats(
        splitters=np.asarray(splitters),
        sublist_lengths=np.asarray(sublens),
        walk_steps=int(steps),
        expected_mean=n / p,
    )
    return rank, stats


def rank_exchange_words(n: int, p: int, num_devices: int) -> int:
    """int32 words a device sends for one sharded ranking call:
    pmax(local)+pmax(owner) (2n) + two lane all-gathers (2p)."""
    del num_devices  # replicated-label scheme: volume is device-local
    return 2 * n + 2 * p
