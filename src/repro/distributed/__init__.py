"""Distribution substrate: sharding rules, pipeline stages, collectives."""
