"""Distribution substrate: sharding rules, pipeline stages, collectives,
and the edge-partitioned multi-device graph engine (``graph``)."""
