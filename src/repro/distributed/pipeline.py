"""GPipe-style pipeline parallelism over a mesh axis (usually "pod").

Stages hold contiguous layer groups; microbatches stream through a
`ppermute` ring inside one shard_map. Differentiable (shard_map + ppermute
both have transposes), so the same construct trains.

Schedule: T = num_microbatches + num_stages - 1 ticks. At tick t, stage s
processes microbatch (t - s) when 0 <= t - s < M. Bubble fraction =
(S-1)/(T) as usual; the perf log discusses overlap options.

This module is deliberately model-agnostic: it pipelines any
``layer_fn(carry, layer_params) -> carry`` applied over a stacked layer
pytree, e.g. a transformer block stack.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import Mesh, shard_map

Array = jax.Array


def pipeline_apply(
    layer_fn: Callable[[Array, Any], Array],
    stacked_params: Any,  # leaves (num_stages, layers_per_stage, ...)
    x_microbatches: Array,  # (num_microbatches, mb, ...) input activations
    mesh: Mesh,
    stage_axis: str = "pod",
) -> Array:
    """Run the pipeline; returns (num_microbatches, mb, ...) outputs."""
    num_stages = mesh.shape[stage_axis]
    num_mb = x_microbatches.shape[0]
    ticks = num_mb + num_stages - 1

    def block(params_s, xs):
        # params_s: (layers_per_stage, ...) for MY stage (shard_map slices)
        # xs: full (num_microbatches, mb, ...) -- only stage 0 consumes it.
        params_s = jax.tree.map(lambda a: a[0], params_s)  # drop stage dim
        sid = jax.lax.axis_index(stage_axis)
        mb_shape = xs.shape[1:]
        buf = jnp.zeros((num_mb,) + mb_shape, xs.dtype)  # outputs (last stage)
        state = jnp.zeros(mb_shape, xs.dtype)  # inflight activation

        def stage_compute(x):
            def body(carry, lp):
                return layer_fn(carry, lp), None
            out, _ = jax.lax.scan(body, x, params_s)
            return out

        def tick(t, carry):
            state, buf = carry
            mb_idx = t - sid
            active = jnp.logical_and(mb_idx >= 0, mb_idx < num_mb)
            # stage 0 reads its microbatch from xs; others use recv state
            x_in = jnp.where(
                sid == 0,
                jax.lax.dynamic_index_in_dim(
                    xs, jnp.clip(mb_idx, 0, num_mb - 1), 0, keepdims=False
                ),
                state,
            )
            y = stage_compute(x_in)
            y = jnp.where(active, y, state)
            # last stage deposits finished microbatch into buf
            deposit = jnp.logical_and(sid == num_stages - 1, active)
            buf = jax.lax.cond(
                deposit,
                lambda b: jax.lax.dynamic_update_index_in_dim(
                    b, y, jnp.clip(mb_idx, 0, num_mb - 1), 0
                ),
                lambda b: b,
                buf,
            )
            # ring-shift activations to the next stage
            state = jax.lax.ppermute(
                y,
                stage_axis,
                [(i, (i + 1) % num_stages) for i in range(num_stages)],
            )
            return state, buf

        _state, buf = jax.lax.fori_loop(0, ticks, tick, (state, buf))
        # all stages return buf; only the last stage's is nonzero -> psum
        # is a cheap way to broadcast it (every other contribution is 0).
        return jax.lax.psum(buf, stage_axis)

    spec_params = jax.tree.map(lambda _: P(stage_axis), stacked_params)
    return shard_map(
        block,
        mesh=mesh,
        in_specs=(spec_params, P()),
        out_specs=P(),
        check_vma=False,
    )(stacked_params, x_microbatches)
