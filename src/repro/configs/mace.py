"""mace [arXiv:2206.07697]: 2 layers, 128 channels, l_max=2,
correlation order 3, 8 radial basis functions, E(3)-equivariant."""
from repro.configs.gnn_family import GNNArch
from repro.models.gnn import mace
from repro.models.gnn.mace import MACEConfig

CONFIG = MACEConfig(
    name="mace", num_layers=2, channels=128, l_max=2, correlation=3,
    n_rbf=8, num_species=64,
)
SMOKE_CONFIG = MACEConfig(
    name="mace-smoke", num_layers=1, channels=16, l_max=2, correlation=3,
    n_rbf=4, num_species=5,
)

ARCH = GNNArch(
    name="mace", module=mace, config=CONFIG, smoke_config=SMOKE_CONFIG,
    geometric=True,
)
