"""LM-family dry-run/arch plumbing shared by the five assigned LM configs.

Shapes (per assignment):
  train_4k     seq 4096,   global_batch 256   (train_step)
  prefill_32k  seq 32768,  global_batch 32    (serve prefill forward)
  decode_32k   seq 32768,  global_batch 128   (serve_step, KV cache)
  long_500k    seq 524288, global_batch 1     (serve_step; SWA archs only)

REPRO_OPT_LEVEL=0 reproduces the paper-faithful baseline schedules;
the default (1) enables the beyond-paper optimizations recorded in
EXPERIMENTS.md section Perf:
  - ZeRO reduce-scatter gradient accumulation (vs per-microbatch
    all-reduce of full gradients),
  - fewer microbatches for the dense LMs (activation memory allows it),
  - fp8 MoE dispatch all-to-all (DeepSeek-style), set per-config.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass, replace
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import Mesh
from repro.configs.common import (
    DryRunSpec,
    dp_axes,
    named,
    sds,
    zero_spec_tree,
)
from repro.launch import perfmodel as pm
from repro.launch.mesh import mesh_num_chips
from repro.distributed.sharding import PathRules, ShardingRules
from repro.models.transformer import (
    TransformerConfig,
    init_kv_cache,
    init_params,
    loss_fn,
    serve_step,
)
from repro.models.transformer import forward as lm_forward
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

LM_SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def lm_path_rules(cfg: TransformerConfig, mesh: Mesh) -> PathRules:
    m = "model" if "model" in mesh.axis_names else None
    ep = None
    if cfg.moe is not None:
        ep_axes = tuple(a for a in cfg.moe.ep_axes if a in mesh.axis_names)
        if ep_axes and cfg.moe.num_experts % math.prod(
            mesh.shape[a] for a in ep_axes
        ) == 0:
            ep = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    rules = [
        (r"(^|/)embed$", P(m, None)),
        (r"(^|/)unembed$", P(None, m)),
        (r"mtp_layer/attn/w(q|q_a|q_b|kv_b)$", P(None, m)),
        (r"mtp_layer/attn/wo$", P(m, None)),
        (r"mtp_layer/ffn/w_(gate|up)$", P(None, m)),
        (r"mtp_layer/ffn/w_down$", P(m, None)),
        (r"mtp_layer/", P()),  # catch-all: unstacked ranks, keep replicated
        (r"moe/router$", P()),
        (r"moe/w_(gate|up)_shared$", P(None, None, m)),
        (r"moe/w_down_shared$", P(None, m, None)),
    ]
    if ep is not None:
        rules += [
            (r"moe/w_(gate|up|down)$", P(None, ep, None, None)),
        ]
    else:
        # expert-TP layout (Mixtral: 8 experts < 16-wide axis)
        rules += [
            (r"moe/w_(gate|up)$", P(None, None, None, m)),
            (r"moe/w_down$", P(None, None, m, None)),
        ]
    rules += [
        (r"attn/w(q|k|v|q_a|q_b|kv_b)$", P(None, None, m)),
        (r"attn/wo$", P(None, m, None)),
        (r"ffn/w_(gate|up)$", P(None, None, m)),
        (r"ffn/w_down$", P(None, m, None)),
    ]
    return PathRules(rules)


def _cache_specs(cfg: TransformerConfig, cache_abs, mesh: Mesh, batch: int):
    """Cache sharding: batch over (pod, data) when divisible, then kv-heads
    over model when divisible, else the sequence dim over model."""
    dp = dp_axes(mesh)
    dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
    batch_dim = dp if (dp and batch % dp_size == 0 and batch >= dp_size) else None
    msize = mesh.shape.get("model", 1)

    def spec_of(leaf):
        if leaf.ndim == 5:  # (L, B, C, hkv, hd)
            heads = leaf.shape[3]
            if heads % msize == 0 and msize > 1:
                return P(None, batch_dim, None, "model", None)
            if leaf.shape[2] % msize == 0:
                return P(None, batch_dim, "model", None, None)
            return P(None, batch_dim, None, None, None)
        # MLA latent: (L, B, C, r)
        if leaf.shape[2] % msize == 0:
            return P(None, batch_dim, "model", None)
        return P(None, batch_dim, None, None)

    return jax.tree.map(spec_of, cache_abs)


@dataclass
class LMArch:
    name: str
    config: TransformerConfig
    smoke_config: TransformerConfig
    sub_quadratic: bool = False  # SWA/SSM/linear-attn -> can run long_500k
    train_microbatches: int = 8
    moment_dtype: str = "float32"
    family: str = "lm"

    def shapes(self):
        return list(LM_SHAPES)

    def skip_reason(self, shape: str) -> str | None:
        if shape == "long_500k" and not self.sub_quadratic:
            return (
                "full quadratic attention; 500k-token decode excluded per "
                "assignment (run only for SSM/hybrid/sliding-window archs)"
            )
        return None

    # ------------------------------------------------------------------
    def build(self, shape: str, mesh: Mesh) -> DryRunSpec:
        info = LM_SHAPES[shape]
        cfg = self.config
        rules = ShardingRules().for_mesh(mesh)
        params_abs = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg)
        )
        pspecs = lm_path_rules(cfg, mesh).spec_tree(params_abs)
        batch, seq = info["batch"], info["seq"]
        dp = dp_axes(mesh)
        n_active = cfg.active_params()
        chips = mesh_num_chips(mesh)

        if info["kind"] == "train":
            opt_cfg = AdamWConfig(moment_dtype=self.moment_dtype)
            opt_abs = jax.eval_shape(partial(init_opt_state, cfg=opt_cfg), params_abs)
            ospecs = {
                "step": P(),
                "m": zero_spec_tree(pspecs, params_abs, mesh, dp),
                "v": zero_spec_tree(pspecs, params_abs, mesh, dp),
            }
            batch_abs = {
                "tokens": sds((batch, seq), jnp.int32),
                "labels": sds((batch, seq), jnp.int32),
            }
            bspecs = {"tokens": P(dp, None), "labels": P(dp, None)}
            opt_level = int(os.environ.get("REPRO_OPT_LEVEL", "1"))
            nmb = self.train_microbatches
            if opt_level and cfg.moe is None:
                # dense LMs fit larger microbatches; fewer accumulation
                # rounds = fewer cross-replica gradient reductions
                nmb = min(nmb, 2)
            grad_specs = zero_spec_tree(pspecs, params_abs, mesh, dp)

            def _zero_constrain(g):
                # Pin gradients to the ZeRO (moment) layout: XLA then emits
                # reduce-scatter per microbatch instead of all-reduce of
                # full gradients (the dominant baseline collective).
                if not opt_level:
                    return g
                return jax.tree.map(
                    lambda x, s: jax.lax.with_sharding_constraint(
                        x, NamedSharding(mesh, s)
                    ),
                    g,
                    grad_specs,
                )

            def train_step(params, opt_state, b):
                def loss_of(p, bb):
                    return loss_fn(p, cfg, bb, mesh=mesh, rules=rules)

                if nmb > 1:
                    def body(carry, i):
                        acc_l, acc_g = carry
                        mb = jax.tree.map(
                            lambda x: jax.lax.dynamic_slice_in_dim(
                                x, i * (x.shape[0] // nmb), x.shape[0] // nmb, 0
                            ),
                            b,
                        )
                        l, g = jax.value_and_grad(loss_of)(params, mb)
                        g = _zero_constrain(g)
                        return (acc_l + l, jax.tree.map(jnp.add, acc_g, g)), None

                    zeros = _zero_constrain(
                        jax.tree.map(
                            lambda p: jnp.zeros(p.shape, jnp.float32), params
                        )
                    )
                    (l, g), _ = jax.lax.scan(
                        body, (jnp.float32(0), zeros), jnp.arange(nmb)
                    )
                    l, g = l / nmb, jax.tree.map(lambda x: x / nmb, g)
                else:
                    l, g = jax.value_and_grad(loss_of)(params, b)
                    g = _zero_constrain(g)
                params, opt_state, _m = adamw_update(g, opt_state, params, opt_cfg)
                return params, opt_state, l

            return DryRunSpec(
                fn=train_step,
                args=(params_abs, opt_abs, batch_abs),
                in_shardings=(
                    named(mesh, pspecs),
                    named(mesh, ospecs),
                    named(mesh, bspecs),
                ),
                donate_argnums=(0, 1),
                model_flops_total=6.0 * n_active * batch * seq,
                flops_total=pm.lm_train_flops(cfg, batch, seq),
                hbm_bytes_per_device=pm.lm_train_bytes_per_device(
                    cfg, batch, seq, chips,
                    moment_dtype=self.moment_dtype, microbatches=nmb,
                ),
                note=f"microbatches={nmb} moment_dtype={self.moment_dtype}",
            )

        if info["kind"] == "prefill":
            batch_abs = sds((batch, seq), jnp.int32)
            bspec = P(dp, None)

            def fwd(params, tokens):
                return lm_forward(params, cfg, tokens, mesh=mesh, rules=rules)

            return DryRunSpec(
                fn=fwd,
                args=(params_abs, batch_abs),
                in_shardings=(named(mesh, pspecs), named(mesh, P(dp, None))),
                model_flops_total=2.0 * n_active * batch * seq,
                flops_total=pm.lm_prefill_flops(cfg, batch, seq),
                hbm_bytes_per_device=pm.lm_prefill_bytes_per_device(
                    cfg, batch, seq, chips
                ),
            )

        # decode
        cache_abs = jax.eval_shape(
            partial(init_kv_cache, cfg, batch, seq)
        )
        cspecs = _cache_specs(cfg, cache_abs, mesh, batch)
        dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
        bdim = dp if (dp and batch % dp_size == 0 and batch >= dp_size) else None
        tok_abs = sds((batch, 1), jnp.int32)
        decode_rules = replace(rules, batch=bdim)

        def step(params, cache, tokens):
            return serve_step(
                params, cfg, cache, tokens, jnp.int32(seq - 1),
                mesh=mesh, rules=decode_rules,
            )

        return DryRunSpec(
            fn=step,
            args=(params_abs, cache_abs, tok_abs),
            in_shardings=(
                named(mesh, pspecs),
                named(mesh, cspecs),
                named(mesh, P(bdim, None)),
            ),
            donate_argnums=(1,),
            model_flops_total=2.0 * n_active * batch,
            flops_total=pm.lm_decode_flops(cfg, batch, seq),
            hbm_bytes_per_device=pm.lm_decode_bytes_per_device(
                cfg, batch, seq, chips
            ),
            note="one decode token against a seq_len KV cache",
        )
