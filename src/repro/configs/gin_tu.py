"""gin-tu [arXiv:1810.00826]: 5 layers, d_hidden=64, sum aggregator,
learnable eps."""
from repro.configs.gnn_family import GNNArch
from repro.models.gnn import gin
from repro.models.gnn.gin import GINConfig

CONFIG = GINConfig(name="gin-tu", num_layers=5, d_hidden=64, eps_learnable=True)
SMOKE_CONFIG = GINConfig(
    name="gin-tu-smoke", num_layers=2, d_hidden=16, in_dim=8, num_classes=3
)

ARCH = GNNArch(
    name="gin-tu", module=gin, config=CONFIG, smoke_config=SMOKE_CONFIG
)
