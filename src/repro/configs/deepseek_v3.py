"""deepseek-v3-671b [arXiv:2412.19437]: 61L d=7168 128H MLA d_ff_expert=2048
vocab=129280, MoE 1 shared + 256 routed top-8, MTP depth 1.

Distribution: experts shard over the flat ("data", "model") = 256-device EP
axis per pod; optimizer moments in bf16 + ZeRO over the data axes so the
671B state fits 512 x 16GB (see DESIGN.md section 4 and EXPERIMENTS.md).
"""
import os

from repro.configs.lm_family import LMArch
from repro.models.transformer import MoEConfig, TransformerConfig

# REPRO_OPT_LEVEL=0 -> paper-faithful bf16 dispatch; default enables the
# fp8 dispatch all-to-all (EXPERIMENTS.md section Perf, deepseek train_4k).
_A2A_DTYPE = (
    None if os.environ.get("REPRO_OPT_LEVEL", "1") == "0" else "float8_e4m3fn"
)

CONFIG = TransformerConfig(
    name="deepseek-v3-671b",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,  # MLA: heads share one latent cache
    head_dim=128,
    d_ff=18432,  # the 3 leading dense layers
    vocab_size=129280,
    activation="silu",
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        capacity_factor=1.25,
        ep_axes=("data", "model"),
        a2a_dtype=_A2A_DTYPE,
    ),
    num_dense_layers=3,
    mtp_depth=1,
    rope_theta=10000.0,
)

SMOKE_CONFIG = TransformerConfig(
    name="deepseek-v3-smoke",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    attention="mla",
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_rope_head_dim=8,
    qk_nope_head_dim=16,
    v_head_dim=16,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32, num_shared_experts=1),
    num_dense_layers=1,
    mtp_depth=1,
    dtype="float32",
    remat=False,
)

ARCH = LMArch(
    name="deepseek-v3-671b",
    config=CONFIG,
    smoke_config=SMOKE_CONFIG,
    train_microbatches=8,
    moment_dtype="bfloat16",
)
