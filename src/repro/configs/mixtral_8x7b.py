"""mixtral-8x7b [arXiv:2401.04088]: 32L d=4096 32H GQA(kv=8) d_ff=14336
vocab=32000, 8 experts top-2, sliding-window attention (w=4096).

SWA makes attention O(n*w): the ONLY assigned LM arch that runs long_500k
(ring-buffer window KV cache keeps the 524288-token decode cache at 4096).
Experts (8) don't divide the 16-wide model axis -> expert-TP schedule.
"""
from repro.configs.lm_family import LMArch
from repro.models.transformer import MoEConfig, TransformerConfig

CONFIG = TransformerConfig(
    name="mixtral-8x7b",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    activation="silu",
    sliding_window=4096,
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        d_ff_expert=14336,
        num_shared_experts=0,
        capacity_factor=1.25,
    ),
    num_dense_layers=0,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = TransformerConfig(
    name="mixtral-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    sliding_window=8,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32),
    dtype="float32",
    remat=False,
)

ARCH = LMArch(
    name="mixtral-8x7b",
    config=CONFIG,
    smoke_config=SMOKE_CONFIG,
    sub_quadratic=True,  # SWA
    train_microbatches=4,
    moment_dtype="bfloat16",
)
