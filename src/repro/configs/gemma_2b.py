"""gemma-2b [arXiv:2403.08295]: 18L d=2048 8H MQA(kv=1) d_ff=16384
vocab=256000, GeGLU, head_dim=256, tied embeddings, sqrt(d) embed scale."""
from repro.configs.lm_family import LMArch
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="gemma-2b",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="gelu_tanh",  # GeGLU
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
)

SMOKE_CONFIG = TransformerConfig(
    name="gemma-2b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    activation="gelu_tanh",
    embed_scale=True,
    tie_embeddings=True,
    dtype="float32",
    remat=False,
)

ARCH = LMArch(name="gemma-2b", config=CONFIG, smoke_config=SMOKE_CONFIG)
