"""RecSys-family dry-run plumbing for xdeepfm.

Shapes (per assignment):
  train_batch     batch=65,536              (train_step)
  serve_p99       batch=512                 (online inference)
  serve_bulk      batch=262,144             (offline scoring)
  retrieval_cand  batch=1, 1e6 candidates   (retrieval scoring)

The embedding table (39 fields x 1e6 rows x dim 10) is row-sharded over the
"model" axis; lookups run through ops.sharded_lookup (partial gather +
psum). Optimizer moments are ZeRO-sharded over the data axes.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import Mesh
from repro.configs.common import (
    DryRunSpec,
    dp_axes,
    named,
    sds,
    zero_spec_tree,
)
from repro.launch import perfmodel as pm
from repro.launch.mesh import mesh_num_chips
from repro.models.recsys import xdeepfm as xm
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

RECSYS_SHAPES = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="retrieval", batch=1),
}


def recsys_param_specs(params_abs, mesh: Mesh):
    m = "model" if "model" in mesh.axis_names else None

    def spec(path, leaf):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if name in ("table", "linear", "cand_embed"):
            return P(m, None)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(params_abs)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat]
    )


@dataclass
class RecsysArch:
    name: str
    config: xm.XDeepFMConfig
    smoke_config: xm.XDeepFMConfig
    family: str = "recsys"

    def shapes(self):
        return list(RECSYS_SHAPES)

    def skip_reason(self, shape: str) -> str | None:
        return None

    def build(self, shape: str, mesh: Mesh) -> DryRunSpec:
        info = RECSYS_SHAPES[shape]
        cfg = self.config
        batch = info["batch"]
        dp = dp_axes(mesh)
        dp_size = math.prod(mesh.shape[a] for a in dp) if dp else 1
        bdim = dp if (dp and batch % dp_size == 0 and batch >= dp_size) else None

        params_abs = jax.eval_shape(
            lambda: xm.init_params(jax.random.PRNGKey(0), cfg)
        )
        pspecs = recsys_param_specs(params_abs, mesh)
        # dense-compute flops per example: CIN + MLP mac counts
        m_f, d_e = cfg.n_fields, cfg.embed_dim
        cin_macs = 0
        h_prev = m_f
        for h in cfg.cin_layers:
            cin_macs += h * h_prev * m_f * d_e
            h_prev = h
        mlp_macs = 0
        d_in = m_f * d_e
        for d_out in cfg.mlp_layers:
            mlp_macs += d_in * d_out
            d_in = d_out
        per_example = 2 * (cin_macs + mlp_macs)

        if info["kind"] == "train":
            opt_cfg = AdamWConfig(lr=1e-3, moment_dtype="float32")
            opt_abs = jax.eval_shape(
                partial(init_opt_state, cfg=opt_cfg), params_abs
            )
            ospecs = {
                "step": P(),
                "m": zero_spec_tree(pspecs, params_abs, mesh, dp),
                "v": zero_spec_tree(pspecs, params_abs, mesh, dp),
            }
            batch_abs = {
                "sparse_ids": sds((batch, cfg.n_fields), jnp.int32),
                "labels": sds((batch,), jnp.int32),
            }
            bspecs = {"sparse_ids": P(bdim, None), "labels": P(bdim)}

            def train_step(params, opt_state, b):
                l, g = jax.value_and_grad(
                    lambda p: xm.loss_fn(p, cfg, b)
                )(params)
                params, opt_state, _ = adamw_update(g, opt_state, params, opt_cfg)
                return params, opt_state, l

            return DryRunSpec(
                fn=train_step,
                args=(params_abs, opt_abs, batch_abs),
                in_shardings=(
                    named(mesh, pspecs),
                    named(mesh, ospecs),
                    named(mesh, bspecs),
                ),
                donate_argnums=(0, 1),
                model_flops_total=3.0 * per_example * batch,  # fwd+bwd
                flops_total=pm.recsys_step_flops(cfg, batch, train=True),
                hbm_bytes_per_device=pm.recsys_bytes_per_device(
                    cfg, batch, mesh_num_chips(mesh), train=True
                ),
            )

        if info["kind"] == "serve":
            batch_abs = {"sparse_ids": sds((batch, cfg.n_fields), jnp.int32)}
            bspecs = {"sparse_ids": P(bdim, None)}

            def serve(params, b):
                return xm.serve_step(params, cfg, b)

            return DryRunSpec(
                fn=serve,
                args=(params_abs, batch_abs),
                in_shardings=(named(mesh, pspecs), named(mesh, bspecs)),
                model_flops_total=float(per_example * batch),
                flops_total=pm.recsys_step_flops(cfg, batch, train=False),
                hbm_bytes_per_device=pm.recsys_bytes_per_device(
                    cfg, batch, mesh_num_chips(mesh), train=False
                ),
            )

        # retrieval: 1 query x n_candidates batched dot
        batch_abs = {"sparse_ids": sds((batch, cfg.n_fields), jnp.int32)}
        bspecs = {"sparse_ids": P(None, None)}

        def retrieve(params, b):
            scores, top = xm.serve_retrieval(params, cfg, b, top_k=100)
            return top

        flops = 2.0 * cfg.n_candidates * cfg.retrieval_dim + per_example
        chips = mesh_num_chips(mesh)
        cand_bytes = 4.0 * cfg.n_candidates * cfg.retrieval_dim / chips
        return DryRunSpec(
            fn=retrieve,
            args=(params_abs, batch_abs),
            in_shardings=(named(mesh, pspecs), named(mesh, bspecs)),
            model_flops_total=flops,
            flops_total=flops,
            hbm_bytes_per_device=cand_bytes
            + pm.recsys_bytes_per_device(cfg, batch, chips, train=False),
        )
