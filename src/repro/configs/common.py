"""Shared dry-run plumbing: DryRunSpec, ZeRO spec derivation, helpers."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import Mesh


@dataclass
class DryRunSpec:
    """Everything dryrun.py needs to lower+compile one (arch x shape) cell."""

    fn: Callable
    args: tuple  # pytrees of ShapeDtypeStruct
    in_shardings: Any
    out_shardings: Any = None
    donate_argnums: tuple = ()
    model_flops_total: float = 0.0  # 6*N*D train / 2*N*D inference (useful)
    flops_total: float | None = None  # analytic whole-step flops (perfmodel)
    hbm_bytes_per_device: float | None = None  # analytic HBM traffic
    note: str = ""


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def abstract_like(fn, *args, **kwargs):
    return jax.eval_shape(fn, *args, **kwargs)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _spec_axes(spec: P) -> set:
    used = set()
    for dim in spec:
        if dim is None:
            continue
        if isinstance(dim, str):
            used.add(dim)
        else:
            used.update(dim)
    return used


def zero_spec(spec: P, shape: tuple[int, ...], mesh: Mesh,
              extra_axes: tuple[str, ...]) -> P:
    """ZeRO-1: extend a param spec with `extra_axes` on the largest
    unsharded, divisible dim. Falls back to fewer axes, then to the
    original spec (always correct, just less sharded)."""
    extra = tuple(a for a in extra_axes if a in mesh.axis_names)
    used = _spec_axes(spec)
    extra = tuple(a for a in extra if a not in used)
    parts = list(tuple(spec) + (None,) * (len(shape) - len(spec)))
    for axes_try in (extra, extra[:1]):
        if not axes_try:
            continue
        size = math.prod(mesh.shape[a] for a in axes_try)
        cands = [
            i for i, dim in enumerate(parts)
            if dim is None and shape[i] % size == 0 and shape[i] >= size
        ]
        if cands:
            best = max(cands, key=lambda i: shape[i])
            parts[best] = axes_try if len(axes_try) > 1 else axes_try[0]
            return P(*parts)
    return P(*parts)


def zero_spec_tree(spec_tree, shape_tree, mesh: Mesh,
                   extra_axes: tuple[str, ...]):
    return jax.tree.map(
        lambda s, l: zero_spec(s, tuple(l.shape), mesh, extra_axes),
        spec_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def flat_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult
