"""The paper's own workloads as selectable configs.

These drive the benchmarks (Tables 2-4, Figures 2-6) and the quickstart:
  listrank-<n>   random-splitter list ranking, n list nodes
  cc-<family>    Shiloach-Vishkin connected components per graph family
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ListRankConfig:
    name: str = "listrank"
    n: int = 8_000_000
    num_splitters: int = 8192
    pack_mode: str = "aos"  # soa | aos | word64
    seed: int = 0


@dataclass(frozen=True)
class CCConfig:
    name: str = "cc"
    graph_family: str = "random"  # list | tree | random
    n: int = 1_000_000
    m: int = 8_000_000
    tree_degree: int = 3
    density: float = 0.001
    seed: int = 0


LISTRANK_DEFAULT = ListRankConfig()
CC_DEFAULT = CCConfig()
