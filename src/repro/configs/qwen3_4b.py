"""qwen3-4b [hf:Qwen/Qwen3-8B family]: 36L d=2560 32H GQA(kv=8) d_ff=9728
vocab=151936, qk-norm, head_dim=128."""
from repro.configs.lm_family import LMArch
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen3-4b",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    activation="silu",
    qk_norm=True,
    rope_theta=1_000_000.0,
)

SMOKE_CONFIG = TransformerConfig(
    name="qwen3-4b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    qk_norm=True,
    dtype="float32",
    remat=False,
)

ARCH = LMArch(name="qwen3-4b", config=CONFIG, smoke_config=SMOKE_CONFIG)
