"""xdeepfm [arXiv:1803.05170]: 39 sparse fields, embed_dim 10,
CIN 200-200-200, MLP 400-400, vocab 1e6 rows per field."""
from repro.configs.recsys_family import RecsysArch
from repro.models.recsys.xdeepfm import XDeepFMConfig

CONFIG = XDeepFMConfig(
    name="xdeepfm",
    n_fields=39,
    vocab_per_field=1_000_000,
    embed_dim=10,
    cin_layers=(200, 200, 200),
    mlp_layers=(400, 400),
    retrieval_dim=64,
    n_candidates=1_000_000,
)

SMOKE_CONFIG = XDeepFMConfig(
    name="xdeepfm-smoke",
    n_fields=8,
    vocab_per_field=1000,
    embed_dim=6,
    cin_layers=(16, 16),
    mlp_layers=(32, 32),
    retrieval_dim=8,
    n_candidates=512,
)

ARCH = RecsysArch(name="xdeepfm", config=CONFIG, smoke_config=SMOKE_CONFIG)
