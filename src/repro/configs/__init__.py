"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

_ARCH_MODULES = {
    "gemma-2b": "repro.configs.gemma_2b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "egnn": "repro.configs.egnn",
    "gat-cora": "repro.configs.gat_cora",
    "mace": "repro.configs.mace",
    "gin-tu": "repro.configs.gin_tu",
    "xdeepfm": "repro.configs.xdeepfm",
}

ARCH_NAMES = list(_ARCH_MODULES)


def get_arch(name: str):
    import importlib

    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_NAMES}")
    return importlib.import_module(_ARCH_MODULES[name]).ARCH


def all_cells() -> list[tuple[str, str]]:
    """All 40 (arch, shape) cells."""
    cells = []
    for name in ARCH_NAMES:
        arch = get_arch(name)
        for shape in arch.shapes():
            cells.append((name, shape))
    return cells
