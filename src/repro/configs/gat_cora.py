"""gat-cora [arXiv:1710.10903]: 2 layers, d_hidden=8, 8 heads, attention
aggregator (Cora: in 1433, 7 classes)."""
from repro.configs.gnn_family import GNNArch
from repro.models.gnn import gat
from repro.models.gnn.gat import GATConfig

CONFIG = GATConfig(
    name="gat-cora", num_layers=2, d_hidden=8, num_heads=8,
    in_dim=1433, num_classes=7,
)
SMOKE_CONFIG = GATConfig(
    name="gat-cora-smoke", num_layers=2, d_hidden=4, num_heads=2,
    in_dim=8, num_classes=3,
)

ARCH = GNNArch(
    name="gat-cora", module=gat, config=CONFIG, smoke_config=SMOKE_CONFIG
)
