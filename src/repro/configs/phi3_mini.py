"""phi3-mini-3.8b [arXiv:2404.14219]: 32L d=3072 32H GQA(kv=32) d_ff=8192
vocab=32064, RoPE + SwiGLU (MHA: kv == q heads)."""
from repro.configs.lm_family import LMArch
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="phi3-mini-3.8b",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    activation="silu",
    rope_theta=10000.0,
)

SMOKE_CONFIG = TransformerConfig(
    name="phi3-mini-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    dtype="float32",
    remat=False,
)

ARCH = LMArch(name="phi3-mini-3.8b", config=CONFIG, smoke_config=SMOKE_CONFIG)
