"""egnn [arXiv:2102.09844]: 4 layers, d_hidden=64, E(n)-equivariant."""
from repro.configs.gnn_family import GNNArch
from repro.models.gnn import egnn
from repro.models.gnn.egnn import EGNNConfig

CONFIG = EGNNConfig(name="egnn", num_layers=4, d_hidden=64)
SMOKE_CONFIG = EGNNConfig(
    name="egnn-smoke", num_layers=2, d_hidden=16, in_dim=8
)

ARCH = GNNArch(
    name="egnn", module=egnn, config=CONFIG, smoke_config=SMOKE_CONFIG,
    geometric=True,
)
