"""GNN-family dry-run plumbing for egnn / gat-cora / mace / gin-tu.

Shapes (per assignment):
  full_graph_sm   n=2,708    m=10,556       d_feat=1,433  (full-batch, Cora)
  minibatch_lg    n=232,965  m=114,615,892  batch=1,024 fanout 15-10 (Reddit)
  ogb_products    n=2,449,029 m=61,859,140  d_feat=100    (full-batch-large)
  molecule        n=30 m=64 per graph, batch=128          (batched-small)

Distribution: edges sharded over every mesh axis (the irregular dimension --
guideline G1 says sort + block them; the data pipeline pre-sorts by dst).
Node tensors are replicated for the small/invariant models; for the
equivariant models on big graphs the CHANNEL dim is model-sharded (MACE's
tensor products are channel-parallel), which keeps per-device irrep tensors
small while edges stay data-sharded.
"""
from __future__ import annotations

import math
import os
from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import Mesh
from repro.configs.common import DryRunSpec, dp_axes, flat_axes, named, pad_to, sds
from repro.launch import perfmodel as pm
from repro.launch.mesh import mesh_num_chips
from repro.models.gnn import egnn as egnn_mod
from repro.models.gnn import gat as gat_mod
from repro.models.gnn import gin as gin_mod
from repro.models.gnn import mace as mace_mod
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

# minibatch_lg sampled-block sizes (batch 1024, fanout 15 then 10):
#   frontier: 1024 -> 15,360 -> 153,600 ; padded union of nodes; edges
_MB_NODES = 1024 + 15360 + 153600
_MB_EDGES = 15360 + 153600

GNN_SHAPES = {
    "full_graph_sm": dict(n=2708, m=10556, d=1433, classes=7),
    "minibatch_lg": dict(n=_MB_NODES, m=_MB_EDGES, d=602, classes=41),
    "ogb_products": dict(n=2449029, m=61859140, d=100, classes=47),
    "molecule": dict(n=30 * 128, m=64 * 128, d=16, classes=1, graphs=128),
}


def _graph_abs(
    info, *, geometric: bool, label_kind: str, mesh: Mesh
) -> tuple[dict, dict]:
    """(ShapeDtypeStruct graph, PartitionSpec graph). num_graphs is static
    and injected by the step closure, not part of the traced args."""
    n, m, d = info["n"], info["m"], info["d"]
    graphs = info.get("graphs", 1)
    ea = flat_axes(mesh)
    esz = math.prod(mesh.shape[a] for a in ea)
    mp = pad_to(m, esz)
    g = {
        "src": sds((mp,), jnp.int32),
        "dst": sds((mp,), jnp.int32),
        "graph_ids": sds((n,), jnp.int32),
        "node_feats": sds((n, d), jnp.float32),
    }
    s = {"src": P(ea), "dst": P(ea), "graph_ids": P(), "node_feats": P()}
    if geometric:
        g["positions"] = sds((n, 3), jnp.float32)
        g["species"] = sds((n,), jnp.int32)
        s |= {"positions": P(), "species": P()}
    if label_kind == "node_int":
        g["labels"] = sds((n,), jnp.int32)
    elif label_kind == "graph_int":
        g["labels"] = sds((graphs,), jnp.int32)
    else:  # graph_float
        g["labels"] = sds((graphs,), jnp.float32)
    s["labels"] = P()
    return g, s


@dataclass
class GNNArch:
    name: str
    module: Any
    config: Any
    smoke_config: Any
    geometric: bool = False  # needs positions/species
    family: str = "gnn"

    def shapes(self):
        return list(GNN_SHAPES)

    def skip_reason(self, shape: str) -> str | None:
        return None

    def config_for(self, shape: str):
        """Specialize in_dim / readout / classes per shape."""
        import dataclasses

        info = GNN_SHAPES[shape]
        cfg = self.config
        kw: dict = {}
        if hasattr(cfg, "in_dim"):
            kw["in_dim"] = info["d"]
        if hasattr(cfg, "num_classes"):
            kw["num_classes"] = max(info["classes"], 2)
        if hasattr(cfg, "readout"):
            if self.geometric:
                kw["readout"] = "graph"  # energy-style regression
            else:
                kw["readout"] = "graph" if shape == "molecule" else "node"
        return dataclasses.replace(cfg, **kw)

    def label_kind(self, shape: str) -> str:
        if self.geometric:
            return "graph_float"
        cfg = self.config_for(shape)
        if getattr(cfg, "readout", "node") == "graph":
            return "graph_int"
        return "node_int"

    def build(self, shape: str, mesh: Mesh) -> DryRunSpec:
        info = GNN_SHAPES[shape]
        cfg = self.config_for(shape)
        mod = self.module
        graph_abs, graph_specs = _graph_abs(
            info, geometric=self.geometric,
            label_kind=self.label_kind(shape), mesh=mesh,
        )
        graphs = info.get("graphs", 1)

        params_abs = jax.eval_shape(
            lambda: mod.init_params(jax.random.PRNGKey(0), cfg)
        )
        # params replicated (tiny); moments too.
        pspecs = jax.tree.map(lambda _: P(), params_abs)
        opt_cfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
        opt_abs = jax.eval_shape(partial(init_opt_state, cfg=opt_cfg), params_abs)
        ospecs = jax.tree.map(lambda _: P(), opt_abs)

        # Beyond-paper hillclimb (REPRO_OPT_LEVEL!=0): MACE's tensor
        # products are channel-elementwise, so on the big graphs the node
        # irrep tensors shard their CHANNEL dim over "model" while edges
        # shard over the data axes -- the replicated-node all-reduce (the
        # baseline's dominant collective) shrinks by the model-axis factor.
        opt_level = int(os.environ.get("REPRO_OPT_LEVEL", "1"))
        msize = mesh.shape.get("model", 1)
        channel_shard = (
            bool(opt_level)
            and self.name == "mace"
            and shape in ("ogb_products", "minibatch_lg")
            and msize > 1
            and getattr(cfg, "channels", 0) % msize == 0
        )
        constrain = None
        if channel_shard:
            from jax.sharding import NamedSharding

            dp = dp_axes(mesh)
            graph_specs["src"] = P(dp)
            graph_specs["dst"] = P(dp)

            def constrain(t, kind):
                if kind == "node":
                    spec = P(None, "model", None)
                elif kind == "mix_in":
                    # C x C mixes contract over the sharded channel dim;
                    # re-layout to node-rows first so the transition is an
                    # all-to-all (~size/dp) instead of a channel all-gather
                    # (~full size). Perf log, mace iteration 2.
                    spec = P(dp, None, None)
                else:  # edge tensors: (edges, C, 2l+1)
                    spec = P(dp, "model", None)
                return jax.lax.with_sharding_constraint(
                    t, NamedSharding(mesh, spec)
                )

        def loss_of(p, g):
            kw = {}
            if constrain is not None:
                kw["constrain"] = constrain
            return mod.loss_fn(p, cfg, dict(g, num_graphs=graphs), **kw)

        def train_step(params, opt_state, g):
            l, grads = jax.value_and_grad(loss_of)(params, g)
            params, opt_state, _ = adamw_update(grads, opt_state, params, opt_cfg)
            return params, opt_state, l

        flops = pm.gnn_train_flops(self.name, cfg, info["n"], info["m"], info["d"])
        chips = mesh_num_chips(mesh)
        return DryRunSpec(
            fn=train_step,
            args=(params_abs, opt_abs, graph_abs),
            in_shardings=(
                named(mesh, pspecs),
                named(mesh, ospecs),
                named(mesh, graph_specs),
            ),
            donate_argnums=(0, 1),
            model_flops_total=flops,
            flops_total=flops,
            hbm_bytes_per_device=pm.gnn_train_bytes_per_device(
                self.name, cfg, info["n"], info["m"], info["d"], chips
            ),
            note=(
                f"edge-parallel; channel_shard={channel_shard} "
                f"(REPRO_OPT_LEVEL={opt_level})"
            ),
        )
