"""Synthetic LM token stream: KISS-generated Zipf-ish token ids.

Deterministic per (seed, step) so a restarted/resumed job replays the same
batches -- a fault-tolerance requirement, not a nicety.
"""
from __future__ import annotations

import numpy as np

from repro.ops.kiss import KissRng


def lm_batch(
    batch: int, seq_len: int, vocab: int, *, seed: int = 0, step: int = 0
) -> dict:
    rng = KissRng(seed * 1_000_003 + step, n_streams=4096)
    u = rng.uniform_ints((batch, seq_len + 1), 1 << 30).astype(np.float64)
    # Zipf-ish skew: squash uniform draws through a power law.
    z = (u / float(1 << 30)) ** 4.0
    toks = (z * (vocab - 1)).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}


def lm_iterator(batch: int, seq_len: int, vocab: int, seed: int = 0):
    from repro.data.pipeline import PrefetchIterator

    return PrefetchIterator(
        lambda i: lm_batch(batch, seq_len, vocab, seed=seed, step=i)
    )
