"""Graph dataset builders for the four assigned GNN shapes.

All graphs are KISS-generated with the paper's generators (ops/kiss.py) so
benchmarks, smoke tests and dry-runs share one distribution. Edges are
returned SORTED BY DESTINATION (guideline G1) with ``indices_are_sorted``
usable downstream.
"""
from __future__ import annotations

import numpy as np

from repro.ops.kiss import KissRng, random_graph
from repro.ops.neighbor_sampler import NeighborSampler, edges_to_csr


def _sort_by_dst(src: np.ndarray, dst: np.ndarray):
    order = np.argsort(dst, kind="stable")
    return src[order], dst[order]


def full_graph(
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    num_classes: int = 7,
    *,
    with_positions: bool = False,
    num_species: int = 10,
    seed: int = 0,
) -> dict:
    """Cora-like / products-like full-batch node-classification graph."""
    rng = KissRng(seed, 8192)
    ends = rng.uniform_ints((n_edges, 2), n_nodes)
    src, dst = _sort_by_dst(
        ends[:, 0].astype(np.int32), ends[:, 1].astype(np.int32)
    )
    feats = (
        rng.uniform_ints((n_nodes, d_feat), 1000).astype(np.float32) / 500.0 - 1.0
    )
    g = {
        "node_feats": feats,
        "src": src,
        "dst": dst,
        "labels": rng.uniform_ints((n_nodes,), num_classes).astype(np.int32),
        "graph_ids": np.zeros(n_nodes, np.int32),
        "num_graphs": 1,
    }
    if with_positions:
        g["positions"] = (
            rng.uniform_ints((n_nodes, 3), 2000).astype(np.float32) / 100.0
        )
        g["species"] = rng.uniform_ints((n_nodes,), num_species).astype(np.int32)
    return g


def molecule_batch(
    batch: int,
    nodes_per_graph: int = 30,
    edges_per_graph: int = 64,
    d_feat: int = 16,
    num_species: int = 10,
    seed: int = 0,
) -> dict:
    """Batched small molecules (single disjoint-union graph)."""
    rng = KissRng(seed, 4096)
    n = batch * nodes_per_graph
    m = batch * edges_per_graph
    ends = rng.uniform_ints((m, 2), nodes_per_graph)
    offs = np.repeat(
        np.arange(batch, dtype=np.int64) * nodes_per_graph, edges_per_graph
    )
    src, dst = _sort_by_dst(
        (ends[:, 0] + offs).astype(np.int32), (ends[:, 1] + offs).astype(np.int32)
    )
    return {
        "node_feats": rng.uniform_ints((n, d_feat), 1000).astype(np.float32)
        / 500.0
        - 1.0,
        "positions": rng.uniform_ints((n, 3), 2000).astype(np.float32) / 200.0,
        "species": rng.uniform_ints((n,), num_species).astype(np.int32),
        "src": src,
        "dst": dst,
        "labels": rng.uniform_ints((batch,), 1000).astype(np.float32) / 500.0 - 1.0,
        "graph_ids": np.repeat(
            np.arange(batch, dtype=np.int32), nodes_per_graph
        ),
        "num_graphs": batch,
    }


def sampled_minibatch(
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    batch_nodes: int,
    fanouts: list[int],
    num_classes: int = 41,
    seed: int = 0,
) -> dict:
    """minibatch_lg: a real neighbor-sampled block batch (Reddit-scale).

    The returned dict contains per-hop (src, dst_index) blocks flattened to
    one padded edge set over the union frontier, plus seed-node labels.
    """
    base_edges = random_graph(n_nodes, 2 * n_edges / (n_nodes * (n_nodes - 1)), seed)
    indptr, indices = edges_to_csr(base_edges, n_nodes)
    sampler = NeighborSampler(indptr, indices, seed=seed + 1)
    rng = KissRng(seed + 2, 4096)
    seeds = rng.uniform_ints((batch_nodes,), n_nodes).astype(np.int64)
    blocks = sampler.sample_multihop(seeds, fanouts)

    # Flatten blocks into one local graph: nodes = all frontier nodes.
    all_nodes = np.concatenate(
        [blocks[0].dst_nodes] + [b.src_nodes for b in blocks]
    )
    uniq, inv = np.unique(all_nodes, return_inverse=True)
    # positions of each hop's arrays inside `inv`
    out_src, out_dst = [], []
    cursor = len(blocks[0].dst_nodes)
    dst_local = {int(v): i for i, v in enumerate(blocks[0].dst_nodes)}
    frontier_local = inv[: len(blocks[0].dst_nodes)]
    prev_local = frontier_local
    prev_nodes = blocks[0].dst_nodes
    for b in blocks:
        src_local = inv[cursor : cursor + len(b.src_nodes)]
        cursor += len(b.src_nodes)
        out_src.append(src_local.astype(np.int32))
        out_dst.append(prev_local[b.dst_index].astype(np.int32))
        prev_local = src_local
        prev_nodes = b.src_nodes
    src = np.concatenate(out_src)
    dst = np.concatenate(out_dst)
    order = np.argsort(dst, kind="stable")
    feats = (
        KissRng(seed + 3, 4096)
        .uniform_ints((len(uniq), d_feat), 1000)
        .astype(np.float32)
        / 500.0
        - 1.0
    )
    labels = np.full(len(uniq), -1, np.int32)
    labels[frontier_local] = rng.uniform_ints(
        (batch_nodes,), num_classes
    ).astype(np.int32)
    return {
        "node_feats": feats,
        "src": src[order].astype(np.int32),
        "dst": dst[order].astype(np.int32),
        "labels": labels,
        "graph_ids": np.zeros(len(uniq), np.int32),
        "num_graphs": 1,
    }


def random_tree(n: int, seed: int = 0) -> np.ndarray:
    """Edge list (n-1, 2) of a uniform-attachment random tree.

    Node i > 0 attaches to a KISS-uniform earlier node, then the whole
    tree is KISS-relabeled so node ids carry no structure (the
    ``repro.trees`` input family: expected depth O(log n), arbitrary
    branching, unlike the balanced ``ops/kiss.tree_graph``).
    """
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    rng = KissRng(seed, n_streams=min(max(n, 1), 8192))
    if n == 1:
        return np.zeros((0, 2), np.int32)
    draws = rng.uniform_ints((n - 1,), 1 << 31)
    child = np.arange(1, n, dtype=np.int64)
    parent = draws % child  # uniform in [0, i) for node i
    keys = rng.uniform_ints((n,), 1 << 31)
    relabel = np.argsort(keys, kind="stable").astype(np.int32)
    return np.stack([relabel[parent], relabel[child]], axis=1).astype(np.int32)


def random_tree_forest(
    n: int, num_trees: int, seed: int = 0
) -> np.ndarray:
    """Edge list of ``num_trees`` disjoint uniform-attachment random
    trees over n nodes (KISS-random node partition): the batched
    many-small-trees workload ``repro.trees`` serves in one padded tour.
    """
    rng = KissRng(seed, n_streams=min(max(n, 1), 8192))
    keys = rng.uniform_ints((n,), 1 << 31)
    order = np.argsort(keys, kind="stable")
    pieces = np.array_split(order, max(num_trees, 1))
    edges = []
    for ci, nodes in enumerate(pieces):
        if len(nodes) < 2:
            continue
        local = random_tree(len(nodes), seed=seed * 7919 + ci + 1)
        edges.append(nodes[local])
    if not edges:
        return np.zeros((0, 2), np.int32)
    return np.concatenate(edges, axis=0).astype(np.int32)


def graph_request_stream(
    num_requests: int,
    *,
    min_nodes: int = 6,
    max_nodes: int = 40,
    edge_factor: float = 1.5,
    kind: str = "analytics",
    family: str = "random",
    seed: int = 0,
) -> list[dict]:
    """A KISS-deterministic stream of small independent graph requests
    -- the ``repro.serve.graph`` workload (many small molecule-scale
    graphs, one request each, NOT a pre-unioned batch like
    ``molecule_batch``). Each entry is ``{"src", "dst", "num_nodes",
    "kind"}``; sizes are KISS-uniform in ``[min_nodes, max_nodes]``.

    ``family="random"`` draws ``edge_factor * n`` uniform endpoint
    pairs (self-loops/duplicates included, as real request traffic has
    them); ``family="tree"`` builds uniform-attachment random trees
    (``random_tree``), the forest-shaped traffic the tree-analytics
    stage is tuned for.

    ``kind="sssp"`` entries additionally carry ``"weights"`` (KISS
    eighths in ``{0, 0.25, ..., 1.75}`` -- zero weights included on
    purpose, they are an adversarial tie-break case) and ``"sources"``
    (1-2 KISS-uniform nodes, duplicates allowed). ``kind="pagerank"``
    entries carry the same eighth-weights (zero weights exercise the
    dangling/zero-degree branch) but no sources -- PageRank scores
    every node.
    """
    if family not in ("random", "tree"):
        raise ValueError(f"unknown family {family!r}")
    rng = KissRng(seed, 4096)
    spans = rng.uniform_ints((max(num_requests, 1),),
                             max_nodes - min_nodes + 1)
    out = []
    for i in range(num_requests):
        n = min_nodes + int(spans[i])
        if family == "tree":
            edges = random_tree(n, seed=seed * 9973 + i + 1)
            src, dst = edges[:, 0].copy(), edges[:, 1].copy()
        else:
            m = max(1, int(edge_factor * n))
            ends = KissRng(seed * 9973 + i + 1, 1024).uniform_ints((m, 2), n)
            src = ends[:, 0].astype(np.int32)
            dst = ends[:, 1].astype(np.int32)
        entry = {"src": src, "dst": dst, "num_nodes": n, "kind": kind}
        if kind in ("sssp", "pagerank"):
            wrng = KissRng(seed * 6007 + i + 1, 1024)
            entry["weights"] = (
                wrng.uniform_ints((len(src),), 8).astype(np.float32) / 4.0
            )
            if kind == "sssp":
                k = 1 + int(spans[i] % 2)
                entry["sources"] = wrng.uniform_ints((k,), n).astype(
                    np.int32
                )
        out.append(entry)
    return out


def random_succ(n: int, seed: int = 0) -> np.ndarray:
    """Random linked-list succ[] with head 0 and self-loop terminal.

    Plain numpy (no KISS): this is the list-ranking INPUT generator shared
    by tests and benchmarks, not one of the paper's graph distributions.
    """
    r = np.random.default_rng(seed)
    order = (
        np.concatenate([[0], 1 + r.permutation(n - 1)])
        if n > 1
        else np.zeros(1, np.int64)
    )
    succ = np.empty(n, dtype=np.int32)
    succ[order[:-1]] = order[1:]
    succ[order[-1]] = order[-1]
    return succ
