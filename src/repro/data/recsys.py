"""Synthetic Criteo-like batches for xDeepFM."""
from __future__ import annotations

import numpy as np

from repro.ops.kiss import KissRng


def recsys_batch(
    batch: int, n_fields: int, vocab: int, *, seed: int = 0, step: int = 0
) -> dict:
    rng = KissRng(seed * 999_983 + step, n_streams=4096)
    ids = rng.uniform_ints((batch, n_fields), 1 << 30).astype(np.float64)
    # power-law id popularity (hot rows), matching real CTR logs
    ids = ((ids / float(1 << 30)) ** 3 * (vocab - 1)).astype(np.int32)
    labels = (rng.uniform_ints((batch,), 100) < 25).astype(np.int32)  # ~25% CTR
    return {"sparse_ids": ids, "labels": labels}
