"""Synthetic data pipelines (KISS-driven, as in the paper's experiments)."""
from repro.data.pipeline import PrefetchIterator

__all__ = ["PrefetchIterator"]
