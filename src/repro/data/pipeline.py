"""Host-side data pipeline with background prefetch (double buffering).

Straggler mitigation starts at the input pipeline: a slow host must never
stall the step; batches are produced by a daemon thread into a bounded
queue so the accelerator-side step overlaps host-side generation.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator


class PrefetchIterator:
    def __init__(self, make_batch: Callable[[int], dict], depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._idx = 0
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        i = 0
        while not self._stop.is_set():
            try:
                self._q.put(self._make(i), timeout=0.2)
                i += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()
