"""Spanning-forest extraction from Shiloach-Vishkin hook decisions.

Hooking-based connectivity produces a spanning forest as a by-product
(Hong, Dhulipala & Shun 2020): every hook event attaches one tree to
another through a real graph edge, a component of size c hooks exactly
c - 1 times, and min-CRCW hooks always point label-decreasing, so the
recorded edges are acyclic. ``repro.core.components.sv_round_fns``
records those winning edges when ``record_hooks=True`` (see
``init_hooks``); this module turns the raw ``(hook_u, hook_v)`` slots
into a compact forest object the tour layer consumes.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class SpanningForest:
    """A spanning forest of the input graph, one tree per component.

    ``edge_u``/``edge_v`` are the ``num_nodes - num_trees`` winning hook
    edges (each a real input edge); ``labels`` are the CC labels, i.e.
    the minimum node id of each component, which the tour layer uses as
    the canonical tree roots.
    """

    num_nodes: int
    labels: np.ndarray  # (n,) component root ids (min node id)
    rounds: int
    edge_u: np.ndarray  # (f,) forest edge endpoints
    edge_v: np.ndarray  # (f,)

    @property
    def num_edges(self) -> int:
        return int(self.edge_u.shape[0])

    @property
    def num_trees(self) -> int:
        return self.num_nodes - self.num_edges


def forest_from_hooks(
    hook_u, hook_v, labels, rounds, num_nodes: int
) -> SpanningForest:
    """Compact raw ``(hook_u, hook_v)`` slot arrays (sentinel n = never
    hooked) into a ``SpanningForest`` (host-side)."""
    hu = np.asarray(hook_u)
    hv = np.asarray(hook_v)
    mask = hu < num_nodes
    return SpanningForest(
        num_nodes=num_nodes,
        labels=np.asarray(labels),
        rounds=int(rounds),
        edge_u=hu[mask].astype(np.int32),
        edge_v=hv[mask].astype(np.int32),
    )


def spanning_forest(
    src,
    dst,
    num_nodes: int,
    *,
    max_rounds: int | None = None,
    mesh=None,
    engine: str = "auto",
    **kwargs,
) -> SpanningForest:
    """Connected components + spanning forest in one CC run.

    Thin wrapper over ``repro.core.connected_components(...,
    record_hooks=True)``: ``engine=`` (``"auto"`` default /
    ``"frontier"`` / ``"dense"`` / ``"sharded_frontier"``), ``mesh=``,
    ``max_rounds=``, and every engine kwarg (``min_bucket=``,
    ``hook_impl=``, ``exchange=``, ``sparse_capacity=``, ``axis=``,
    ``sample_rounds=``, ``seed=``, ``dedup=``) behave exactly as there
    -- see ``docs/engines.md`` for the full matrix -- and the
    labels/round counts are bit-identical to a plain CC call: hook
    recording only *reads* the round state. The recorded forest is
    itself engine-independent (ties break to the lexicographically
    smallest edge), except under a sampling pre-pass (``sample_rounds``)
    which hooks through sampled edges -- still a valid spanning forest,
    but a different one.
    """
    from repro.core import connected_components

    if kwargs.pop("record_hooks", True) is not True:
        raise ValueError("spanning_forest always records hooks")
    res = connected_components(
        src, dst, num_nodes, max_rounds=max_rounds, mesh=mesh,
        engine=engine, record_hooks=True, **kwargs,
    )
    labels, rounds, (hook_u, hook_v) = res[0], res[1], res[2]
    return forest_from_hooks(hook_u, hook_v, labels, rounds, num_nodes)
