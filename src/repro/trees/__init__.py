"""Euler-tour tree analytics: list ranking and connectivity, composed.

The paper's stated reason list ranking matters is the Euler-tour
technique for parallel tree computations; this package closes that loop
with three layers built entirely from primitives the repo already
trusts:

1. **forest** -- a spanning forest extracted from the hook decisions of
   Shiloach-Vishkin connected components (``record_hooks=True`` on any
   CC engine: dense, frontier-compacted, or sharded), bit-neutral to
   labels and round counts.
2. **tour** -- the Euler tour of that forest, built by sorted adjacency
   twinning (``ops/sorted_dispatch`` + ``ops/segment``): a successor
   array that is a ready-made input to the list-ranking engines.
3. **compute** -- tree computations (``root_tree``, ``depths``,
   ``subtree_sizes``, ``preorder``/``postorder``) as +-1-weighted ranks
   over the tour, dispatching through the same ``kernel_impl=`` /
   engine plumbing as ``list_rank``; a whole forest of small trees runs
   batched in one (optionally padded) tour.
"""
from repro.trees.forest import SpanningForest, spanning_forest
from repro.trees.tour import EulerTour, euler_tour, tour_capacity
from repro.trees.compute import (
    RANK_ENGINES,
    TreeAnalytics,
    TreeComputations,
    depths,
    postorder,
    preorder,
    root_tree,
    subtree_sizes,
    tour_ranks,
    tour_splitters,
    tree_analytics,
    tree_computations,
)

__all__ = [
    "SpanningForest",
    "spanning_forest",
    "EulerTour",
    "euler_tour",
    "tour_capacity",
    "RANK_ENGINES",
    "TreeAnalytics",
    "TreeComputations",
    "tour_ranks",
    "tour_splitters",
    "tree_computations",
    "tree_analytics",
    "root_tree",
    "depths",
    "subtree_sizes",
    "preorder",
    "postorder",
]
