"""Serial NumPy oracle for the Euler-tour tree computations.

Walks each tree's Euler circuit arc-by-arc in a Python loop -- no list
ranking, no prefix scans, no JAX -- maintaining DFS counters, so the
parallel pipeline's depth/parent/size/pre/post results can be checked
bit-exactly. The arc ordering (stable sort by source, twin-next rule,
root = min node id unless re-rooted) mirrors ``trees/tour.py`` by
definition of the tour; everything downstream is independent.
"""
from __future__ import annotations

import numpy as np


def serial_tree_reference(
    edge_u,
    edge_v,
    num_nodes: int,
    *,
    labels=None,
    root: int | None = None,
) -> dict:
    """Reference parent/depth/subtree_size/preorder/postorder arrays.

    ``edge_u``/``edge_v`` must be a forest. Roots follow the same
    convention as ``euler_tour``: the minimum node id per component
    (or ``root`` for its own tree).
    """
    n = num_nodes
    u = np.asarray(edge_u, np.int64).ravel()
    v = np.asarray(edge_v, np.int64).ravel()
    f = len(u)

    if labels is None:
        from repro.core.serial import serial_connected_components

        labels = serial_connected_components(np.stack([u, v], axis=1), n) \
            if f else np.arange(n, dtype=np.int64)
    labels = np.asarray(labels, np.int64)
    root_of = labels.copy()
    if root is not None:
        root_of[labels == labels[root]] = root

    parent = np.arange(n, dtype=np.int64)
    depth = np.zeros(n, np.int64)
    size = np.ones(n, np.int64)
    pre = np.zeros(n, np.int64)
    post = np.zeros(n, np.int64)
    if f == 0:
        return dict(parent=parent, depth=depth, subtree_size=size,
                    preorder=pre, postorder=post)

    # Same arc layout as trees/tour.py: arcs [u->v | v->u], stable-sorted
    # by source; twin at stride f; successor = arc after twin in the
    # destination's circular adjacency.
    asrc = np.concatenate([u, v])
    adst = np.concatenate([v, u])
    L = 2 * f
    order = np.argsort(asrc, kind="stable")
    inv = np.empty(L, np.int64)
    inv[order] = np.arange(L)
    counts = np.bincount(asrc, minlength=n)
    offsets = np.concatenate([[0], np.cumsum(counts)[:-1]])
    twin = (np.arange(L) + f) % L
    tpos = inv[twin]
    grp_end = offsets[adst] + counts[adst]
    nxt_pos = np.where(tpos + 1 < grp_end, tpos + 1, offsets[adst])
    succ = order[nxt_pos]

    # Serial circuit walk per tree root, maintaining DFS counters.
    roots = np.unique(root_of[asrc])
    in_pos = np.full(n, -1, np.int64)
    out_pos = np.full(n, -1, np.int64)
    for r in roots:
        head = order[offsets[r]]
        pre_c, post_c, p = 0, 0, 0
        arc = head
        while True:
            a, bnode = int(asrc[arc]), int(adst[arc])
            if in_pos[bnode] < 0 and bnode != r:
                # forward arc: discover bnode
                parent[bnode] = a
                depth[bnode] = depth[a] + 1
                pre_c += 1
                pre[bnode] = pre_c
                in_pos[bnode] = p
            else:
                # backward arc: finish a
                post[a] = post_c
                post_c += 1
                out_pos[a] = p
            p += 1
            arc = int(succ[arc])
            if arc == head:
                break
        post[r] = post_c  # root finishes last
        size[r] = post_c + 1
    covered = in_pos >= 0
    size[covered] = (out_pos[covered] - in_pos[covered] + 1) // 2
    return dict(parent=parent, depth=depth, subtree_size=size,
                preorder=pre, postorder=post)
