"""Euler tour construction by sorted adjacency twinning (Tarjan-Vishkin).

Each forest edge {u, v} becomes two arcs u->v and v->u (twins at a
fixed stride, so twinning costs no search). Arcs are grouped by source
with ONE stable sort (``ops/sorted_dispatch.sort_by_key``) and the
per-node group extents come from the same segment machinery the GNN
paths use (``grouped_offsets``). The tour successor of arc (u->v) is
the arc after its twin (v->u) in v's circular adjacency -- one gather
chain, no data-dependent control flow (guideline G3) -- which yields
one Euler circuit per tree. Breaking each circuit at its root's first
arc (terminal arcs become self-loops) produces exactly the linked-list
shape ``wylie_rank`` / ``random_splitter_rank`` consume: the whole
forest is ONE multi-list ranking instance, which is what makes batched
many-small-trees workloads a single padded call.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.ops.sorted_dispatch import grouped_offsets, sort_by_key

Array = jax.Array


@dataclass
class EulerTour:
    """A linearized Euler tour of a spanning forest, padded or exact.

    ``succ`` is the tour successor over arc ids (terminal arcs and
    padded slots are self-loops), ready for list ranking. ``valid``
    masks the ``num_arcs`` real arcs -- a contiguous prefix unless the
    tour was built over a padded edge buffer (``num_edges=``), so
    consumers mask by it rather than slicing. Padded slots are inert
    self-loops at node 0 so every downstream op stays branch-free.
    """

    succ: Array  # (L,) int32 tour successor (self-loop terminals)
    arc_src: Array  # (L,) int32 source node per arc
    arc_dst: Array  # (L,) int32 destination node per arc
    twin: Array  # (L,) int32 opposite-orientation arc (self for padding)
    head_of_arc: Array  # (L,) int32 head arc of the arc's own tour
    valid: Array  # (L,) bool, False on padded/dead slots
    num_arcs: int  # 2 * num_edges real arcs (pre-padding)
    num_nodes: int
    labels: Array  # (n,) int32 component label per node
    root_of: Array  # (n,) int32 tree root per node (= labels unless re-rooted)

    @property
    def capacity(self) -> int:
        return int(self.succ.shape[0])


def tour_capacity(num_edges: int, min_capacity: int = 16) -> int:
    """Power-of-two arc capacity covering a forest of ``num_edges``
    edges: the padded-batch convention (one compiled shape serves every
    request below the capacity)."""
    need = max(2 * num_edges, min_capacity)
    return 1 << (need - 1).bit_length()


@partial(jax.jit, static_argnames=("n", "f", "pad"))
def _build_tour(u, v, root_of, k, *, n, f, pad):
    """Tour arrays over a (possibly edge-padded) forest edge buffer.

    ``f`` is the STATIC buffer length -- the compile key -- while ``k``
    (traced int32) is the live edge count: slots ``k..f`` of ``u``/``v``
    are inert padding, so variable-size forests served at one buffer
    capacity share ONE compiled program (the batch-serving convention;
    ``k == f`` is the exact, unpadded case). Dead edge slots become
    self-loop arcs grouped under a virtual node ``n`` so they sort past
    every real adjacency group and never perturb the twin-next rule.
    """
    L2 = 2 * f
    ids = jnp.arange(L2, dtype=jnp.int32)
    live = (ids % f) < k  # arc j mirrors edge slot j mod f
    asrc = jnp.concatenate([u, v]).astype(jnp.int32)
    adst = jnp.concatenate([v, u]).astype(jnp.int32)
    src_key = jnp.where(live, asrc, n)
    dst_key = jnp.where(live, adst, n)
    twin = (ids + f) % L2

    # Group arcs by source: ONE stable sort + segment counts. Dead arcs
    # all carry key n, occupying a trailing group real arcs never read.
    sorted_src, perm = sort_by_key(src_key)
    inv = jnp.zeros((L2,), jnp.int32).at[perm].set(ids)
    counts, offsets = grouped_offsets(sorted_src, n + 1)

    # succ(u->v) = the arc after twin (v->u) in v's circular adjacency.
    tpos = inv[twin]
    grp_end = offsets[dst_key] + counts[dst_key]
    nxt_pos = jnp.where(tpos + 1 < grp_end, tpos + 1, offsets[dst_key])
    succ = perm[nxt_pos]

    # Linearize each circuit at its root's first arc. Any node of a
    # nonempty tree has arcs, so offsets[root] is in range for every
    # arc's root; the clamps only guard unused (isolated-root/dead)
    # lanes.
    head_by_node = perm[jnp.minimum(offsets[root_of], L2 - 1)]
    head_of_arc = head_by_node[jnp.minimum(src_key, n - 1)]
    succ = jnp.where(succ == head_of_arc, ids, succ)

    # Dead edge slots collapse to inert self-loops, exactly like the
    # capacity padding below.
    succ = jnp.where(live, succ, ids)
    twin = jnp.where(live, twin, ids)
    head_of_arc = jnp.where(live, head_of_arc, ids)
    asrc = jnp.where(live, asrc, 0)
    adst = jnp.where(live, adst, 0)

    if pad > 0:
        pad_ids = jnp.arange(L2, L2 + pad, dtype=jnp.int32)
        succ = jnp.concatenate([succ, pad_ids])
        twin = jnp.concatenate([twin, pad_ids])
        head_of_arc = jnp.concatenate([head_of_arc, pad_ids])
        asrc = jnp.concatenate([asrc, jnp.zeros((pad,), jnp.int32)])
        adst = jnp.concatenate([adst, jnp.zeros((pad,), jnp.int32)])
        live = jnp.concatenate([live, jnp.zeros((pad,), jnp.bool_)])
    return succ, asrc, adst, twin, head_of_arc, live


def euler_tour(
    edge_u,
    edge_v,
    num_nodes: int,
    *,
    labels=None,
    root: int | None = None,
    pad_to: int | None = None,
    num_edges: int | None = None,
) -> EulerTour:
    """Build the linearized Euler tour of a spanning forest.

    ``edge_u``/``edge_v`` are the forest edges (e.g. from
    ``spanning_forest``); passing a non-forest edge set is undefined.
    ``labels`` are per-node component labels (computed with a dense CC
    run over the forest when omitted); the label representative (min
    node id) roots each tree, unless ``root=`` re-roots the single tree
    containing it. ``pad_to`` pads the arc arrays to a fixed capacity
    (inert self-loops) so many requests share one compiled shape --
    see ``tour_capacity``.

    ``num_edges`` declares ``edge_u``/``edge_v`` to be a PADDED buffer
    of which only the first ``num_edges`` slots are live: the compiled
    tour program is then keyed by the buffer length, not the live
    count, so a serving layer can run variable-size forests at one
    fixed edge capacity (``repro.serve.graph``). The two mirror arcs of
    a dead edge slot become inert self-loops, which means ``valid`` is
    no longer a contiguous prefix -- consumers must mask by ``valid``
    (as ``tree_computations`` and ``tour_splitters`` do), not slice by
    ``num_arcs``.
    """
    n = num_nodes
    u = jnp.asarray(edge_u, jnp.int32).ravel()
    v = jnp.asarray(edge_v, jnp.int32).ravel()
    F = int(u.shape[0])
    f = F if num_edges is None else int(num_edges)
    if not 0 <= f <= F:
        raise ValueError(f"num_edges={f} outside the edge buffer [0, {F}]")
    cap = pad_to if pad_to is not None else 2 * F
    if cap < 2 * F:
        raise ValueError(f"pad_to={cap} below the {2 * F} arcs of the forest")

    if labels is None:
        from repro.core.components import shiloach_vishkin

        labels, _ = shiloach_vishkin(u[:f], v[:f], n)
    labels = jnp.asarray(labels, jnp.int32)
    if root is not None:
        root_of = jnp.where(labels == labels[root], jnp.int32(root), labels)
    else:
        root_of = labels

    if f == 0:  # no live edges: every node is its own (tour-less) tree
        ids = jnp.arange(cap, dtype=jnp.int32)
        zeros = jnp.zeros((cap,), jnp.int32)
        return EulerTour(
            succ=ids, arc_src=zeros, arc_dst=zeros, twin=ids,
            head_of_arc=ids, valid=jnp.zeros((cap,), jnp.bool_),
            num_arcs=0, num_nodes=n, labels=labels, root_of=root_of,
        )

    succ, asrc, adst, twin, head_of_arc, valid = _build_tour(
        u, v, root_of, jnp.int32(f), n=n, f=F, pad=cap - 2 * F
    )
    return EulerTour(
        succ=succ, arc_src=asrc, arc_dst=adst, twin=twin,
        head_of_arc=head_of_arc, valid=valid,
        num_arcs=2 * f, num_nodes=n, labels=labels, root_of=root_of,
    )
