"""Tree computations as +-1-weighted ranks over the Euler tour.

The heavy lifting -- ordering the tour arcs -- is a LIST RANKING call,
dispatched through the exact engines ``list_rank`` uses (`wylie_rank`,
``random_splitter_rank``, or the sharded splitter engine, with the same
``kernel_impl=`` Pallas plumbing). Every tree quantity then falls out
of dense prefix sums over the ranked order, which is the Euler-tour
technique verbatim:

* an arc is **forward** (discovers its destination) iff it precedes its
  twin in the tour;
* ``parent[v]`` = source of the forward arc into v (``root_tree``);
* ``depth[v]`` = prefix sum of +1 (forward) / -1 (backward) weights at
  that arc;
* ``subtree_size[v]`` = half the (inclusive) span between the forward
  arc and its twin;
* ``preorder``/``postorder`` = prefix counts of forward/backward arcs.

All quantities are exact int32, so they are bit-identical across rank
engines. Forests batch for free: the tour of every tree ranks in ONE
multi-list call, per-tree prefix sums are isolated by construction
(each complete tour's +-1 weights sum to zero), and padded capacity
slots are inert self-loops -- the serving path for many concurrent
small-graph requests at one compiled shape.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.components import check_choice
from repro.core.operators import next_pow2
from repro.core.list_ranking import (
    KERNEL_IMPLS,
    WYLIE_PACK_MODES,
    max_splitters_for_linear_work,
    random_splitter_rank,
    select_splitters,
    wylie_rank,
)
from repro.trees.forest import SpanningForest, spanning_forest
from repro.trees.tour import EulerTour, euler_tour, tour_capacity

Array = jax.Array

RANK_ENGINES = ("auto", "wylie", "splitter")


def tour_splitters(
    tour: EulerTour, num_splitters: int | None = None, seed: int = 0
) -> np.ndarray:
    """Splitters for ranking a (multi-list) tour: every tour head plus
    random extras. Heads MUST be splitters -- a sub-list walk only
    covers arcs downstream of some splitter, and a list head has no
    upstream -- which is the one extra rule the forest case adds over
    ``select_splitters``'s single-list convention.

    The returned set is capacity-padded to the next power of two (with
    distinct, deterministically-chosen extra arc ids): the splitter
    COUNT is a compiled dimension of ``_random_splitter_core``, and
    the head count of a served forest varies per wave -- without the
    pad every distinct tour-head count costs one recompile per bucket
    (pinned by ``benchmarks/graph_serve.py``'s splitter lane). Extra
    splitters only refine the sub-list decomposition; ranks are exact
    integers either way. The pad ids must be DISTINCT from the
    existing set: a duplicate splitter would hand one arc two lane
    ids, making the lane scatter order-dependent."""
    L = tour.capacity
    if tour.num_arcs:
        # mask, don't slice: padded-edge-buffer tours interleave dead
        # self-loop arcs with the real ones (see ``euler_tour``)
        heads = np.unique(
            np.asarray(tour.head_of_arc, dtype=np.int64)[
                np.asarray(tour.valid)
            ]
        )
    else:
        heads = np.zeros((0,), np.int64)
    p = num_splitters or min(4096, max_splitters_for_linear_work(max(L, 2)))
    p = min(max(p, 1), L)
    head0 = int(heads[0]) if len(heads) else 0
    extras = select_splitters(L, p, seed=seed, head=head0)
    spl = np.unique(np.concatenate([heads, extras.astype(np.int64)]))
    target = min(L, next_pow2(len(spl)))
    if target > len(spl):
        pool = np.setdiff1d(np.arange(L, dtype=np.int64), spl)
        spl = np.sort(np.concatenate([spl, pool[: target - len(spl)]]))
    return spl


def tour_ranks(
    tour: EulerTour,
    *,
    rank_engine: str = "auto",
    num_splitters: int | None = None,
    kernel_impl: str = "auto",
    pack_mode: str = "aos",
    seed: int = 0,
    mesh=None,
) -> Array:
    """Rank the tour's arcs: rank[j] = arcs from j to its tour's end.

    ``rank_engine="wylie"`` runs pointer jumping, ``"splitter"`` the
    random-splitter engine (single-device, or the sharded engine when a
    mesh is given / several devices are visible -- the same dispatch
    convention as ``repro.core.list_rank``, including ``kernel_impl``
    routing the RS4/RS5 phases through the Pallas kernels). ``"auto"``
    picks wylie on one device and the sharded splitter engine
    otherwise. Ranks are exact integers: every route is bit-identical.

    Every dispatch string is validated up front -- including knobs the
    chosen branch then ignores (wylie has no kernels) -- so a typo
    never silently measures the wrong engine.
    """
    check_choice("rank_engine", rank_engine, RANK_ENGINES)
    check_choice("kernel_impl", kernel_impl, KERNEL_IMPLS)
    check_choice("pack_mode", pack_mode, WYLIE_PACK_MODES)
    multi = mesh is not None or jax.device_count() > 1
    if rank_engine == "auto":
        rank_engine = "splitter" if multi else "wylie"
    if rank_engine == "wylie":
        if mesh is not None:
            raise ValueError(
                "wylie_rank is single-device; drop mesh= or use "
                "rank_engine='splitter'"
            )
        return wylie_rank(tour.succ, pack_mode=pack_mode)
    splitters = tour_splitters(tour, num_splitters=num_splitters, seed=seed)
    if multi:
        from repro.distributed.graph import sharded_random_splitter_rank

        return sharded_random_splitter_rank(
            tour.succ, splitters=splitters, mesh=mesh,
            kernel_impl=kernel_impl,
        )
    return random_splitter_rank(
        tour.succ, splitters=splitters, kernel_impl=kernel_impl
    )


@partial(jax.jit, static_argnames=("n",))
def _analytics(ranks, arc_src, arc_dst, twin, head_of_arc, valid, root_of,
               *, n):
    """All tree quantities from the arc ranks, in dense prefix ops.

    Everything is sized by the (static) capacity L, never by the traced
    real-arc count, so variable-size forests served at one ``pad_to``
    capacity share ONE compiled program: order-buffer slots past the
    real arcs hold garbage, but every read position (``gpos`` of a real
    arc) lies below them, and a cumsum prefix is unaffected by entries
    above it."""
    L = ranks.shape[0]
    ids = jnp.arange(L, dtype=jnp.int32)
    ranks = ranks.astype(jnp.int32)
    # Position within the arc's own tour (0-based; 0 on padded slots
    # because their head is themselves).
    pos = ranks[head_of_arc] - ranks

    # Per-tree tour length and the exclusive base offset of each tree in
    # the concatenated (root-id-ordered) global order.
    tree_of_arc = root_of[arc_src]
    tree_len = jnp.zeros((n,), jnp.int32).at[
        jnp.where(valid, tree_of_arc, n)
    ].max(pos + 1, mode="drop")
    base = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(tree_len)[:-1].astype(jnp.int32)]
    )
    gpos = base[tree_of_arc] + pos  # bijection: valid arcs -> [0, num_arcs)

    fwd = pos < pos[twin]  # forward = discovers its destination

    # The arc occupying each global tour slot, then the three prefix
    # families: +-1 depth weights, forward counts, backward counts.
    # Cross-tree isolation is automatic for depth (each complete tour
    # sums to 0); pre/post subtract their tree-start prefix.
    order = jnp.zeros((L,), jnp.int32).at[
        jnp.where(valid, gpos, L)
    ].set(ids, mode="drop")
    w_fwd = fwd[order].astype(jnp.int32)
    C = jnp.cumsum(2 * w_fwd - 1)
    F = jnp.cumsum(w_fwd)
    B = jnp.cumsum(1 - w_fwd)
    F_start = jnp.where(base > 0, F[jnp.maximum(base - 1, 0)], 0)
    B_start = jnp.where(base > 0, B[jnp.maximum(base - 1, 0)], 0)

    # The unique forward arc into each non-root node, and its twin out.
    in_arc = jnp.full((n,), -1, jnp.int32).at[
        jnp.where(fwd & valid, arc_dst, n)
    ].set(ids, mode="drop")
    has = in_arc >= 0
    ia = jnp.maximum(in_arc, 0)
    oa = twin[ia]
    nodes = jnp.arange(n, dtype=jnp.int32)

    parent = jnp.where(has, arc_src[ia], nodes)
    depth = jnp.where(has, C[gpos[ia]], 0)
    size_sub = jnp.where(
        has, (pos[oa] - pos[ia] + 1) // 2, tree_len[nodes] // 2 + 1
    )
    pre = jnp.where(has, F[gpos[ia]] - F_start[root_of], 0)
    post = jnp.where(
        has, B[gpos[oa]] - B_start[root_of] - 1, tree_len[nodes] // 2
    )
    return parent, depth, size_sub, pre, post


@dataclass
class TreeComputations:
    """Per-node tree quantities over a (forest) Euler tour; roots have
    ``parent[r] == r``, ``depth 0``, ``preorder 0``, and per-tree
    ``postorder == tree_size - 1``; isolated nodes are size-1 roots."""

    parent: Array  # (n,) int32
    depth: Array  # (n,) int32
    subtree_size: Array  # (n,) int32
    preorder: Array  # (n,) int32 per-tree DFS discovery index
    postorder: Array  # (n,) int32 per-tree DFS finish index
    ranks: Array  # (L,) the tour ranks everything derives from


def tree_computations(
    tour: EulerTour, *, ranks: Array | None = None, **rank_kwargs
) -> TreeComputations:
    """Run the whole tree-computation family over one ranked tour.

    ``ranks`` reuses an existing ``tour_ranks`` result; otherwise one is
    computed with ``rank_kwargs`` (``rank_engine=``, ``kernel_impl=``,
    ``mesh=``, ...).
    """
    n = tour.num_nodes
    if tour.capacity == 0 or tour.num_arcs == 0:
        # validate dispatch strings even on the trivial path
        check_choice(
            "rank_engine", rank_kwargs.get("rank_engine", "auto"),
            RANK_ENGINES,
        )
        check_choice(
            "kernel_impl", rank_kwargs.get("kernel_impl", "auto"),
            KERNEL_IMPLS,
        )
        ids = jnp.arange(n, dtype=jnp.int32)
        zeros = jnp.zeros((n,), jnp.int32)
        return TreeComputations(
            parent=ids, depth=zeros, subtree_size=zeros + 1,
            preorder=zeros, postorder=zeros,
            ranks=jnp.zeros((tour.capacity,), jnp.int32),
        )
    if ranks is None:
        ranks = tour_ranks(tour, **rank_kwargs)
    parent, depth, size_sub, pre, post = _analytics(
        ranks, tour.arc_src, tour.arc_dst, tour.twin, tour.head_of_arc,
        tour.valid, tour.root_of, n=n,
    )
    return TreeComputations(
        parent=parent, depth=depth, subtree_size=size_sub,
        preorder=pre, postorder=post, ranks=ranks,
    )


def root_tree(tour: EulerTour, **kwargs) -> Array:
    """Parent array of the rooted forest (roots point at themselves)."""
    return tree_computations(tour, **kwargs).parent


def depths(tour: EulerTour, **kwargs) -> Array:
    return tree_computations(tour, **kwargs).depth


def subtree_sizes(tour: EulerTour, **kwargs) -> Array:
    return tree_computations(tour, **kwargs).subtree_size


def preorder(tour: EulerTour, **kwargs) -> Array:
    return tree_computations(tour, **kwargs).preorder


def postorder(tour: EulerTour, **kwargs) -> Array:
    return tree_computations(tour, **kwargs).postorder


@dataclass
class TreeAnalytics:
    """End-to-end result: forest -> tour -> computations."""

    forest: SpanningForest
    tour: EulerTour
    computations: TreeComputations

    @property
    def parent(self) -> Array:
        return self.computations.parent

    @property
    def depth(self) -> Array:
        return self.computations.depth

    @property
    def subtree_size(self) -> Array:
        return self.computations.subtree_size


def tree_analytics(
    src,
    dst,
    num_nodes: int,
    *,
    engine: str = "auto",
    rank_engine: str = "auto",
    kernel_impl: str = "auto",
    num_splitters: int | None = None,
    pad_to: int | None = None,
    pad_edges_to: int | None = None,
    mesh=None,
    seed: int = 0,
    **cc_kwargs,
) -> TreeAnalytics:
    """One-shot pipeline on an arbitrary graph: CC + spanning forest,
    Euler tour, and the batched tree computations. Keywords (full
    matrix in ``docs/engines.md``):

    * ``engine=`` -- ``"auto"`` (default), ``"frontier"``, ``"dense"``,
      ``"sharded_frontier"``: the CC engine extracting the forest (as
      in ``connected_components``); ``**cc_kwargs`` forward to it.
    * ``rank_engine=`` -- ``"auto"`` (default), ``"wylie"``,
      ``"splitter"``: the list-ranking engine over the tour ("auto"
      picks wylie on one device, the sharded splitter engine when a
      mesh is given or several devices are visible).
    * ``kernel_impl=`` -- ``"auto"`` (default), ``"xla"``, ``"pallas"``,
      ``"pallas_interpret"``: Pallas routing for the splitter engine's
      RS4/RS5 phases (ignored by wylie, validated regardless).
    * ``num_splitters=`` (int, default: linear-work bound), ``seed=``
      (int, default 0) -- splitter selection.
    * ``pad_to=`` (int, default None) -- fixes the tour capacity so many
      variable-size requests compile once (see ``tour_capacity``); a
      forest of many small graphs (e.g. ``data/graphs.molecule_batch``)
      is one batched call.
    * ``pad_edges_to=`` (int, default None) -- pads the extracted
      forest-edge buffer to a fixed capacity before touring, so the
      tour/compute stages compile per CAPACITY instead of per live
      forest-edge count (the data-dependent quantity); this is what
      lets ``repro.serve.graph`` run every wave of a capacity bucket
      through one compiled program. Implies a tour capacity of
      ``2 * pad_edges_to`` unless ``pad_to`` raises it.
    * ``mesh=`` -- threads to BOTH the CC engine and the ranking engine
      (the all-sharded path end to end).

    All quantities are exact int32: results are bit-identical across
    every engine combination.
    """
    forest = spanning_forest(
        src, dst, num_nodes, engine=engine, mesh=mesh, **cc_kwargs
    )
    edge_u, edge_v, num_edges = forest.edge_u, forest.edge_v, None
    if pad_edges_to is not None:
        f = forest.num_edges
        if f > pad_edges_to:
            raise ValueError(
                f"pad_edges_to={pad_edges_to} below the {f} forest edges"
            )
        num_edges = f
        edge_u = np.zeros((pad_edges_to,), np.int32)
        edge_v = np.zeros((pad_edges_to,), np.int32)
        edge_u[:f] = forest.edge_u
        edge_v[:f] = forest.edge_v
    tour = euler_tour(
        edge_u, edge_v, num_nodes,
        labels=forest.labels, pad_to=pad_to, num_edges=num_edges,
    )
    comp = tree_computations(
        tour, rank_engine=rank_engine, kernel_impl=kernel_impl,
        num_splitters=num_splitters, seed=seed, mesh=mesh,
    )
    return TreeAnalytics(forest=forest, tour=tour, computations=comp)
