"""JAX API-drift shims: one import site for everything that moved.

The repo targets current JAX but must also run on the 0.4.x line (the
pinned CI environment). Three API families drifted between those:

* ``jax.sharding.AxisType`` + ``jax.make_mesh(..., axis_types=...)`` --
  the explicit-sharding mesh flags do not exist before jax 0.5; meshes
  built here behave as ``Auto`` on old releases (which is all this repo
  ever asks for).
* ``jax.shard_map`` -- lived at ``jax.experimental.shard_map.shard_map``
  until ~0.6, and its replication-check kwarg was renamed
  ``check_rep`` -> ``check_vma`` when it was promoted.
* ``jax.tree`` utilities and friends occasionally move; anything else
  that drifts gets its shim added HERE, never inline at a call site.

Every mesh and every shard_map in the repo routes through this module so
the same code runs on jax 0.4.x through current. ``Mesh`` is re-exported
from here for the same reason: call sites write ``from repro.compat
import Mesh`` so this stays the one direct ``jax.sharding`` import site
(enforced by the compat-shim lint pass, docs/lint.md).
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Sequence

import numpy as np

import jax
from jax.sharding import Mesh  # noqa: F401  (re-exported, see docstring)

# --------------------------------------------------------------------------
# AxisType (explicit-sharding flags, jax >= 0.5)
# --------------------------------------------------------------------------

HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")

if HAS_AXIS_TYPES:
    AxisType = jax.sharding.AxisType
else:

    class AxisType:  # type: ignore[no-redef]
        """Stand-in for jax.sharding.AxisType on pre-0.5 releases.

        Old JAX has no explicit-sharding mode; every mesh axis behaves as
        ``Auto``, so the sentinels only need to exist and be distinct.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


def auto_axis_types(ndim: int):
    """(AxisType.Auto,) * ndim -- the only mode this repo uses."""
    return (AxisType.Auto,) * ndim


# --------------------------------------------------------------------------
# Mesh construction
# --------------------------------------------------------------------------

_MAKE_MESH = getattr(jax, "make_mesh", None)
_MAKE_MESH_KW = (
    frozenset(inspect.signature(_MAKE_MESH).parameters)
    if _MAKE_MESH is not None
    else frozenset()
)


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: tuple | None = None,
    devices: Sequence[Any] | None = None,
) -> Mesh:
    """``jax.make_mesh`` that works on jax 0.4.x through current.

    * ``axis_types`` is forwarded when the installed ``jax.make_mesh``
      accepts it and silently dropped otherwise (pre-0.5 JAX is always
      implicitly Auto, so dropping it preserves semantics).
    * ``devices`` pins the mesh to an explicit device list IN THAT ORDER
      (jax.make_mesh may permute devices for ICI topology; tests and
      sub-meshes need determinism), falling back to direct ``Mesh``
      construction.
    """
    shape = tuple(int(s) for s in axis_shapes)
    names = tuple(axis_names)
    if devices is not None:
        n = int(np.prod(shape))
        dev = np.asarray(list(devices)[:n]).reshape(shape)
        # Forward axis_types only on AxisType-era jax: 0.4.x Mesh also
        # has an axis_types kwarg but with different (dict-shaped,
        # experimental) semantics, and old jax is implicitly Auto anyway.
        if axis_types is not None and HAS_AXIS_TYPES:
            return Mesh(dev, names, axis_types=axis_types)
        return Mesh(dev, names)
    if _MAKE_MESH is not None:
        kw = {}
        if axis_types is not None and "axis_types" in _MAKE_MESH_KW:
            kw["axis_types"] = axis_types
        return _MAKE_MESH(shape, names, **kw)
    dev = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return Mesh(dev, names)


# --------------------------------------------------------------------------
# shard_map
# --------------------------------------------------------------------------

if hasattr(jax, "shard_map"):
    _SHARD_MAP = jax.shard_map
else:  # pre-promotion location
    from jax.experimental.shard_map import shard_map as _SHARD_MAP

_SHARD_MAP_KW = frozenset(inspect.signature(_SHARD_MAP).parameters)
# check_rep (old) was renamed check_vma (new); pick whichever exists.
_REP_KW = (
    "check_vma"
    if "check_vma" in _SHARD_MAP_KW
    else ("check_rep" if "check_rep" in _SHARD_MAP_KW else None)
)


def shard_map(
    f: Callable,
    *,
    mesh: Mesh,
    in_specs,
    out_specs,
    check_vma: bool = True,
) -> Callable:
    """``jax.shard_map`` with the current calling convention on any jax.

    ``check_vma=False`` maps to ``check_rep=False`` on old releases (the
    replication checker predates varying-manual-axes but guards the same
    thing: collectives whose replication the tracer cannot prove).
    """
    kw = {_REP_KW: check_vma} if _REP_KW is not None else {}
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


# --------------------------------------------------------------------------
# Tracer detection (host-driven engines need concrete inputs)
# --------------------------------------------------------------------------

_TRACER_T = getattr(getattr(jax, "core", None), "Tracer", None)


def is_tracer(x: Any) -> bool:
    """True when ``x`` is a JAX tracer (i.e. we are inside a jit trace).

    If a future release moves ``jax.core.Tracer``, fall back to the
    class name (every tracer class is a ``*Tracer``) -- erring toward
    tracer, because mis-dispatching a tracer into a host-driven engine
    crashes while the traceable fallback path merely runs unfused.
    """
    if _TRACER_T is not None:
        return isinstance(x, _TRACER_T)
    return "Tracer" in type(x).__name__
