"""GIN (Graph Isomorphism Network), arXiv:1810.00826.

h_v^{k} = MLP_k( (1 + eps_k) h_v^{k-1} + sum_{u in N(v)} h_u^{k-1} )

The sum aggregator is a sorted segment_sum (paper guideline G1: edges are
pre-sorted by destination by the data pipeline). BatchNorm from the original
is replaced by LayerNorm (stateless, TPU-friendly); noted in DESIGN.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import he_init, layer_norm
from repro.ops.segment import segment_sum_dist

Array = jax.Array


@dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    num_layers: int = 5
    d_hidden: int = 64
    in_dim: int = 64
    num_classes: int = 2
    readout: str = "graph"  # "graph" (TU datasets) or "node"
    eps_learnable: bool = True
    dtype: str = "float32"


def init_params(key, cfg: GINConfig) -> dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    layers = []
    d_in = cfg.in_dim
    keys = jax.random.split(key, cfg.num_layers + 1)
    for i in range(cfg.num_layers):
        k1, k2 = jax.random.split(keys[i])
        layers.append(
            {
                "w1": he_init(k1, (d_in, cfg.d_hidden), d_in, dtype),
                "b1": jnp.zeros((cfg.d_hidden,), dtype),
                "w2": he_init(k2, (cfg.d_hidden, cfg.d_hidden), cfg.d_hidden, dtype),
                "b2": jnp.zeros((cfg.d_hidden,), dtype),
                "ln_g": jnp.ones((cfg.d_hidden,), dtype),
                "ln_b": jnp.zeros((cfg.d_hidden,), dtype),
                "eps": jnp.zeros((), dtype),
            }
        )
        d_in = cfg.d_hidden
    head_in = cfg.d_hidden * cfg.num_layers  # jumping-knowledge concat
    return {
        "layers": layers,
        "head_w": he_init(keys[-1], (head_in, cfg.num_classes), head_in, dtype),
        "head_b": jnp.zeros((cfg.num_classes,), dtype),
    }


def forward(
    params,
    cfg: GINConfig,
    graph: dict[str, Array],
    *,
    psum_axes: tuple[str, ...] = (),
) -> Array:
    """graph: node_feats (n,d), src/dst (m,), graph_ids (n,) for readout."""
    h = graph["node_feats"]
    n = h.shape[0]
    src, dst = graph["src"], graph["dst"]
    reps = []
    for layer in params["layers"]:
        agg = segment_sum_dist(h[src], dst, n, psum_axes)
        eps = layer["eps"] if cfg.eps_learnable else 0.0
        z = (1.0 + eps) * h + agg
        z = jax.nn.relu(z @ layer["w1"] + layer["b1"])
        z = z @ layer["w2"] + layer["b2"]
        h = layer_norm(z, layer["ln_g"], layer["ln_b"])
        reps.append(h)
    hcat = jnp.concatenate(reps, axis=-1)
    if cfg.readout == "graph":
        num_graphs = graph["num_graphs"]
        pooled = jax.ops.segment_sum(hcat, graph["graph_ids"], num_graphs)
        return pooled @ params["head_w"] + params["head_b"]
    return hcat @ params["head_w"] + params["head_b"]


def loss_fn(
    params, cfg: GINConfig, graph, *, psum_axes: tuple[str, ...] = ()
) -> Array:
    logits = forward(params, cfg, graph, psum_axes=psum_axes)
    labels = graph["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].clip(0), axis=-1)[:, 0]
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
