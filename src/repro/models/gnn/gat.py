"""GAT (Graph Attention Network), arXiv:1710.10903. Cora config: 2 layers,
8 hidden units, 8 heads, attention aggregation.

Edge attention is SDDMM -> segment-softmax -> SpMM in the taxonomy; here:
gather endpoints (irregular read), LeakyReLU score, segment softmax over
destination (two reductions resolved min-CRCW-style), weighted segment sum.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import lecun_init
from repro.ops.segment import segment_softmax_dist, segment_sum_dist

Array = jax.Array


@dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    num_layers: int = 2
    d_hidden: int = 8
    num_heads: int = 8
    in_dim: int = 1433
    num_classes: int = 7
    negative_slope: float = 0.2
    dtype: str = "float32"


def init_params(key, cfg: GATConfig) -> dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    layers = []
    d_in = cfg.in_dim
    keys = jax.random.split(key, cfg.num_layers)
    for i in range(cfg.num_layers):
        last = i == cfg.num_layers - 1
        heads = 1 if last else cfg.num_heads
        d_out = cfg.num_classes if last else cfg.d_hidden
        k1, k2, k3 = jax.random.split(keys[i], 3)
        layers.append(
            {
                "w": lecun_init(k1, (d_in, heads * d_out), d_in, dtype),
                "a_src": lecun_init(k2, (heads, d_out), d_out, dtype),
                "a_dst": lecun_init(k3, (heads, d_out), d_out, dtype),
                "b": jnp.zeros((heads * d_out,), dtype),
            }
        )
        d_in = heads * d_out if not last else d_out
    return {"layers": layers}


def _gat_layer(layer, cfg, h, src, dst, n, heads, d_out, psum_axes, last):
    wh = (h @ layer["w"]).reshape(n, heads, d_out)
    s_src = jnp.einsum("nhd,hd->nh", wh, layer["a_src"])
    s_dst = jnp.einsum("nhd,hd->nh", wh, layer["a_dst"])
    e = jax.nn.leaky_relu(
        s_src[src] + s_dst[dst], negative_slope=cfg.negative_slope
    )  # (m, heads)
    num, den = segment_softmax_dist(e, dst, n, psum_axes)
    msgs = wh[src] * num[..., None]  # (m, heads, d_out)
    agg = segment_sum_dist(msgs, dst, n, psum_axes)
    out = agg / den[..., None]
    if last:
        return out.mean(axis=1)  # average heads -> logits
    return jax.nn.elu(out.reshape(n, heads * d_out) + layer["b"])


def forward(
    params,
    cfg: GATConfig,
    graph: dict[str, Array],
    *,
    psum_axes: tuple[str, ...] = (),
) -> Array:
    h = graph["node_feats"]
    n = h.shape[0]
    src, dst = graph["src"], graph["dst"]
    for i, layer in enumerate(params["layers"]):
        last = i == len(params["layers"]) - 1
        heads = 1 if last else cfg.num_heads
        d_out = cfg.num_classes if last else cfg.d_hidden
        h = _gat_layer(layer, cfg, h, src, dst, n, heads, d_out, psum_axes, last)
    return h


def loss_fn(
    params, cfg: GATConfig, graph, *, psum_axes: tuple[str, ...] = ()
) -> Array:
    logits = forward(params, cfg, graph, psum_axes=psum_axes)
    labels = graph["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].clip(0), axis=-1)[:, 0]
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
