"""Bonus pool architectures on the same substrate: GCN [arXiv:1609.02907],
GraphSAGE [arXiv:1706.02216], PNA [arXiv:2004.05718].

These reuse ops.segment / ops.scatter_gather unchanged -- the point of the
framework: a new message-passing arch is ~40 lines. Registered under
``repro.configs.EXTRA_ARCHS`` (the assigned 10-arch registry is fixed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import he_init
from repro.ops.segment import (
    segment_count,
    segment_max_dist,
    segment_mean,
    segment_sum_dist,
)

Array = jax.Array


def _node_ce(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None].clip(0), axis=-1)[:, 0]
    mask = (labels >= 0).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# GCN: h' = D^-1/2 A D^-1/2 h W  (symmetric-normalized SpMM)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn"
    num_layers: int = 2
    d_hidden: int = 64
    in_dim: int = 64
    num_classes: int = 7
    dtype: str = "float32"


def gcn_init(key, cfg: GCNConfig) -> dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    dims = [cfg.in_dim] + [cfg.d_hidden] * (cfg.num_layers - 1) + [cfg.num_classes]
    ks = jax.random.split(key, cfg.num_layers)
    return {
        "layers": [
            {
                "w": he_init(ks[i], (dims[i], dims[i + 1]), dims[i], dtype),
                "b": jnp.zeros((dims[i + 1],), dtype),
            }
            for i in range(cfg.num_layers)
        ]
    }


def gcn_forward(params, cfg: GCNConfig, graph, *, psum_axes=()) -> Array:
    h = graph["node_feats"]
    n = h.shape[0]
    src, dst = graph["src"], graph["dst"]
    deg = segment_count(dst, n).astype(jnp.float32) + 1.0  # +self loop
    inv_sqrt = jax.lax.rsqrt(deg)
    norm = inv_sqrt[src] * inv_sqrt[dst]  # (m,)
    for i, layer in enumerate(params["layers"]):
        z = h @ layer["w"] + layer["b"]
        agg = segment_sum_dist(z[src] * norm[:, None], dst, n, psum_axes)
        h = agg + z * (inv_sqrt * inv_sqrt)[:, None]  # self loop
        if i < len(params["layers"]) - 1:
            h = jax.nn.relu(h)
    return h


def gcn_loss(params, cfg, graph, *, psum_axes=()):
    return _node_ce(gcn_forward(params, cfg, graph, psum_axes=psum_axes),
                    graph["labels"])


# ---------------------------------------------------------------------------
# GraphSAGE (mean aggregator): h' = act(W_self h || W_neigh mean_j h_j)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SAGEConfig:
    name: str = "graphsage"
    num_layers: int = 2
    d_hidden: int = 64
    in_dim: int = 64
    num_classes: int = 41
    dtype: str = "float32"


def sage_init(key, cfg: SAGEConfig) -> dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    dims = [cfg.in_dim] + [cfg.d_hidden] * (cfg.num_layers - 1) + [cfg.num_classes]
    ks = jax.random.split(key, 2 * cfg.num_layers)
    return {
        "layers": [
            {
                "w_self": he_init(ks[2 * i], (dims[i], dims[i + 1]), dims[i], dtype),
                "w_neigh": he_init(
                    ks[2 * i + 1], (dims[i], dims[i + 1]), dims[i], dtype
                ),
                "b": jnp.zeros((dims[i + 1],), dtype),
            }
            for i in range(cfg.num_layers)
        ]
    }


def sage_forward(params, cfg: SAGEConfig, graph, *, psum_axes=()) -> Array:
    h = graph["node_feats"]
    n = h.shape[0]
    src, dst = graph["src"], graph["dst"]
    for i, layer in enumerate(params["layers"]):
        neigh = segment_mean(h[src], dst, n)
        if psum_axes:  # mean of partials needs sum/count psums
            s = segment_sum_dist(h[src], dst, n, psum_axes)
            c = segment_sum_dist(
                jnp.ones((src.shape[0], 1), h.dtype), dst, n, psum_axes
            )
            neigh = s / jnp.maximum(c, 1.0)
        h = h @ layer["w_self"] + neigh @ layer["w_neigh"] + layer["b"]
        if i < len(params["layers"]) - 1:
            h = jax.nn.relu(h)
            # L2 normalize per GraphSAGE
            h = h / jnp.maximum(
                jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6
            )
    return h


def sage_loss(params, cfg, graph, *, psum_axes=()):
    return _node_ce(sage_forward(params, cfg, graph, psum_axes=psum_axes),
                    graph["labels"])


# ---------------------------------------------------------------------------
# PNA: 4 aggregators (mean/min/max/std) x 3 degree scalers, then linear
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    num_layers: int = 2
    d_hidden: int = 32
    in_dim: int = 32
    num_classes: int = 7
    delta: float = 2.5  # avg log-degree normalizer
    dtype: str = "float32"


def pna_init(key, cfg: PNAConfig) -> dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    dims = [cfg.in_dim] + [cfg.d_hidden] * (cfg.num_layers - 1) + [cfg.num_classes]
    ks = jax.random.split(key, cfg.num_layers)
    return {
        "layers": [
            {
                # 4 aggregators x 3 scalers + self = 13 x d_in -> d_out
                "w": he_init(
                    ks[i], (13 * dims[i], dims[i + 1]), 13 * dims[i], dtype
                ),
                "b": jnp.zeros((dims[i + 1],), dtype),
            }
            for i in range(cfg.num_layers)
        ]
    }


def pna_forward(params, cfg: PNAConfig, graph, *, psum_axes=()) -> Array:
    h = graph["node_feats"]
    n = h.shape[0]
    src, dst = graph["src"], graph["dst"]
    deg = segment_count(dst, n).astype(jnp.float32)
    logd = jnp.log1p(deg)[:, None]
    scalers = [
        jnp.ones_like(logd),
        logd / cfg.delta,  # amplification
        cfg.delta / jnp.maximum(logd, 1e-6),  # attenuation
    ]
    for li, layer in enumerate(params["layers"]):
        msgs = h[src]
        s1 = segment_sum_dist(msgs, dst, n, psum_axes)
        cnt = jnp.maximum(deg, 1.0)[:, None]
        mean = s1 / cnt
        s2 = segment_sum_dist(msgs * msgs, dst, n, psum_axes)
        var = jnp.maximum(s2 / cnt - mean * mean, 0.0)
        std = jnp.sqrt(var + 1e-6)
        mx = segment_max_dist(msgs, dst, n, psum_axes)
        mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
        mn = -segment_max_dist(-msgs, dst, n, psum_axes)
        mn = jnp.where(jnp.isfinite(mn), mn, 0.0)
        aggs = [mean, mn, mx, std]
        feats = [h] + [a * s for a in aggs for s in scalers]
        h = jnp.concatenate(feats, axis=-1) @ layer["w"] + layer["b"]
        if li < len(params["layers"]) - 1:
            h = jax.nn.relu(h)
    return h


def pna_loss(params, cfg, graph, *, psum_axes=()):
    return _node_ce(pna_forward(params, cfg, graph, psum_axes=psum_axes),
                    graph["labels"])
