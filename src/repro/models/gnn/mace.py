"""MACE (higher-order equivariant message passing), arXiv:2206.07697.

Simplified-but-real MACE: l_max=2 irreps, correlation order 3, Bessel radial
basis with polynomial cutoff, real-basis CG tensor products (so3.py), and
per-layer invariant readouts summed into a total energy.

Structure per layer:
  A-basis  A_i^{L} = sum_j R_path(r_ij) * CG(l1,l2,L) h_j^{l1} Y_{l2}(r_ij)
  B-basis  products of A up to correlation 3 via nested CG contractions
  update   h'^{L} = W_A A^{L} + W_B B^{L} + W_res h^{L}

Features are lists indexed by l: feats[l] has shape (n, C, 2l+1).
The edge reduction is ops.segment (irregular-scatter regime, guideline G1:
edges pre-sorted by destination).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import he_init
from repro.models.gnn.so3 import cg_jnp, num_m, real_sph_harm
from repro.ops.segment import segment_sum_dist

Array = jax.Array


@dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    num_layers: int = 2
    channels: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    num_species: int = 10
    r_cut: float = 5.0
    dtype: str = "float32"


def _msg_paths(ls_in: list[int], l_max: int) -> list[tuple[int, int, int]]:
    paths = []
    for l1 in ls_in:
        for l2 in range(l_max + 1):
            for l3 in range(l_max + 1):
                if abs(l1 - l2) <= l3 <= l1 + l2:
                    paths.append((l1, l2, l3))
    return paths


def _prod2_paths(l_max: int) -> list[tuple[int, int, int]]:
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l1, l_max + 1):
            for lo in range(l_max + 1):
                if abs(l1 - l2) <= lo <= l1 + l2:
                    out.append((l1, l2, lo))
    return out


def _prod3_paths(l_max: int) -> list[tuple[int, int, int, int, int]]:
    out = []
    for l1, l2, l12 in _prod2_paths(l_max):
        for l3 in range(l_max + 1):
            for lo in range(l_max + 1):
                if abs(l12 - l3) <= lo <= l12 + l3:
                    out.append((l1, l2, l12, l3, lo))
    return out


def bessel_rbf(r: Array, n_rbf: int, r_cut: float) -> Array:
    """Bessel radial basis with smooth polynomial cutoff (DimeNet-style)."""
    rs = jnp.clip(r, 1e-6, r_cut)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * rs[:, None] / r_cut) / rs[:, None]
    u = jnp.clip(r / r_cut, 0.0, 1.0)[:, None]
    envelope = 1.0 - 10.0 * u ** 3 + 15.0 * u ** 4 - 6.0 * u ** 5
    return basis * envelope


def init_params(key, cfg: MACEConfig) -> dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    c = cfg.channels
    keys = jax.random.split(key, cfg.num_layers + 2)
    layers = []
    ls_in = [0]
    for i in range(cfg.num_layers):
        mpaths = _msg_paths(ls_in, cfg.l_max)
        p2 = _prod2_paths(cfg.l_max)
        p3 = _prod3_paths(cfg.l_max) if cfg.correlation >= 3 else []
        k = jax.random.split(keys[i], 8)
        layers.append(
            {
                # radial MLP: (n_rbf,) -> per-(msg path, channel) weight
                "rad_w1": he_init(k[0], (cfg.n_rbf, 64), cfg.n_rbf, dtype),
                "rad_b1": jnp.zeros((64,), dtype),
                "rad_w2": he_init(k[1], (64, len(mpaths) * c), 64, dtype),
                # channel mixers
                "mix_pre": [
                    he_init(jax.random.fold_in(k[2], l), (c, c), c, dtype)
                    for l in ls_in
                ],
                "w_A": [
                    he_init(jax.random.fold_in(k[3], l), (c, c), c, dtype)
                    for l in range(cfg.l_max + 1)
                ],
                "w_B2": (jax.random.normal(k[4], (len(p2), c)) * 0.1).astype(dtype),
                "w_B3": (jax.random.normal(k[5], (len(p3), c)) * 0.03).astype(dtype)
                if p3
                else None,
                "w_res": [
                    he_init(jax.random.fold_in(k[6], l), (c, c), c, dtype)
                    for l in ls_in
                ],
                "readout_w": he_init(k[7], (c, 1), c, dtype),
            }
        )
        ls_in = list(range(cfg.l_max + 1))
    return {
        "species_embed": (
            jax.random.normal(keys[-2], (cfg.num_species, c)) * 0.5
        ).astype(dtype),
        "layers": layers,
        "final_w1": he_init(keys[-1], (c, 16), c, dtype),
        "final_w2": jnp.zeros((16, 1), dtype),
    }


def forward(
    params,
    cfg: MACEConfig,
    graph: dict[str, Array],
    *,
    psum_axes: tuple[str, ...] = (),
    constrain=None,
) -> Array:
    """graph: species (n,) int, positions (n,3), src/dst (m,), graph_ids.

    Returns per-graph energies (num_graphs,).

    ``constrain(tensor, kind)`` with kind in {"node", "edge"} lets the
    launcher pin shardings: MACE's CG products and radial weights are
    CHANNEL-elementwise, so the channel dim shards cleanly over "model"
    while edges shard over the data axes -- the hillclimb that removes the
    replicated-node all-reduce on ogb_products (EXPERIMENTS.md Perf).
    """
    C_ = constrain or (lambda t, kind: t)
    species = graph["species"]
    x = graph["positions"].astype(jnp.float32)
    src, dst = graph["src"], graph["dst"]
    n = species.shape[0]
    c = cfg.channels

    vec = x[dst] - x[src]
    r = jnp.sqrt(jnp.maximum(jnp.sum(vec * vec, axis=-1), 1e-12))
    rhat = vec / r[:, None]
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.r_cut)  # (m, n_rbf)
    sh = [real_sph_harm(l, rhat) for l in range(cfg.l_max + 1)]  # (m, 2l+1)

    h0 = jnp.take(params["species_embed"], species, axis=0)  # (n, C)
    feats = [h0[:, :, None]]  # l=0 only
    ls_in = [0]
    energy_nodes = jnp.zeros((n,), jnp.float32)

    for layer in params["layers"]:
        mpaths = _msg_paths(ls_in, cfg.l_max)
        rad = jax.nn.silu(rbf @ layer["rad_w1"] + layer["rad_b1"])
        rad = (rad @ layer["rad_w2"]).reshape(-1, len(mpaths), c)  # (m, P, C)

        pre = [
            C_(
                jnp.einsum(
                    "ncm,cd->ndm", C_(feats[i], "mix_in"), layer["mix_pre"][i]
                ),
                "node",
            )
            for i in range(len(ls_in))
        ]

        # ---- A-basis: message passing with CG couplings ----
        A = [
            jnp.zeros((n, c, num_m(l)), h0.dtype) for l in range(cfg.l_max + 1)
        ]
        for pi, (l1, l2, l3) in enumerate(mpaths):
            cg = cg_jnp(l1, l2, l3, h0.dtype)
            hj = pre[ls_in.index(l1)][src]  # (m, C, 2l1+1)
            contrib = jnp.einsum(
                "mca,mb,abz->mcz", hj, sh[l2], cg
            ) * rad[:, pi, :, None]
            contrib = C_(contrib, "edge")
            A[l3] = A[l3] + C_(
                segment_sum_dist(contrib, dst, n, psum_axes), "node"
            )

        # ---- B-basis: symmetric products (correlation 2 and 3) ----
        msg = [
            C_(
                jnp.einsum("ncm,cd->ndm", C_(A[l], "mix_in"), layer["w_A"][l]),
                "node",
            )
            for l in range(cfg.l_max + 1)
        ]
        for pi, (l1, l2, lo) in enumerate(_prod2_paths(cfg.l_max)):
            cg = cg_jnp(l1, l2, lo, h0.dtype)
            b = jnp.einsum("nca,ncb,abo->nco", A[l1], A[l2], cg)
            msg[lo] = msg[lo] + b * layer["w_B2"][pi][None, :, None]
        if layer["w_B3"] is not None:
            for pi, (l1, l2, l12, l3, lo) in enumerate(_prod3_paths(cfg.l_max)):
                cg_a = cg_jnp(l1, l2, l12, h0.dtype)
                cg_b = cg_jnp(l12, l3, lo, h0.dtype)
                t = jnp.einsum("nca,ncb,abi->nci", A[l1], A[l2], cg_a)
                b = jnp.einsum("nci,ncj,ijo->nco", t, A[l3], cg_b)
                msg[lo] = msg[lo] + b * layer["w_B3"][pi][None, :, None]

        # ---- update + residual ----
        new_feats = []
        for l in range(cfg.l_max + 1):
            f = msg[l]
            if l in ls_in:
                f = f + C_(
                    jnp.einsum(
                        "ncm,cd->ndm",
                        C_(feats[ls_in.index(l)], "mix_in"),
                        layer["w_res"][l],
                    ),
                    "node",
                )
            new_feats.append(C_(f, "node"))
        feats = new_feats
        ls_in = list(range(cfg.l_max + 1))

        # ---- per-layer invariant readout ----
        energy_nodes = energy_nodes + (
            feats[0][:, :, 0] @ layer["readout_w"]
        )[:, 0].astype(jnp.float32)

    h_inv = feats[0][:, :, 0]
    final = jax.nn.silu(h_inv @ params["final_w1"]) @ params["final_w2"]
    energy_nodes = energy_nodes + final[:, 0].astype(jnp.float32)
    return jax.ops.segment_sum(
        energy_nodes, graph["graph_ids"], graph["num_graphs"]
    )


def loss_fn(
    params,
    cfg: MACEConfig,
    graph,
    *,
    psum_axes: tuple[str, ...] = (),
    constrain=None,
) -> Array:
    pred = forward(params, cfg, graph, psum_axes=psum_axes, constrain=constrain)
    target = graph["labels"].astype(jnp.float32)
    return jnp.mean((pred - target) ** 2)
