"""Real spherical harmonics and real Clebsch-Gordan coefficients (l <= 3).

MACE needs CG tensor products over real-basis irreps. Instead of porting
complex-basis Racah algebra, we solve for the equivariant coupling tensors
numerically once at import time:

* real Wigner-D matrices are fit from the identity Y_l(R v) = D_l(R) Y_l(v)
  over a well-conditioned set of sample directions;
* the CG tensor C is the (1-dimensional) null space of the equivariance
  constraint C (D1 x D2) = D3 C stacked over a few random rotations.

This is exact up to float64 solve error (~1e-12) and keeps the whole stack
dependency-free. Coefficients are cached per (l1, l2, l3).
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

_SQRT_PI = np.sqrt(np.pi)


def num_m(l: int) -> int:
    return 2 * l + 1


def real_sph_harm_np(l: int, v: np.ndarray) -> np.ndarray:
    """Orthonormal real spherical harmonics on unit vectors v (N, 3)."""
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    if l == 0:
        return np.full(v.shape[:-1] + (1,), 0.5 / _SQRT_PI)
    if l == 1:
        c = np.sqrt(3.0 / (4 * np.pi))
        return np.stack([c * y, c * z, c * x], axis=-1)
    if l == 2:
        c1 = 0.5 * np.sqrt(15.0 / np.pi)
        c2 = 0.25 * np.sqrt(5.0 / np.pi)
        c3 = 0.25 * np.sqrt(15.0 / np.pi)
        return np.stack(
            [
                c1 * x * y,
                c1 * y * z,
                c2 * (3 * z * z - 1.0),
                c1 * x * z,
                c3 * (x * x - y * y),
            ],
            axis=-1,
        )
    if l == 3:
        return np.stack(
            [
                0.25 * np.sqrt(35 / (2 * np.pi)) * y * (3 * x * x - y * y),
                0.5 * np.sqrt(105 / np.pi) * x * y * z,
                0.25 * np.sqrt(21 / (2 * np.pi)) * y * (5 * z * z - 1),
                0.25 * np.sqrt(7 / np.pi) * z * (5 * z * z - 3),
                0.25 * np.sqrt(21 / (2 * np.pi)) * x * (5 * z * z - 1),
                0.25 * np.sqrt(105 / np.pi) * (x * x - y * y) * z,
                0.25 * np.sqrt(35 / (2 * np.pi)) * x * (x * x - 3 * y * y),
            ],
            axis=-1,
        )
    raise NotImplementedError(f"l={l} > 3")


def real_sph_harm(l: int, v: jax.Array) -> jax.Array:
    """jnp version (same formulas); v must be unit vectors (..., 3)."""
    x, y, z = v[..., 0], v[..., 1], v[..., 2]
    if l == 0:
        return jnp.full(v.shape[:-1] + (1,), 0.5 / _SQRT_PI, v.dtype)
    if l == 1:
        c = float(np.sqrt(3.0 / (4 * np.pi)))
        return jnp.stack([c * y, c * z, c * x], axis=-1)
    if l == 2:
        c1 = float(0.5 * np.sqrt(15.0 / np.pi))
        c2 = float(0.25 * np.sqrt(5.0 / np.pi))
        c3 = float(0.25 * np.sqrt(15.0 / np.pi))
        return jnp.stack(
            [
                c1 * x * y,
                c1 * y * z,
                c2 * (3 * z * z - 1.0),
                c1 * x * z,
                c3 * (x * x - y * y),
            ],
            axis=-1,
        )
    raise NotImplementedError(f"l={l} > 2 (jnp path)")


def _sample_dirs(k: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    v = rng.normal(size=(k, 3))
    return v / np.linalg.norm(v, axis=-1, keepdims=True)


def _rand_rotation(rng) -> np.ndarray:
    a = rng.normal(size=(3, 3))
    q, r = np.linalg.qr(a)
    q = q * np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] = -q[:, 0]
    return q


def wigner_d_real(l: int, rot: np.ndarray) -> np.ndarray:
    """Real Wigner-D: Y_l(R v) = D_l(R) @ Y_l(v) (column convention)."""
    dirs = _sample_dirs(max(4 * num_m(l), 16))
    a = real_sph_harm_np(l, dirs)  # (K, 2l+1)
    b = real_sph_harm_np(l, dirs @ rot.T)  # (K, 2l+1)
    dt, *_ = np.linalg.lstsq(a, b, rcond=None)
    return dt.T  # D such that Y(Rv) = D @ Y(v)


@functools.lru_cache(maxsize=None)
def clebsch_gordan_real(l1: int, l2: int, l3: int) -> np.ndarray | None:
    """Real coupling tensor C (2l1+1, 2l2+1, 2l3+1), Frobenius-normalized.

    Returns None when the triangle inequality fails. C satisfies, for every
    rotation R:  C_{a'b'c} D1_{a'a} D2_{b'b} = D3_{cc'} C_{abc'}.
    """
    if not (abs(l1 - l2) <= l3 <= l1 + l2):
        return None
    n1, n2, n3 = num_m(l1), num_m(l2), num_m(l3)
    rng = np.random.default_rng(12345)
    rows = []
    for _ in range(4):
        rot = _rand_rotation(rng)
        d1 = wigner_d_real(l1, rot)
        d2 = wigner_d_real(l2, rot)
        d3 = wigner_d_real(l3, rot)
        # constraint matrix acting on vec(C): (D1xD2xI - IxIxD3^T) vec = 0
        m = np.kron(np.kron(d1.T, d2.T), np.eye(n3)) - np.kron(
            np.kron(np.eye(n1), np.eye(n2)), d3
        )
        rows.append(m)
    m = np.concatenate(rows, axis=0)
    _u, s, vh = np.linalg.svd(m)
    null = vh[s.size - np.sum(s < 1e-8) :] if np.sum(s < 1e-8) else vh[-1:]
    # For l<=3 couplings of distinct irreps the null space is 1-dim.
    c = null[0].reshape(n1, n2, n3)
    c = c / np.linalg.norm(c)
    # Fix sign deterministically: first nonzero entry positive.
    flat = c.reshape(-1)
    idx = np.argmax(np.abs(flat) > 1e-10)
    if flat[idx] < 0:
        c = -c
    return c


def cg_jnp(l1: int, l2: int, l3: int, dtype=jnp.float32) -> jax.Array | None:
    c = clebsch_gordan_real(l1, l2, l3)
    return None if c is None else jnp.asarray(c, dtype)
