"""GNN architectures: GIN, GAT, EGNN, MACE -- all built on the
ops.scatter_gather / ops.segment message-passing substrate (the paper's
irregular-access regime)."""
