"""EGNN (E(n)-equivariant GNN), arXiv:2102.09844. Config: 4 layers, d=64.

m_ij   = phi_e(h_i, h_j, ||x_i - x_j||^2)
x_i'   = x_i + (1/deg_i) sum_j (x_i - x_j) phi_x(m_ij)
h_i'   = phi_h(h_i, sum_j m_ij)

Scalars are invariant and coordinates equivariant by construction; the
property test rotates inputs and checks both.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import he_init
from repro.ops.segment import segment_sum_dist

Array = jax.Array


@dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    num_layers: int = 4
    d_hidden: int = 64
    in_dim: int = 64
    out_dim: int = 1  # per-graph scalar (energy-style) or per-node
    readout: str = "graph"
    dtype: str = "float32"


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [
        {
            "w": he_init(ks[i], (dims[i], dims[i + 1]), dims[i], dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        }
        for i in range(len(dims) - 1)
    ]


def _mlp(layers, x, act=jax.nn.silu, last_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if i < len(layers) - 1 or last_act:
            x = act(x)
    return x


def init_params(key, cfg: EGNNConfig) -> dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_hidden
    keys = jax.random.split(key, cfg.num_layers + 2)
    layers = []
    for i in range(cfg.num_layers):
        k1, k2, k3 = jax.random.split(keys[i], 3)
        layers.append(
            {
                "edge_mlp": _mlp_init(k1, (2 * d + 1, d, d), dtype),
                "coord_mlp": _mlp_init(k2, (d, d, 1), dtype),
                "node_mlp": _mlp_init(k3, (2 * d, d, d), dtype),
            }
        )
    return {
        "embed": _mlp_init(keys[-2], (cfg.in_dim, d), dtype),
        "layers": layers,
        "head": _mlp_init(keys[-1], (d, d, cfg.out_dim), dtype),
    }


def forward(
    params,
    cfg: EGNNConfig,
    graph: dict[str, Array],
    *,
    psum_axes: tuple[str, ...] = (),
) -> tuple[Array, Array]:
    """Returns (readout, updated positions)."""
    h = _mlp(params["embed"], graph["node_feats"])
    x = graph["positions"].astype(jnp.float32)
    n = h.shape[0]
    src, dst = graph["src"], graph["dst"]
    deg = segment_sum_dist(
        jnp.ones((src.shape[0], 1), h.dtype), dst, n, psum_axes
    )
    inv_deg = 1.0 / jnp.maximum(deg, 1.0)
    for layer in params["layers"]:
        dx = x[dst] - x[src]  # (m, 3)
        dist2 = jnp.sum(dx * dx, axis=-1, keepdims=True).astype(h.dtype)
        m_ij = _mlp(
            layer["edge_mlp"],
            jnp.concatenate([h[dst], h[src], dist2], axis=-1),
            last_act=True,
        )
        coord_w = _mlp(layer["coord_mlp"], m_ij)  # (m, 1)
        x = x + segment_sum_dist(
            dx * coord_w.astype(jnp.float32), dst, n, psum_axes
        ) * inv_deg
        agg = segment_sum_dist(m_ij, dst, n, psum_axes)
        h = h + _mlp(
            layer["node_mlp"], jnp.concatenate([h, agg], axis=-1)
        )
    node_out = _mlp(params["head"], h)
    if cfg.readout == "graph":
        out = jax.ops.segment_sum(node_out, graph["graph_ids"], graph["num_graphs"])
    else:
        out = node_out
    return out, x


def loss_fn(
    params, cfg: EGNNConfig, graph, *, psum_axes: tuple[str, ...] = ()
) -> Array:
    pred, _x = forward(params, cfg, graph, psum_axes=psum_axes)
    target = graph["labels"].astype(jnp.float32)
    return jnp.mean((pred.squeeze(-1).astype(jnp.float32) - target) ** 2)
