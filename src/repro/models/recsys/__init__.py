"""RecSys: xDeepFM with huge sharded embedding tables (the paper's
irregular-gather regime at its purest: the lookup IS the hot path)."""
