"""xDeepFM (arXiv:1803.05170): linear + CIN + DNN over field embeddings.

Assigned config: 39 sparse fields, embed_dim 10, CIN 200-200-200, MLP
400-400. Embedding tables are stored as ONE stacked (n_fields * vocab, dim)
array sharded on rows over the "model" axis -- the row gather is exactly
the paper's irregular read, and the row-major AoS layout means one fetch
per (field, id) pair (guideline G5).

CIN (Compressed Interaction Network):
  x^{k+1}_{h} = sum_{i,j} W^{k}_{h,i,j} (x^k_i o x^0_j)   (o = Hadamard over D)
with per-layer sum pooling over D into the final logit.

The retrieval head (retrieval_cand shape) scores one user against 10^6
candidates with a factorized dot product (CIN is pairwise and cannot score
1M candidates per query; DESIGN.md notes this adaptation).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import he_init

Array = jax.Array


@dataclass(frozen=True)
class XDeepFMConfig:
    name: str = "xdeepfm"
    n_fields: int = 39
    vocab_per_field: int = 1_000_000
    embed_dim: int = 10
    cin_layers: tuple[int, ...] = (200, 200, 200)
    mlp_layers: tuple[int, ...] = (400, 400)
    retrieval_dim: int = 64
    n_candidates: int = 1_000_000
    dtype: str = "float32"


def init_params(key, cfg: XDeepFMConfig) -> dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    rows = cfg.n_fields * cfg.vocab_per_field
    keys = jax.random.split(key, 8 + len(cfg.cin_layers) + len(cfg.mlp_layers))
    p: dict[str, Any] = {
        "table": (jax.random.normal(keys[0], (rows, cfg.embed_dim)) * 0.01).astype(
            dtype
        ),
        "linear": (jax.random.normal(keys[1], (rows, 1)) * 0.01).astype(dtype),
        "bias": jnp.zeros((), dtype),
    }
    h_prev = cfg.n_fields
    cin = []
    for i, h in enumerate(cfg.cin_layers):
        cin.append(
            he_init(keys[2 + i], (h, h_prev, cfg.n_fields), h_prev * cfg.n_fields, dtype)
        )
        h_prev = h
    p["cin"] = cin
    p["cin_out"] = he_init(
        keys[2 + len(cin)], (sum(cfg.cin_layers), 1), sum(cfg.cin_layers), dtype
    )
    mlp = []
    d_in = cfg.n_fields * cfg.embed_dim
    base = 3 + len(cin)
    for i, d_out in enumerate(cfg.mlp_layers):
        mlp.append(
            {
                "w": he_init(keys[base + i], (d_in, d_out), d_in, dtype),
                "b": jnp.zeros((d_out,), dtype),
            }
        )
        d_in = d_out
    p["mlp"] = mlp
    p["mlp_out"] = he_init(keys[-3], (d_in, 1), d_in, dtype)
    # retrieval head: user projection + candidate tower table
    p["retrieval_proj"] = he_init(
        keys[-2], (d_in, cfg.retrieval_dim), d_in, dtype
    )
    p["cand_embed"] = (
        jax.random.normal(keys[-1], (cfg.n_candidates, cfg.retrieval_dim)) * 0.05
    ).astype(dtype)
    return p


def _lookup(params, cfg, sparse_ids: Array) -> Array:
    """sparse_ids: (B, n_fields) -> (B, n_fields, D). One row gather per
    (field, id); ids are offset into the stacked table."""
    offsets = (
        jnp.arange(cfg.n_fields, dtype=jnp.int32) * cfg.vocab_per_field
    )[None, :]
    rows = sparse_ids.astype(jnp.int32) + offsets
    return jnp.take(params["table"], rows.reshape(-1), axis=0).reshape(
        sparse_ids.shape[0], cfg.n_fields, cfg.embed_dim
    )


def _cin(params, x0: Array) -> Array:
    """x0: (B, m, D) -> pooled (B, sum(H_k))."""
    xk = x0
    pooled = []
    for w in params["cin"]:
        # z: (B, Hk_prev, m, D) outer Hadamard; compressed by W -> (B, H, D)
        xk = jnp.einsum("bhd,bmd,ohm->bod", xk, x0, w)
        pooled.append(jnp.sum(xk, axis=-1))  # sum-pool over D
    return jnp.concatenate(pooled, axis=-1)


def _dnn_hidden(params, x0_flat: Array) -> Array:
    h = x0_flat
    for layer in params["mlp"]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    return h


def forward(params, cfg: XDeepFMConfig, batch: dict[str, Array]) -> Array:
    """batch["sparse_ids"]: (B, n_fields) -> logits (B,)."""
    sparse_ids = batch["sparse_ids"]
    b = sparse_ids.shape[0]
    emb = _lookup(params, cfg, sparse_ids)  # (B, m, D)

    offsets = (
        jnp.arange(cfg.n_fields, dtype=jnp.int32) * cfg.vocab_per_field
    )[None, :]
    rows = sparse_ids.astype(jnp.int32) + offsets
    linear = jnp.take(params["linear"], rows.reshape(-1), axis=0).reshape(
        b, cfg.n_fields
    ).sum(axis=-1)

    cin_logit = (_cin(params, emb) @ params["cin_out"])[:, 0]
    hidden = _dnn_hidden(params, emb.reshape(b, -1))
    dnn_logit = (hidden @ params["mlp_out"])[:, 0]
    return linear + cin_logit + dnn_logit + params["bias"]


def loss_fn(params, cfg: XDeepFMConfig, batch: dict[str, Array]) -> Array:
    logits = forward(params, cfg, batch).astype(jnp.float32)
    labels = batch["labels"].astype(jnp.float32)
    # numerically stable BCE-with-logits
    return jnp.mean(
        jnp.maximum(logits, 0.0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def serve_step(params, cfg: XDeepFMConfig, batch: dict[str, Array]) -> Array:
    """CTR scores in [0,1] (serve_p99 / serve_bulk shapes)."""
    return jax.nn.sigmoid(forward(params, cfg, batch))


def serve_retrieval(
    params, cfg: XDeepFMConfig, batch: dict[str, Array], top_k: int = 100
):
    """retrieval_cand shape: one query scored against the candidate tower.

    batch["sparse_ids"]: (1, n_fields). Returns (scores (n_cand,), top-k ids).
    Batched dot, not a loop: (1, r) @ (r, n_cand).
    """
    emb = _lookup(params, cfg, batch["sparse_ids"])
    hidden = _dnn_hidden(params, emb.reshape(emb.shape[0], -1))
    user = hidden @ params["retrieval_proj"]  # (1, r)
    scores = (user @ params["cand_embed"].T)[0]  # (n_cand,)
    top = jax.lax.top_k(scores, top_k)
    return scores, top
