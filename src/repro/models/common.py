"""Shared model building blocks: norms, inits, RoPE, losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def trunc_normal(key, shape, scale: float, dtype=jnp.float32) -> Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * scale).astype(dtype)


def he_init(key, shape, fan_in: int, dtype=jnp.float32) -> Array:
    return trunc_normal(key, shape, (2.0 / max(fan_in, 1)) ** 0.5, dtype)


def lecun_init(key, shape, fan_in: int, dtype=jnp.float32) -> Array:
    return trunc_normal(key, shape, (1.0 / max(fan_in, 1)) ** 0.5, dtype)


def rms_norm(x: Array, gamma: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: Array, gamma: Array, beta: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma + beta).astype(x.dtype)


def rope_freqs(head_dim: int, theta: float, positions: Array) -> tuple[Array, Array]:
    """cos/sin tables for rotary embedding; positions (..., seq)."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., seq, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (..., seq, heads, head_dim); cos/sin: (..., seq, head_dim/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(
        x.dtype
    )


def activation_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    }[name]


def softmax_cross_entropy(logits: Array, labels: Array, ignore_id: int = -1):
    """Mean CE over non-ignored positions; logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None].clip(0), axis=-1
    ).squeeze(-1)
    nll = lse - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    total = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / total


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
