"""Model zoo: the assigned architectures, built on the ops substrate."""
