"""Decoder LM: init / forward / loss / KV-cache serving.

Layers are scanned (stacked params) so the HLO stays O(1) in depth -- a
hard requirement for compiling 61-layer DeepSeek-V3 on the 512-device
dry-run mesh. MoE models keep two stacks: the leading dense layers and the
MoE layers (DeepSeek-V3: 3 dense + 58 MoE).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from repro.compat import Mesh
from repro.distributed.sharding import ShardingRules, constrain, spec_for
from repro.ops.sharded_lookup import sharded_row_gather
from repro.models.common import (
    activation_fn,
    rms_norm,
    softmax_cross_entropy,
)
from repro.models.transformer.attention import (
    gqa_attention,
    gqa_decode,
    init_gqa_params,
    init_mla_params,
    mla_attention,
    mla_decode,
)
from repro.models.transformer.config import TransformerConfig
from repro.models.transformer.moe import init_moe_params, moe_ffn

Array = jax.Array


def _dtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_attn(key, cfg, dtype):
    if cfg.attention == "mla":
        return init_mla_params(key, cfg, dtype)
    return init_gqa_params(key, cfg, dtype)


def _init_dense_ffn(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(k2, (d, f)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(k3, (f, d)) * f ** -0.5).astype(dtype),
    }


def _init_layer(key, cfg, dtype, *, use_moe: bool):
    ka, kf = jax.random.split(key)
    layer = {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "attn": _init_attn(ka, cfg, dtype),
    }
    if use_moe:
        layer["moe"] = init_moe_params(kf, cfg, dtype)
    else:
        layer["ffn"] = _init_dense_ffn(kf, cfg, dtype)
    return layer


def init_params(key, cfg: TransformerConfig) -> dict[str, Any]:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 6)
    n_dense = cfg.num_dense_layers_effective()
    n_moe = cfg.num_moe_layers()
    params: dict[str, Any] = {
        "embed": (
            jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model)) * 0.02
        ).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(keys[1], (cfg.d_model, cfg.vocab_size))
            * cfg.d_model ** -0.5
        ).astype(dtype)
    if n_dense:
        params["dense_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, dtype, use_moe=False)
        )(jax.random.split(keys[2], n_dense))
    if n_moe:
        params["moe_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, dtype, use_moe=True)
        )(jax.random.split(keys[3], n_moe))
    if cfg.mtp_depth:
        params["mtp_layer"] = _init_layer(keys[4], cfg, dtype, use_moe=False)
        params["mtp_norm"] = jnp.zeros((cfg.d_model,), dtype)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _embed_lookup(params, cfg, tokens, mesh, rules):
    """Vocab-sharded token embedding via explicit partial-gather + psum."""
    if mesh is None or mesh.empty:
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        r = rules.for_mesh(mesh)
        x = sharded_row_gather(
            params["embed"], tokens, mesh, r.vocab,
            idx_spec=spec_for(r, "batch", None),
        )
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return constrain(x, mesh, rules, "batch", None, None)


def _dense_ffn(p, cfg, x):
    # bf16 end-to-end: the MXU accumulates f32 internally, and bf16
    # activations/cotangents HALVE every TP collective (Perf log).
    act = activation_fn(cfg.activation)
    h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * jnp.einsum(
        "bsd,df->bsf", x, p["w_up"]
    )
    # NOTE: no preferred_element_type here -- bf16 partials mean the TP
    # all-reduce of the down projection moves half the bytes (Perf log).
    return jnp.einsum("bsf,fd->bsd", h.astype(x.dtype), p["w_down"])


def _attn(p, cfg, x, positions, mesh=None, rules=None):
    if cfg.attention == "mla":
        return mla_attention(p, cfg, x, positions, mesh=mesh, rules=rules)
    return gqa_attention(p, cfg, x, positions, mesh=mesh, rules=rules)


def _layer_fwd(cfg, mesh, rules, use_moe):
    act = activation_fn(cfg.activation)

    def f(x, layer, positions):
        h = x + _attn(
            layer["attn"], cfg, rms_norm(x, layer["ln1"]), positions,
            mesh=mesh, rules=rules,
        )
        h = constrain(h, mesh, rules, "batch", None, None)
        hn = rms_norm(h, layer["ln2"])
        if use_moe:
            out = h + moe_ffn(layer["moe"], cfg, hn, act, mesh=mesh)
        else:
            out = h + _dense_ffn(layer["ffn"], cfg, hn)
        return constrain(out, mesh, rules, "batch", None, None)

    return f


def _scan_layers(x, stack, fwd, positions, remat: bool):
    f = (lambda c, l: (fwd(c, l, positions), None))
    if remat:
        f = jax.checkpoint(f, prevent_cse=False)
    x, _ = jax.lax.scan(f, x, stack)
    return x


def forward(
    params,
    cfg: TransformerConfig,
    tokens: Array,
    *,
    mesh: Mesh | None = None,
    rules: ShardingRules | None = None,
) -> Array:
    """tokens: (B, S) int32 -> logits (B, S, V)."""
    rules = rules or ShardingRules()
    b, s = tokens.shape
    x = _embed_lookup(params, cfg, tokens, mesh, rules)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    if "dense_layers" in params:
        x = _scan_layers(
            x,
            params["dense_layers"],
            _layer_fwd(cfg, mesh, rules, use_moe=False),
            positions,
            cfg.remat,
        )
    if "moe_layers" in params:
        x = _scan_layers(
            x,
            params["moe_layers"],
            _layer_fwd(cfg, mesh, rules, use_moe=True),
            positions,
            cfg.remat,
        )
    x = rms_norm(x, params["final_norm"])
    unembed = (
        params["embed"].T if cfg.tie_embeddings else params["unembed"]
    )
    logits = jnp.einsum("bsd,dv->bsv", x, unembed,
                        preferred_element_type=jnp.float32)
    return constrain(logits, mesh, rules, "batch", None, "vocab")


def _mtp_logits(params, cfg, x_final, tokens, mesh, rules):
    """DeepSeek-V3 multi-token prediction head (depth 1, simplified: the
    MTP block sees the trunk's final hidden states shifted one step and the
    embedding of the next token, then predicts token t+2)."""
    b, s = tokens.shape
    emb_next = _embed_lookup(params, cfg, tokens, mesh, rules)  # (B, S, d)
    h = rms_norm(x_final, params["mtp_norm"]) + emb_next
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    fwd = _layer_fwd(cfg, mesh, rules, use_moe=False)
    h = fwd(h, params["mtp_layer"], positions)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return jnp.einsum("bsd,dv->bsv", h, unembed,
                      preferred_element_type=jnp.float32)


def loss_fn(
    params,
    cfg: TransformerConfig,
    batch: dict[str, Array],
    *,
    mesh: Mesh | None = None,
    rules: ShardingRules | None = None,
    mtp_weight: float = 0.1,
) -> Array:
    """batch: tokens (B, S), labels (B, S) with -1 = ignore."""
    rules = rules or ShardingRules()
    tokens, labels = batch["tokens"], batch["labels"]
    b, s = tokens.shape
    x = _embed_lookup(params, cfg, tokens, mesh, rules)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if "dense_layers" in params:
        x = _scan_layers(
            x, params["dense_layers"],
            _layer_fwd(cfg, mesh, rules, use_moe=False), positions, cfg.remat,
        )
    if "moe_layers" in params:
        x = _scan_layers(
            x, params["moe_layers"],
            _layer_fwd(cfg, mesh, rules, use_moe=True), positions, cfg.remat,
        )
    xf = rms_norm(x, params["final_norm"])
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", xf, unembed,
                        preferred_element_type=jnp.float32)
    logits = constrain(logits, mesh, rules, "batch", None, "vocab")
    loss = softmax_cross_entropy(logits, labels)
    if cfg.mtp_depth and "mtp_layer" in params:
        # labels for t+2: shift labels left by one, pad with ignore.
        mtp_labels = jnp.concatenate(
            [labels[:, 1:], jnp.full((b, 1), -1, labels.dtype)], axis=1
        )
        mtp_logits = _mtp_logits(params, cfg, x, tokens, mesh, rules)
        loss = loss + mtp_weight * softmax_cross_entropy(mtp_logits, mtp_labels)
    return loss


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def cache_length(cfg: TransformerConfig, max_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(max_len, cfg.sliding_window)
    return max_len


def init_kv_cache(cfg: TransformerConfig, batch: int, max_len: int):
    """Stacked per-layer caches. GQA: ring (L, B, C, hkv, hd) pairs.
    MLA: compressed latent (L, B, C, kv_lora) + rope keys (L, B, C, dr)."""
    dtype = _dtype(cfg)
    clen = cache_length(cfg, max_len)
    n_dense = cfg.num_dense_layers_effective()
    n_moe = cfg.num_moe_layers()

    def stack(n):
        if cfg.attention == "mla":
            return {
                "ckv": jnp.zeros((n, batch, clen, cfg.kv_lora_rank), dtype),
                "krope": jnp.zeros((n, batch, clen, cfg.qk_rope_head_dim), dtype),
            }
        return {
            "k": jnp.zeros(
                (n, batch, clen, cfg.num_kv_heads, cfg.head_dim), dtype
            ),
            "v": jnp.zeros(
                (n, batch, clen, cfg.num_kv_heads, cfg.head_dim), dtype
            ),
        }

    cache = {}
    if n_dense:
        cache["dense"] = stack(n_dense)
    if n_moe:
        cache["moe"] = stack(n_moe)
    return cache


def _decode_layer(cfg, mesh, rules, use_moe):
    act = activation_fn(cfg.activation)

    def f(carry, layer_and_cache):
        x, pos = carry
        layer, cache = layer_and_cache
        hn = rms_norm(x, layer["ln1"])
        if cfg.attention == "mla":
            attn_out, ckv, krope = mla_decode(
                layer["attn"], cfg, hn, cache["ckv"], cache["krope"], pos
            )
            new_cache = {"ckv": ckv, "krope": krope}
        else:
            attn_out, ck, cv = gqa_decode(
                layer["attn"], cfg, hn, cache["k"], cache["v"], pos
            )
            new_cache = {"k": ck, "v": cv}
        h = x + attn_out
        hn2 = rms_norm(h, layer["ln2"])
        if use_moe:
            out = h + moe_ffn(layer["moe"], cfg, hn2, act, mesh=mesh)
        else:
            out = h + _dense_ffn(layer["ffn"], cfg, hn2)
        return (out, pos), new_cache

    return f


def serve_step(
    params,
    cfg: TransformerConfig,
    cache,
    tokens: Array,  # (B, 1)
    pos: Array,  # scalar int32: index of the new token
    *,
    mesh: Mesh | None = None,
    rules: ShardingRules | None = None,
):
    """One decode step; returns (logits (B, 1, V), new_cache)."""
    rules = rules or ShardingRules()
    x = _embed_lookup(params, cfg, tokens, mesh, rules)
    new_cache = {}
    if "dense_layers" in params:
        (x, _), new_cache["dense"] = jax.lax.scan(
            _decode_layer(cfg, mesh, rules, use_moe=False),
            (x, pos),
            (params["dense_layers"], cache["dense"]),
        )
    if "moe_layers" in params:
        (x, _), new_cache["moe"] = jax.lax.scan(
            _decode_layer(cfg, mesh, rules, use_moe=True),
            (x, pos),
            (params["moe_layers"], cache["moe"]),
        )
    x = rms_norm(x, params["final_norm"])
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, unembed,
                        preferred_element_type=jnp.float32)
    return constrain(logits, mesh, rules, "batch", None, "vocab"), new_cache


def prefill(
    params,
    cfg: TransformerConfig,
    tokens: Array,  # (B, S)
    max_len: int,
    *,
    mesh: Mesh | None = None,
    rules: ShardingRules | None = None,
):
    """Sequential prefill via serve_step (simple reference path for the
    examples; production prefill would batch this)."""
    b, s = tokens.shape
    cache = init_kv_cache(cfg, b, max_len)
    logits = None
    for i in range(s):
        logits, cache = serve_step(
            params, cfg, cache, tokens[:, i : i + 1],
            jnp.int32(i), mesh=mesh, rules=rules,
        )
    return logits, cache
