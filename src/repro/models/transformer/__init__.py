from repro.models.transformer.config import TransformerConfig, MoEConfig
from repro.models.transformer.model import (
    init_params,
    forward,
    loss_fn,
    init_kv_cache,
    serve_step,
    prefill,
)

__all__ = [
    "TransformerConfig",
    "MoEConfig",
    "init_params",
    "forward",
    "loss_fn",
    "init_kv_cache",
    "serve_step",
    "prefill",
]
