"""Decoder-LM configuration covering all five assigned LM architectures."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    # "sorted_ep": sort-by-expert + all_to_all over the expert-sharded axis
    #              (the paper's coalescing guideline at pod scale).
    # "unsorted":  same buffers built by raw scatter without the sort
    #              (the uncoalesced baseline for the A/B).
    dispatch: str = "sorted_ep"
    router_renorm: bool = True  # renormalize top-k gate weights
    # Mesh axes jointly treated as the flat expert-parallel axis. DeepSeek's
    # 256 experts shard over ("data", "model") = 256 devices per pod.
    ep_axes: tuple[str, ...] = ("model",)
    # Quantize the dispatch-direction all-to-all payload (DeepSeek trains
    # with fp8 dispatch; combine stays bf16). None = full precision.
    a2a_dtype: str | None = None


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    activation: str = "silu"  # silu => SwiGLU, gelu_tanh => GeGLU
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: int | None = None  # Mixtral SWA
    attention: str = "gqa"  # "gqa" | "mla"
    # MLA (DeepSeek-V3) dims
    q_lora_rank: int | None = None
    kv_lora_rank: int = 512
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128
    # MoE
    moe: MoEConfig | None = None
    num_dense_layers: int = 0  # leading dense layers (DeepSeek-V3 uses 3)
    # Multi-token prediction (DeepSeek-V3): extra depth-1 MTP head
    mtp_depth: int = 0
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d)
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    logical_rules: dict = field(default_factory=dict)

    @property
    def q_dim(self) -> int:
        if self.attention == "mla":
            return self.num_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
        return self.num_heads * self.head_dim

    @property
    def attn_out_dim(self) -> int:
        if self.attention == "mla":
            return self.num_heads * self.v_head_dim
        return self.num_heads * self.head_dim

    def num_moe_layers(self) -> int:
        return 0 if self.moe is None else self.num_layers - self.num_dense_layers

    def param_count_dense_layer(self) -> int:
        d = self.d_model
        if self.attention == "mla":
            attn = (
                d * (self.q_lora_rank or self.q_dim)
                + (self.q_lora_rank or 0) * self.q_dim
                + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                + self.kv_lora_rank
                * self.num_heads
                * (self.qk_nope_head_dim + self.v_head_dim)
                + self.attn_out_dim * d
            )
        else:
            attn = d * self.q_dim + 2 * d * self.num_kv_heads * self.head_dim
            attn += self.attn_out_dim * d
        ffn = 3 * d * self.d_ff
        return attn + ffn

    def param_count_moe_layer(self) -> int:
        assert self.moe is not None
        d = self.d_model
        base = self.param_count_dense_layer() - 3 * d * self.d_ff
        experts = 3 * d * self.moe.d_ff_expert * self.moe.num_experts
        shared = 3 * d * self.moe.d_ff_expert * self.moe.num_shared_experts
        router = d * self.moe.num_experts
        return base + experts + shared + router

    def total_params(self) -> int:
        n = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        n += self.num_dense_layers_effective() * self.param_count_dense_layer()
        n += self.num_moe_layers() * (
            self.param_count_moe_layer() if self.moe else 0
        )
        return n

    def num_dense_layers_effective(self) -> int:
        return self.num_layers if self.moe is None else self.num_dense_layers

    def active_params(self) -> int:
        """Activated parameters per token (for MoE model FLOP accounting)."""
        if self.moe is None:
            return self.total_params()
        d = self.d_model
        base = self.param_count_dense_layer() - 3 * d * self.d_ff
        act_ffn = 3 * d * self.moe.d_ff_expert * (
            self.moe.top_k + self.moe.num_shared_experts
        )
        n = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        n += self.num_dense_layers * self.param_count_dense_layer()
        n += self.num_moe_layers() * (base + act_ffn + d * self.moe.num_experts)
        return n
