"""Attention variants: GQA/MQA (gemma/phi3/qwen3/mixtral) and MLA (DeepSeek).

Training uses the flash_attention kernel wrapper (Pallas on TPU, jnp oracle
elsewhere). Decode paths operate on static-shaped KV caches with masked
lengths so serve_step compiles once per cache geometry.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.models.common import apply_rope, rms_norm, rope_freqs

Array = jax.Array


def _attn_shardings(cfg, mesh, rules):
    """Pick head-TP vs pure-DP attention per head-count divisibility.

    Without explicit constraints GSPMD may split the CONTRACTION of the
    score einsum across 'model' and all-reduce the (B, H, S, S) score
    tensor in f32 -- measured 116 GB/step on gemma-2b train_4k. Pinning
    q (and kv when divisible) to head sharding, or falling back to
    batch-only attention, keeps scores device-local.
    """
    if mesh is None or mesh.empty or rules is None:
        return None
    import jax as _jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    msize = mesh.shape.get("model", 1)
    r = rules.for_mesh(mesh)
    batch = r.batch

    def mk(heads_sharded):
        return NamedSharding(
            mesh, P(batch, None, "model" if heads_sharded else None, None)
        )

    q_spec = mk(cfg.num_heads % msize == 0 and msize > 1)
    kv_spec = mk(cfg.num_kv_heads % msize == 0 and msize > 1)

    def constrain_qkv(q, k, v):
        return (
            _jax.lax.with_sharding_constraint(q, q_spec),
            _jax.lax.with_sharding_constraint(k, kv_spec),
            _jax.lax.with_sharding_constraint(v, kv_spec),
        )

    return constrain_qkv


# ---------------------------------------------------------------------------
# GQA family
# ---------------------------------------------------------------------------


def init_gqa_params(key, cfg, dtype) -> dict[str, Any]:
    d = cfg.d_model
    hd, hq, hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, hq * hd)) * scale).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, hkv * hd)) * scale).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, hkv * hd)) * scale).astype(dtype),
        "wo": (
            jax.random.normal(ks[3], (hq * hd, d)) * (hq * hd) ** -0.5
        ).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def gqa_attention(
    p, cfg, x: Array, positions: Array, *, mesh=None, rules=None
) -> Array:
    """Training/prefill attention. x: (B, S, d); positions: (B, S)."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    constrain_qkv = _attn_shardings(cfg, mesh, rules)
    if constrain_qkv is not None:
        q, k, v = constrain_qkv(q, k, v)
    out = flash_attention(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=True,
        window=cfg.sliding_window,
    )
    return out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd) @ p["wo"]


def gqa_decode(
    p, cfg, x: Array, cache_k: Array, cache_v: Array, pos: Array
) -> tuple[Array, Array, Array]:
    """One-token decode. x: (B, 1, d); cache_k/v: (B, L, hkv, hd); pos: ().

    With a sliding window the cache is a ring buffer of length
    min(window, L) and writes wrap (pos % cache_len).
    """
    b, _, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    cache_len = cache_k.shape[1]
    q = (x @ p["wq"]).reshape(b, 1, hq, hd)
    k = (x @ p["wk"]).reshape(b, 1, hkv, hd)
    v = (x @ p["wv"]).reshape(b, 1, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    posb = jnp.full((b, 1), pos, jnp.int32)
    cos, sin = rope_freqs(hd, cfg.rope_theta, posb)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    slot = pos % cache_len  # ring-buffer write (no-op when cache covers seq)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))

    group = hq // hkv
    kr = jnp.repeat(cache_k, group, axis=2)  # (B, L, hq, hd)
    vr = jnp.repeat(cache_v, group, axis=2)
    scores = jnp.einsum(
        "bqhd,blhd->bhql", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) / (hd ** 0.5)
    # Valid cache slots: absolute position of slot l is recoverable because
    # the ring advances monotonically; slot l holds some position <= pos,
    # and with window w only the last min(pos+1, w) slots are live.
    idx = jnp.arange(cache_len)
    if cfg.sliding_window is not None and cache_len <= cfg.sliding_window:
        live = idx < jnp.minimum(pos + 1, cache_len)
    else:
        live = idx <= pos
        if cfg.sliding_window is not None:
            live &= idx > pos - cfg.sliding_window
    scores = jnp.where(live[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhql,blhd->bqhd", probs, vr.astype(jnp.float32))
    out = ctx.astype(x.dtype).reshape(b, 1, hq * hd) @ p["wo"]
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------


def init_mla_params(key, cfg, dtype) -> dict[str, Any]:
    d = cfg.d_model
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    p = {}
    if qr:
        p["wq_a"] = (jax.random.normal(ks[0], (d, qr)) * d ** -0.5).astype(dtype)
        p["q_norm"] = jnp.zeros((qr,), dtype)
        p["wq_b"] = (
            jax.random.normal(ks[1], (qr, h * (dn + dr))) * qr ** -0.5
        ).astype(dtype)
    else:
        p["wq"] = (
            jax.random.normal(ks[0], (d, h * (dn + dr))) * d ** -0.5
        ).astype(dtype)
    p["wkv_a"] = (
        jax.random.normal(ks[2], (d, kr + dr)) * d ** -0.5
    ).astype(dtype)
    p["kv_norm"] = jnp.zeros((kr,), dtype)
    p["wkv_b"] = (
        jax.random.normal(ks[3], (kr, h * (dn + dv))) * kr ** -0.5
    ).astype(dtype)
    p["wo"] = (
        jax.random.normal(ks[4], (h * dv, d)) * (h * dv) ** -0.5
    ).astype(dtype)
    return p


def _mla_qkv(p, cfg, x, positions):
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        q = rms_norm(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = rope_freqs(dr, cfg.rope_theta, positions)
    q_rope = apply_rope(q_rope, cos, sin)

    kv = x @ p["wkv_a"]  # (b, s, kr + dr)
    c_kv = rms_norm(kv[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(kv[..., cfg.kv_lora_rank :][:, :, None, :], cos, sin)
    return q_nope, q_rope, c_kv, k_rope[:, :, 0, :]


def mla_attention(
    p, cfg, x: Array, positions: Array, *, mesh=None, rules=None
) -> Array:
    """Training/prefill MLA: expand the latent, run standard attention."""
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, cfg, x, positions)
    kv = (c_kv @ p["wkv_b"]).reshape(b, s, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    constrain_qkv = _attn_shardings(cfg, mesh, rules)
    if constrain_qkv is not None:
        q, k, v = constrain_qkv(q, k, v)
    out = attention_ref(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        causal=True,
    )
    return out.transpose(0, 2, 1, 3).reshape(b, s, h * dv) @ p["wo"]


def mla_decode(
    p, cfg, x: Array, cache_ckv: Array, cache_krope: Array, pos: Array
) -> tuple[Array, Array, Array]:
    """Absorbed-matmul MLA decode over the compressed cache.

    cache_ckv: (B, L, kv_lora); cache_krope: (B, L, dr). Scores are computed
    directly against the latent (q absorbed through W_uk); context is read
    in latent space and expanded through W_uv afterwards -- the production
    decode path that makes MLA's cache 9x smaller than GQA's.
    """
    b, _, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kr = cfg.kv_lora_rank
    posb = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(p, cfg, x, posb)

    cache_ckv = jax.lax.dynamic_update_slice(cache_ckv, c_kv_new, (0, pos, 0))
    cache_krope = jax.lax.dynamic_update_slice(
        cache_krope, k_rope_new, (0, pos, 0)
    )

    wkv_b = p["wkv_b"].reshape(kr, h, dn + dv)
    w_uk = wkv_b[..., :dn]  # (kr, h, dn)
    w_uv = wkv_b[..., dn:]  # (kr, h, dv)
    # Absorb: q_eff[b,h,kr] = q_nope[b,h,dn] . w_uk[kr,h,dn]
    q_eff = jnp.einsum("bqhd,khd->bqhk", q_nope.astype(jnp.float32), w_uk)
    s_nope = jnp.einsum("bqhk,blk->bhql", q_eff, cache_ckv.astype(jnp.float32))
    s_rope = jnp.einsum(
        "bqhd,bld->bhql", q_rope.astype(jnp.float32), cache_krope.astype(jnp.float32)
    )
    scores = (s_nope + s_rope) / ((dn + dr) ** 0.5)
    live = jnp.arange(cache_ckv.shape[1]) <= pos
    scores = jnp.where(live[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhql,blk->bqhk", probs, cache_ckv.astype(jnp.float32))
    ctx = jnp.einsum("bqhk,khd->bqhd", ctx_lat, w_uv)
    out = ctx.astype(x.dtype).reshape(b, 1, h * dv) @ p["wo"]
    return out, cache_ckv, cache_krope
