"""Mixture-of-Experts layer with sort-based (coalesced) token dispatch.

The paper-technique integration point for the LM family: top-k expert
routing is an irregular scatter/gather, and we treat it exactly like the
paper treats list pointers -- sort tokens by expert id so every downstream
access is a contiguous block (guideline G1), keep the per-(expert, slot)
bookkeeping packed (G5), and express drops/capacity branch-free (G3).

Two distributed schedules, chosen per mesh/shape:

* ``all_to_all`` EP (DeepSeek-style): tokens are sliced along the "model"
  axis inside the block, routed locally, exchanged with two all_to_alls so
  each device runs only its E/tp experts, then all_gathered back.
  Used when E % tp == 0 and there are enough tokens to slice.
* ``expert-TP`` (Mixtral-style): every device runs all experts over the
  d_ff/tp slice and the outputs are psum'd -- the dense-FFN TP pattern.
  Used when E < tp (8 experts on a 16-wide axis) or for tiny decode steps.

The unsorted dispatch variant (``dispatch="unsorted"``) builds identical
buffers through a raw scatter without the pre-sort; it is semantically
identical (same drops) and exists as the uncoalesced baseline for the
paper's A/B (benchmarks/moe_dispatch.py).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import Mesh, shard_map
from repro.ops.sorted_dispatch import sort_by_key

Array = jax.Array


def init_moe_params(key, cfg, dtype) -> dict[str, Any]:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 7)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(
            jnp.float32
        ),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * f ** -0.5).astype(dtype),
    }
    if m.num_shared_experts:
        fs = f * m.num_shared_experts
        p["w_gate_shared"] = (
            jax.random.normal(ks[4], (d, fs)) * d ** -0.5
        ).astype(dtype)
        p["w_up_shared"] = (
            jax.random.normal(ks[5], (d, fs)) * d ** -0.5
        ).astype(dtype)
        p["w_down_shared"] = (
            jax.random.normal(ks[6], (fs, d)) * fs ** -0.5
        ).astype(dtype)
    return p


def _route(tokens: Array, router: Array, m) -> tuple[Array, Array]:
    """fp32 router -> (gates (T,k), expert ids (T,k))."""
    logits = tokens.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, m.top_k)
    if m.router_renorm:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, eidx.astype(jnp.int32)


def _dispatch(tokens, gates, eidx, m, num_experts, capacity):
    """Pack token copies into a dense (E, C, d) buffer.

    Returns (buffer, slot, kept, token_of_row, gate_of_row). The sorted
    variant derives in-group positions from the sort (O(T k)); the unsorted
    baseline pays an O(T k E) one-hot cumsum and scatters in token order.
    Drop sets are identical (first-arrival in token order, both stable).
    """
    T, d = tokens.shape
    k = m.top_k
    flat_e = eidx.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    flat_gate = gates.reshape(-1)

    if m.dispatch == "sorted_ep":
        keys, perm, tok_s, gate_s = sort_by_key(flat_e, flat_tok, flat_gate)
        counts = jax.ops.segment_sum(
            jnp.ones_like(keys), keys, num_experts, indices_are_sorted=True
        )
        offsets = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)]
        )
        pos = jnp.arange(T * k, dtype=jnp.int32) - offsets[keys]
    elif m.dispatch == "unsorted":
        keys, tok_s, gate_s = flat_e, flat_tok, flat_gate
        onehot = jax.nn.one_hot(keys, num_experts, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - onehot)[
            jnp.arange(T * k), keys
        ]
    else:
        raise ValueError(f"unknown dispatch {m.dispatch!r}")

    kept = pos < capacity
    slot = keys * capacity + pos
    slot = jnp.where(kept, slot, num_experts * capacity)
    buf = jnp.zeros((num_experts * capacity, tokens.shape[1]), tokens.dtype)
    buf = buf.at[slot].set(tokens[tok_s], mode="drop")
    return (
        buf.reshape(num_experts, capacity, -1),
        slot,
        kept,
        tok_s,
        gate_s,
    )


def _combine(expert_rows, slot, kept, tok_s, gate_s, num_tokens, dtype):
    rows = expert_rows.reshape(-1, expert_rows.shape[-1])
    safe = jnp.clip(slot, 0, rows.shape[0] - 1)
    contrib = jnp.where(kept[:, None], rows[safe], 0.0)
    contrib = contrib * gate_s[:, None].astype(contrib.dtype)
    out = jnp.zeros((num_tokens, rows.shape[-1]), contrib.dtype)
    return out.at[tok_s].add(contrib).astype(dtype)


def _expert_ffn(buf, w_gate, w_up, w_down, act):
    h = act(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", buf, w_up
    )
    return jnp.einsum("ecf,efd->ecd", h.astype(buf.dtype), w_down)


def _shared_ffn(x, p, act):
    h = act(jnp.einsum("td,df->tf", x, p["w_gate_shared"])) * jnp.einsum(
        "td,df->tf", x, p["w_up_shared"]
    )
    return jnp.einsum("tf,fd->td", h.astype(x.dtype), p["w_down_shared"])


def _capacity(tokens_per_shard: int, m, num_experts: int) -> int:
    return max(
        1,
        math.ceil(tokens_per_shard * m.top_k / num_experts * m.capacity_factor),
    )


def moe_ffn_local(p, cfg, x: Array, act) -> Array:
    """Single-shard MoE (tests, smoke configs, meshless runs)."""
    m = cfg.moe
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    gates, eidx = _route(tokens, p["router"], m)
    cap = _capacity(tokens.shape[0], m, m.num_experts)
    buf, slot, kept, tok_s, gate_s = _dispatch(
        tokens, gates, eidx, m, m.num_experts, cap
    )
    outs = _expert_ffn(buf, p["w_gate"], p["w_up"], p["w_down"], act)
    out = _combine(outs, slot, kept, tok_s, gate_s, tokens.shape[0], x.dtype)
    if m.num_shared_experts:
        out = out + _shared_ffn(tokens, p, act)
    return out.reshape(b, s, d)


def moe_ffn(
    p,
    cfg,
    x: Array,
    act,
    *,
    mesh: Mesh | None = None,
    dp_axes: tuple[str, ...] = ("pod", "data"),
    tp_axis: str = "model",
) -> Array:
    """Distributed MoE layer. x: (B, S, d) sharded over dp_axes on batch."""
    if mesh is None or mesh.empty or tp_axis not in mesh.axis_names:
        return moe_ffn_local(p, cfg, x, act)

    m = cfg.moe
    dp_axes = tuple(a for a in dp_axes if a in mesh.axis_names)
    b, s, d = x.shape
    # tiny/odd batches (e.g. long-context decode with B=1) can't shard the
    # batch dim -- fall back to replicated tokens (still correct).
    while dp_axes and b % math.prod(mesh.shape[a] for a in dp_axes):
        dp_axes = dp_axes[:-1]
    tp = mesh.shape[tp_axis]
    dp = math.prod(mesh.shape[a] for a in dp_axes) if dp_axes else 1
    t_local = (b // dp) * s
    # Flat expert-parallel axis (possibly spanning data+model for big E).
    ep_axes = tuple(a for a in m.ep_axes if a in mesh.axis_names) or (tp_axis,)
    ep_size = math.prod(mesh.shape[a] for a in ep_axes)
    use_a2a = (
        m.num_experts % ep_size == 0
        and t_local % tp == 0
        and t_local >= tp
        and ep_size > 1
    )

    x_spec = P(dp_axes if dp_axes else None, None, None)

    if use_a2a:
        e_local = m.num_experts // ep_size
        chunk = t_local // tp
        cap = _capacity(chunk, m, m.num_experts)

        def block(xb, router, wg, wu, wd, shared):
            tokens = xb.reshape(-1, d)
            mi = jax.lax.axis_index(tp_axis)
            my = jax.lax.dynamic_slice_in_dim(tokens, mi * chunk, chunk, 0)
            gates, eidx = _route(my, router, m)
            buf, slot, kept, tok_s, gate_s = _dispatch(
                my, gates, eidx, m, m.num_experts, cap
            )
            # exchange: every peer sends each expert-shard its slice.
            # Optionally quantize the dispatch payload (fp8 + per-row bf16
            # scale): halves the dominant wire traffic; combine stays bf16.
            if m.a2a_dtype is not None:
                qdt = jnp.dtype(m.a2a_dtype)
                scale = jnp.max(jnp.abs(buf), axis=-1, keepdims=True).astype(
                    jnp.float32
                ) / 448.0 + 1e-12
                qbuf = (buf.astype(jnp.float32) / scale).astype(qdt)
                qy = jax.lax.all_to_all(
                    qbuf, ep_axes, split_axis=0, concat_axis=1, tiled=True
                )
                sy = jax.lax.all_to_all(
                    scale.astype(jnp.bfloat16), ep_axes,
                    split_axis=0, concat_axis=1, tiled=True,
                )
                y = (qy.astype(jnp.float32) * sy.astype(jnp.float32)).astype(
                    buf.dtype
                )
            else:
                y = jax.lax.all_to_all(
                    buf, ep_axes, split_axis=0, concat_axis=1, tiled=True
                )  # (e_local, ep_size * cap, d)
            outs = _expert_ffn(y, wg, wu, wd, act)
            z = jax.lax.all_to_all(
                outs, ep_axes, split_axis=1, concat_axis=0, tiled=True
            )  # (num_experts, cap, d)
            out = _combine(z, slot, kept, tok_s, gate_s, chunk, x.dtype)
            if shared is not None:
                out = out + _shared_ffn(my, shared, act)
            full = jax.lax.all_gather(out, tp_axis, axis=0, tiled=True)
            return full.reshape(xb.shape)

        shared = (
            {k: p[k] for k in p if k.endswith("_shared")}
            if m.num_shared_experts
            else None
        )
        return shard_map(
            lambda xb, r, wg, wu, wd, sh: block(xb, r, wg, wu, wd, sh),
            mesh=mesh,
            in_specs=(
                x_spec,
                P(),  # router replicated
                P(ep_axes, None, None),  # experts sharded over the EP axes
                P(ep_axes, None, None),
                P(ep_axes, None, None),
                (
                    jax.tree.map(lambda _: P(), shared)
                    if shared is not None
                    else None
                ),
            ),
            out_specs=x_spec,
            check_vma=False,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)

    # ---- small-batch EP (decode): experts STAY put, tokens move ----------
    # The naive fallback would reshard the (huge) expert weights to an
    # expert-TP layout -- an all-gather of the full expert bank per layer
    # (measured 52s/step for deepseek decode_32k). Instead: gather the
    # (tiny) token set across the data portion of the EP axes, compute each
    # device's resident experts densely on all tokens, and psum the result.
    if m.num_experts % ep_size == 0 and ep_size > 1:
        e_local = m.num_experts // ep_size
        gather_axes = tuple(a for a in ep_axes if a in dp_axes)

        def block_psum(xb, router, wg, wu, wd, shared):
            tokens_local = xb.reshape(-1, d)
            tokens = (
                jax.lax.all_gather(tokens_local, gather_axes, axis=0, tiled=True)
                if gather_axes
                else tokens_local
            )
            gates, eidx = _route(tokens, router, m)  # (T, k)
            idxs = [jax.lax.axis_index(a) for a in ep_axes]
            flat = idxs[0]
            for a, i in zip(ep_axes[1:], idxs[1:]):
                flat = flat * mesh.shape[a] + i
            e0 = flat * e_local
            # (T, e_local) gate mass routed to MY experts (0 elsewhere)
            match = (
                eidx[:, :, None]
                == (e0 + jnp.arange(e_local, dtype=jnp.int32))[None, None, :]
            )
            gate_local = jnp.sum(
                gates[:, :, None] * match.astype(gates.dtype), axis=1
            )  # (T, e_local)
            h = act(
                jnp.einsum("td,edf->tef", tokens, wg,
                           preferred_element_type=jnp.float32)
            ) * jnp.einsum("td,edf->tef", tokens, wu,
                           preferred_element_type=jnp.float32)
            y = jnp.einsum("tef,efd->ted", h.astype(tokens.dtype), wd,
                           preferred_element_type=jnp.float32)
            out = jnp.einsum(
                "ted,te->td", y, gate_local.astype(y.dtype)
            ).astype(x.dtype)
            out = jax.lax.psum(out, ep_axes)
            if gather_axes:
                gi = jax.lax.axis_index(gather_axes[0])
                for a in gather_axes[1:]:
                    gi = gi * mesh.shape[a] + jax.lax.axis_index(a)
                out = jax.lax.dynamic_slice_in_dim(
                    out, gi * tokens_local.shape[0], tokens_local.shape[0], 0
                )
            if shared is not None:
                # shared expert: f sliced over tp, partial-summed over model
                sh = _shared_ffn(tokens_local, shared, act)
                out = out + jax.lax.psum(sh, tp_axis)
            return out.reshape(xb.shape)

        shared = None
        if m.num_shared_experts:
            shared = {
                "w_gate_shared": p["w_gate_shared"],
                "w_up_shared": p["w_up_shared"],
                "w_down_shared": p["w_down_shared"],
            }
        return shard_map(
            lambda xb, r, wg, wu, wd, sh: block_psum(xb, r, wg, wu, wd, sh),
            mesh=mesh,
            in_specs=(
                x_spec,
                P(),
                P(ep_axes, None, None),  # weights stay in storage layout
                P(ep_axes, None, None),
                P(ep_axes, None, None),
                (
                    {
                        "w_gate_shared": P(None, tp_axis),
                        "w_up_shared": P(None, tp_axis),
                        "w_down_shared": P(tp_axis, None),
                    }
                    if shared is not None
                    else None
                ),
            ),
            out_specs=x_spec,
            check_vma=False,
        )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)

    # ---- expert-TP fallback: all experts on every peer, d_ff sliced ----
    cap = _capacity(t_local, m, m.num_experts)

    def block_tp(xb, router, wg, wu, wd, shared):
        tokens = xb.reshape(-1, d)
        gates, eidx = _route(tokens, router, m)
        buf, slot, kept, tok_s, gate_s = _dispatch(
            tokens, gates, eidx, m, m.num_experts, cap
        )
        outs = _expert_ffn(buf, wg, wu, wd, act)  # partial over f slice
        out = _combine(outs, slot, kept, tok_s, gate_s, tokens.shape[0], x.dtype)
        if shared is not None:
            out = out + _shared_ffn(tokens, shared, act)
        out = jax.lax.psum(out, tp_axis)
        return out.reshape(xb.shape)

    shared = None
    if m.num_shared_experts:
        shared = {
            "w_gate_shared": p["w_gate_shared"],
            "w_up_shared": p["w_up_shared"],
            "w_down_shared": p["w_down_shared"],
        }
    return shard_map(
        lambda xb, r, wg, wu, wd, sh: block_tp(xb, r, wg, wu, wd, sh),
        mesh=mesh,
        in_specs=(
            x_spec,
            P(),
            P(None, None, tp_axis),  # f sliced
            P(None, None, tp_axis),
            P(None, tp_axis, None),
            (
                {
                    "w_gate_shared": P(None, tp_axis),
                    "w_up_shared": P(None, tp_axis),
                    "w_down_shared": P(tp_axis, None),
                }
                if shared is not None
                else None
            ),
        ),
        out_specs=x_spec,
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared)
