"""PRAM -> TPU adaptation utilities (paper section 2).

The paper's guidelines are reified here as concrete primitives:

* G1 striding vs partitioning: the two canonical assignments of N data items
  to p lanes, exposed as reshaping views so benchmarks can compare layouts.
* G3 branch-freedom: ``lockstep_walk`` -- the masked while-loop that executes
  divergent per-lane walks SIMD-style. This is the exact cost model of warp
  divergence made explicit: the loop runs until the *slowest* lane finishes
  and finished lanes burn masked (no-op) steps.
* G7 oversubscription: lanes are vector elements, so p >> cores is free; the
  trip count of ``lockstep_walk`` is the software analogue of the hardware
  scheduler's load-balancing window.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


def striding_indices(n: int, p: int) -> Array:
    """(steps, p) index matrix: lane i touches A[i + s*p] at step s.

    Consecutive lanes touch consecutive addresses within a step -- the
    coalesced layout on GPU, and the unit-stride vectorized layout on TPU.
    Requires p | n (pad first otherwise).
    """
    if n % p:
        raise ValueError(f"striding requires p|n, got n={n} p={p}")
    return jnp.arange(n, dtype=jnp.int32).reshape(n // p, p)


def partitioning_indices(n: int, p: int) -> Array:
    """(steps, p) index matrix: lane i touches A[i*(n/p) + s] at step s.

    The cache-friendly multicore layout; on GPU/TPU each step's lane
    addresses are n/p apart -> one memory transaction per lane.
    """
    if n % p:
        raise ValueError(f"partitioning requires p|n, got n={n} p={p}")
    return (
        jnp.arange(p, dtype=jnp.int32)[None, :] * (n // p)
        + jnp.arange(n // p, dtype=jnp.int32)[:, None]
    )


def strided_view(x: Array, p: int) -> Array:
    """Reshape (n,) -> (steps, p) so that row s holds step-s lane values."""
    return x.reshape(-1, p)


def partitioned_view(x: Array, p: int) -> Array:
    return x.reshape(p, -1).T


def lockstep_walk(
    state: Any,
    active_fn: Callable[[Any], Array],
    step_fn: Callable[[Any, Array], Any],
    max_steps: int | None = None,
) -> tuple[Any, Array, Array]:
    """Run per-lane walks in SIMD lockstep until every lane is done.

    Args:
        state: pytree of per-lane (and shared) arrays.
        active_fn: state -> (p,) bool mask of lanes still walking.
        step_fn: (state, active) -> state; must itself be branch-free and
            use `active` to mask updates (guideline G3).
        max_steps: optional hard bound (safety for adversarial inputs).

    Returns:
        (final_state, steps_taken, converged). steps_taken is the trip
        count = the maximum lane walk length, i.e. the divergence cost
        the paper's Table 3 measures via sub-list length distributions.
        converged is the fixpoint sentinel: True iff every lane
        finished, False iff ``max_steps`` cut lanes off mid-walk (the
        final state would be WRONG for those lanes -- host-driven
        callers raise ``ConvergenceError`` on it; always True when
        ``max_steps`` is None).
    """

    def cond(carry):
        state, steps = carry
        ok = jnp.any(active_fn(state))
        if max_steps is not None:
            ok = jnp.logical_and(ok, steps < max_steps)
        return ok

    def body(carry):
        state, steps = carry
        active = active_fn(state)
        return step_fn(state, active), steps + 1

    final, steps = jax.lax.while_loop(cond, body, (state, jnp.int32(0)))
    converged = jnp.logical_not(jnp.any(active_fn(final)))
    return final, steps, converged
