"""Frontier-compacted Shiloach-Vishkin connected components.

The dense engine (``components.sv_run``) walks all 2m edge orientations
every round, but an edge whose endpoints already share a label can never
hook again (labels of same-labeled nodes evolve identically under both
short-cuts and min-hooks), so after the first few rounds most of the 2m
walk is dead work -- the connected-components instance of the
frontier-centric operators Gunrock showed are THE key GPU graph-analytics
optimization. This engine compacts the edge list to the **active
frontier** (edges with ``D[a] != D[b]``) between rounds:

* the round body is ``components.sv_round_fns`` -- the SAME body the
  dense and sharded engines run, so hook semantics (min-CRCW
  resolution, Q stamps, the log_{3/2} n + 2 round bound) are
  bit-identical and, with ``sample_rounds=0``, labels AND round counts
  match ``sv_run`` exactly;
* compiled shapes stay static via **size-bucketed shrink levels**: each
  level runs a ``lax.while_loop`` at a fixed edge-buffer size and exits
  when the live count falls below half the buffer; the host then
  compacts into the next power-of-two bucket (padding with inert (0, 0)
  self-loops) and resumes the loop carry ``(D, Q, s)`` unchanged.

Optional **Afforest-style sampling pre-pass** (``sample_rounds=k > 0``),
after Sutton, Ben-Nun & Barak, "Optimizing Parallel Graph Connectivity
Computation via Subgraph Sampling" (IPDPS 2018): run k SV rounds that
hook each node through one sampled incident edge (one streaming scatter
pass builds all k samples), which resolves the giant component(s) at
O(n) cost per round; the first frontier compaction then drops every
edge internal to the largest component -- and to every other
already-resolved component -- before full SV runs on the residue. The
pre-pass changes which root represents each component (hooks happen in
a different order), so it is OFF by default; labels remain a correct
component partition and are canonicalization-equal to the dense
engine's.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.components import (
    HOOK_IMPLS,
    ConvergenceError,
    _maybe_dedup,
    check_choice,
    init_hooks,
    sv_compress,
    sv_round_bound,
    sv_round_fns,
)
from repro.core.operators import (  # noqa: F401  (re-exported: the
    bucket_size,  # filter primitives lived here before core/operators.py)
    compact_frontier,
    next_pow2,
    run_bucket_ladder,
)
from repro.obs import trace

Array = jax.Array


@dataclass
class FrontierStats:
    """Work accounting for the frontier engine (benchmarks/cc_frontier).

    ``edges_touched`` counts edge-slot visits the way the paper's
    Table 4 counts kernel work: each SV round walks its edge buffer
    TWICE (one SV2 pass, one SV3 pass), each compaction writes the new
    buffer once (the live mask is a by-product of the round's own
    D[a]/D[b] gathers), and the sampling pre-pass streams the full edge
    list once to build its (n, k) table. The dense engine's same-metric
    cost is ``2 * m2 * rounds``.
    """

    rounds: int  # total SV rounds (pre-pass included)
    edges_touched: int  # per-phase edge-slot visits (see docstring)
    m2: int  # oriented edge count after dedup (dense walks this per phase)
    levels: list = field(default_factory=list)  # (buffer_size, rounds) pairs
    sample_rounds: int = 0
    live_after_sample: int = 0  # frontier size after the pre-pass
    largest_component_frac: float = 0.0  # node share of the Afforest giant

    def publish(self, registry=None, prefix: str = "cc.frontier") -> None:
        """Publish into the metrics registry (``repro.obs.metrics``)."""
        from repro.obs.metrics import publish_stats

        publish_stats(self, prefix, registry)


@partial(
    jax.jit,
    static_argnames=("n", "bound", "shrink_at", "hook_impl", "record_hooks"),
)
def _run_level(a, b, D, Q, s, aux, *, n, bound, shrink_at, hook_impl,
               record_hooks=False):
    """Run SV rounds at one fixed buffer size until convergence, the
    round bound, or (when ``shrink_at`` is set) the frontier mask drops
    to half the buffer -- whichever comes first. The mask is the round
    body's own SV3 compare (``with_frontier=True``), so watching it
    costs no extra edge passes; it is a superset of the truly-live
    edges, which only delays a shrink, never breaks one. ``aux`` (the
    hook-recording state when ``record_hooks``) is node-indexed, so it
    threads through level changes untouched by compaction."""
    body = sv_round_fns(a, b, n, hook_impl=hook_impl, with_frontier=True,
                        record_hooks=record_hooks)
    m = a.shape[0]

    def wrapped(carry):
        D, Q, aux, s, changed, fmask, rounds = carry
        D, Q, aux, s, changed, fmask = body(
            (D, Q, aux, s, changed, fmask)
        )
        return D, Q, aux, s, changed, fmask, rounds + 1

    def cond(carry):
        _D, _Q, _aux, s, changed, fmask, _rounds = carry
        keep = jnp.logical_and(changed, s <= bound)
        if shrink_at is not None:
            live = jnp.sum(fmask.astype(jnp.int32))  # elementwise only
            keep = jnp.logical_and(keep, live > shrink_at)
        return keep

    init = (
        D, Q, aux, s, jnp.bool_(True), jnp.ones((m,), jnp.bool_),
        jnp.int32(0),
    )
    D, Q, aux, s, changed, fmask, rounds = jax.lax.while_loop(
        cond, wrapped, init
    )
    return D, Q, aux, s, changed, fmask, rounds


@partial(jax.jit, static_argnames=("n", "k"))
def _build_samples(a, b, perm, *, n, k):
    """ONE streaming scatter pass over the 2m edges fills an (n, k)
    sampled-neighbor table (last write wins over a seeded permutation)."""
    m = a.shape[0]
    slot = jnp.arange(m, dtype=jnp.int32) % k
    tbl = jnp.full((n, k), -1, jnp.int32)
    return tbl.at[a[perm], slot].set(b[perm])


@partial(jax.jit, static_argnames=("n", "record_hooks"))
def _sample_round(neigh, D, Q, s, aux, *, n, record_hooks=False):
    """One SV round hooking every node through one sampled neighbor;
    nodes without a sample become inert self-loops. Sampled arcs are
    real graph edges, so hook recording stays valid in the pre-pass."""
    sa = jnp.arange(n, dtype=jnp.int32)
    sb = jnp.where(neigh >= 0, neigh, sa)
    body = sv_round_fns(sa, sb, n, record_hooks=record_hooks)
    D, Q, aux, s, changed = body((D, Q, aux, s, jnp.bool_(True)))
    return D, Q, aux, s, changed


@partial(jax.jit, static_argnames=("n",))
def _largest_component_frac(D, *, n):
    counts = jnp.zeros((n,), jnp.int32).at[D].add(1)
    return jnp.max(counts).astype(jnp.float32) / n


def frontier_shiloach_vishkin(
    src: Array,
    dst: Array,
    num_nodes: int,
    *,
    max_rounds: int | None = None,
    dedup: bool = True,
    sample_rounds: int = 0,
    min_bucket: int = 1024,
    hook_impl: str = "xla",
    seed: int = 0,
    record_hooks: bool = False,
    with_stats: bool = False,
):
    """Connected components over a shrinking active-edge frontier.

    Bit-exact vs ``shiloach_vishkin`` (labels AND rounds) when
    ``sample_rounds=0``; with a sampling pre-pass the labels are a
    correct partition with possibly different representatives. Returns
    (labels, rounds), or (labels, rounds, FrontierStats) when
    ``with_stats`` -- ``stats.edges_touched`` counts every edge slot
    walked by a round plus one buffer pass per compaction/sampling,
    the number the dense engine pays ``2m * rounds`` for.

    ``record_hooks=True`` inserts the spanning-forest hook record
    ``(hook_u, hook_v)`` after rounds in the return tuple (labels AND
    round counts stay bit-identical -- recording only reads the round
    state). Compaction cannot drop a future winner: a winning edge has
    differently-labeled endpoints at hook time, label equality is
    permanent, and the frontier mask keeps every unequal-label edge.
    """
    n = num_nodes
    check_choice("hook_impl", hook_impl, HOOK_IMPLS)
    src, dst = _maybe_dedup(src, dst, dedup)
    src = jnp.asarray(src, jnp.int32).ravel()
    dst = jnp.asarray(dst, jnp.int32).ravel()
    a = jnp.concatenate([src, dst])
    b = jnp.concatenate([dst, src])
    m2 = int(a.shape[0])

    bound = (max_rounds if max_rounds is not None else sv_round_bound(n))
    bound += sample_rounds
    D = jnp.arange(n, dtype=jnp.int32)
    Q = jnp.zeros(n, jnp.int32)
    s = jnp.int32(1)
    aux = (init_hooks(n), jnp.int32(0)) if record_hooks else jnp.int32(0)
    stats = FrontierStats(rounds=0, edges_touched=0, m2=m2,
                          sample_rounds=sample_rounds)

    if sample_rounds > 0 and m2 > 0:
        sample_sp = trace.span("cc.frontier.sample", k=sample_rounds)
        sample_sp.__enter__()
        rng = np.random.default_rng(seed)
        perm = jnp.asarray(rng.permutation(m2).astype(np.int32))
        samples = _build_samples(a, b, perm, n=n, k=sample_rounds)
        stats.edges_touched += m2  # the sampling pass streams all edges once
        for t in range(sample_rounds):
            D, Q, aux, s, _changed = _sample_round(
                samples[:, t], D, Q, s, aux, n=n, record_hooks=record_hooks
            )
            stats.edges_touched += 2 * n  # SV2 + SV3 over the n sampled edges
        if with_stats:  # O(n) scatter + host sync: only when asked for
            # repro-lint: disable=host-sync  (opt-in stats readback)
            stats.largest_component_frac = float(
                _largest_component_frac(D, n=n)
            )
        # Compact straight away: drops ALL edges internal to the giant
        # (and to every other component the pre-pass already resolved).
        live_mask = D[a] != D[b]
        # The level-synchronous sync (paper sec. 4): the host must see the
        # live count to pick the next power-of-two bucket.
        live = int(jnp.sum(live_mask.astype(jnp.int32)))  # repro-lint: disable=host-sync
        stats.live_after_sample = live
        stats.edges_touched += m2  # full-list live scan (pre-pass rounds
        # walked only the sampled edges, so this mask needs its own pass)
        size = bucket_size(live, min_bucket=min_bucket, cap=m2)
        a, b = compact_frontier(a, b, live_mask, size=size)
        m2_level = size
        sample_sp.tag(live=live).__exit__(None, None, None)
    else:
        m2_level = m2

    fmask = None
    # Spans attach at the per-LEVEL syncs the shrink ladder already pays
    # (the int()/bool() reads below); tags reuse those reads, so tracing
    # adds zero device round-trips (docs/observability.md). The ladder
    # itself is operators.run_bucket_ladder -- the engine only supplies
    # the level/compaction closures, so counters and sync sites are
    # unchanged by construction.
    with trace.span("cc.frontier", n=n, m2=m2) as run_sp:

        def sv_level(bucket, shrink_at):
            nonlocal D, Q, aux, s, fmask
            with trace.span("cc.frontier.level", bucket=bucket) as sp:
                D, Q, aux, s, changed, fmask, rounds = _run_level(
                    a, b, D, Q, s, aux,
                    n=n, bound=bound, shrink_at=shrink_at,
                    hook_impl=hook_impl, record_hooks=record_hooks,
                )
                # SV2 + SV3 passes; the Pallas hook kernel doesn't export
                # its compare mask, so that path pays a third (mask) pass
                # per round.
                passes = 2 if hook_impl == "xla" else 3
                # Per-level host syncs, not per-round: _run_level keeps
                # the inner SV iteration on device (lax.while_loop) and
                # the host reads one round count / convergence flag /
                # live count per LEVEL to drive the shrink ladder -- the
                # paper's level-synchronous design.
                level_rounds = int(rounds)  # repro-lint: disable=host-sync
                stats.edges_touched += passes * level_rounds * bucket
                stats.levels.append((bucket, level_rounds))
                converged = not bool(changed)  # repro-lint: disable=host-sync
                sp.tag(rounds=level_rounds, converged=converged)
            over = not converged and int(s) > bound  # repro-lint: disable=host-sync
            return converged, over

        def live_edges():
            # Shrink: the masked frontier fits the next power-of-two
            # bucket.
            return int(jnp.sum(fmask.astype(jnp.int32)))  # repro-lint: disable=host-sync

        def charge_shrink(new_size):
            # The mask came out of this level's last SV3 pass; only the
            # gather-write of the surviving edges into the new buffer is
            # extra work.
            stats.edges_touched += new_size

        def shrink(new_size):
            nonlocal a, b
            a, b = compact_frontier(a, b, fmask, size=new_size)

        def bound_hit():
            # The level loop ran out of round budget with hooks still
            # flowing: labels would be wrong, so fail loudly (the
            # convergence sentinel; see core.components.ConvergenceError).
            raise ConvergenceError(
                f"frontier_shiloach_vishkin hit its round bound ({bound}"
                f"{f', incl. {sample_rounds} sampling rounds' if sample_rounds else ''})"
                f" before the label fixpoint on {n} nodes; raise max_rounds"
            )

        run_bucket_ladder(
            bucket=m2_level, min_bucket=min_bucket, run_level=sv_level,
            live_count=live_edges, compact=shrink, on_shrink=charge_shrink,
            on_nonconverged=bound_hit,
        )
        D = sv_compress(D, n)
        # Terminal readback: the loop above already synced on s per level.
        rounds_total = int(s) - 1  # repro-lint: disable=host-sync
        run_sp.tag(rounds=rounds_total, levels=len(stats.levels))
    stats.rounds = rounds_total
    out = (D, jnp.int32(rounds_total))
    if record_hooks:
        hooks, _inner = aux
        out = out + (hooks,)
    if with_stats:
        out = out + (stats,)
    return out
