"""Shortest paths on the frontier machinery: frontier Bellman-Ford.

The first workload beyond connected components to ride the compacted
edge-frontier + ``next_pow2`` size-bucket loop of ``core/frontier.py``.
Gunrock's observation (PAPERS.md) is that the advance/filter frontier
loop expresses BFS, SSSP, and CC with only the per-edge functor
swapped; here the CC engine's hook-min-scatter becomes a
**relax-min-scatter** -- ``dist.at[:, b].min(dist[:, a] + w)`` -- which
is min-CRCW and therefore deterministic (RL002-clean) by construction.
BFS falls out as the unit-weight case (``weights=None``).

Two engines share the relax round:

* ``bellman_ford`` -- the dense walk: every oriented edge relaxes every
  round inside one ``lax.while_loop``; fully traceable, one compile per
  shape, the serve path's engine (``kind="sssp"`` waves).
* ``frontier_bellman_ford`` -- level-synchronous frontier relaxation:
  each level gathers only the edges OUT of nodes whose distance changed
  last round into a ``next_pow2``-bucketed buffer (padding with inert
  (0, 0) zero-weight self-loops) and relaxes just those. Unlike CC --
  where label equality is permanent, so the buffer shrinks
  monotonically -- a relaxed-quiet edge can wake up again when its
  source's distance later drops, so each level re-compacts **from the
  full edge list** (one O(m) boolean mask gather per level, against the
  S x bucket relax work it saves). The host sync per level is the same
  level-synchronous design as the CC frontier engine, with
  ``sssp.level`` spans attached at those already-paid sync points.

**Exactness.** Distances are the unique least fixpoint of the float32
Bellman relaxations ``dist[v] = min(dist[v], dist[u] + w)`` (float add
is monotonic and each candidate is a single add -- no accumulation-
order ambiguity), so dense, frontier, batched, and the serial oracles
(``core/serial.serial_dijkstra`` / ``serial_bellman_ford``) all produce
bit-identical distances. Skipping quiet edges never changes a round's
outcome (their contribution was already min'd in), so the frontier
engine's per-round distance evolution equals the dense engine's.
Parents are recovered by one deterministic post-pass: ``parent[v]`` is
the **minimum** u over non-self-loop edges with ``dist[u] + w ==
dist[v]`` (min-CRCW again), ``parent[source] = source``, unreachable
nodes get ``-1`` with ``dist = +inf``.

**Batched multi-source** shares one padded compile: sources are extra
rows of the ``(S, n)`` distance matrix, relaxed by the same scatter
(the Johnson all-pairs trick -- n independent sources as one batch).
Rows are independent, so batched results are bit-exact vs per-source
solo runs; the disjoint-union serve packing (``repro.serve.graph``)
builds on exactly this.

Negative weights are rejected up front: edges are walked in both
orientations (the repo-wide undirected convention), so any negative
edge is a negative cycle.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.components import ConvergenceError, check_choice
from repro.core.operators import (
    MIN,
    advance,
    bucket_size,
    compact_weighted,
    run_rebuild_loop,
)
from repro.obs import trace

Array = jax.Array

# shortest_paths(engine=) choices (RL004: registered as "sssp_engine"
# in tools/lint/passes/choice_set.py; docs/engines.md choice-matrix).
# "sharded_frontier" is absent on purpose: the relax scatter has no
# sharded counterpart yet (ROADMAP).
SSSP_ENGINES = ("auto", "frontier", "dense")

UNREACHABLE = -1  # parent sentinel for dist == +inf nodes


def sssp_round_bound(n: int) -> int:
    """Relax-round ceiling: a shortest path uses at most n - 1 edges,
    so n rounds always suffice (n - 1 improving + 1 confirming)."""
    return max(int(n), 1)


@dataclass
class SsspStats:
    """Work accounting for the SSSP engines (benchmarks/sssp_frontier).

    ``relax_visits`` counts edge-slot relax visits the way
    ``FrontierStats.edges_touched`` counts hook work: one per buffer
    slot per relax round (row-batched: the S source rows share each
    slot's gather/scatter lanes). The dense engine's same-metric cost
    is ``m2 * rounds``. ``mask_visits`` is the frontier engine's extra
    cost: one full-edge-list boolean gather per level to rebuild the
    frontier mask (quiet edges can wake up again -- see module
    docstring -- so compaction cannot be permanent like CC's).
    """

    rounds: int
    relax_visits: int  # compacted relax slots walked (see docstring)
    mask_visits: int  # full-list frontier-mask gathers, m2 per level
    m2: int  # oriented edge count (dense relaxes this per round)
    num_sources: int
    levels: list = field(default_factory=list)  # (bucket, live) per level

    def publish(self, registry=None, prefix: str = "sssp.frontier") -> None:
        """Publish into the metrics registry (``repro.obs.metrics``)."""
        from repro.obs.metrics import publish_stats

        publish_stats(self, prefix, registry)


def _prep_edges(src, dst, weights):
    """Both-orientation edge arrays (a, b, w2): the repo's undirected
    2m walk. ``weights=None`` means unit weights (BFS). Host-side
    inputs are validated (NaN / negative weights rejected; +inf is a
    legal "non-edge", the serve path's pad convention)."""
    if weights is not None and isinstance(
        weights, (np.ndarray, list, tuple)
    ):
        wh = np.asarray(weights, np.float32).ravel()
        if np.isnan(wh).any():
            raise ValueError("weights contain NaN")
        if (wh < 0).any():
            raise ValueError(
                "negative weights are unsupported: edges relax in both "
                "orientations (undirected), so a negative edge is a "
                "negative cycle"
            )
    src = jnp.asarray(src, jnp.int32).ravel()
    dst = jnp.asarray(dst, jnp.int32).ravel()
    if weights is None:
        w = jnp.ones(src.shape, jnp.float32)
    else:
        w = jnp.asarray(weights, jnp.float32).ravel()
    if w.shape != src.shape:
        raise ValueError(
            f"weights length {w.shape[0]} != edge count {src.shape[0]}"
        )
    a = jnp.concatenate([src, dst])
    b = jnp.concatenate([dst, src])
    w2 = jnp.concatenate([w, w])
    return a, b, w2


def _prep_sources(sources, n: int):
    """Normalized (sources int32 array, scalar?) pair. Scalar callers
    get (n,)-shaped results back; array callers the (S, n) batch."""
    scalar = np.ndim(sources) == 0
    srcs = np.atleast_1d(np.asarray(sources, np.int32))
    if srcs.size < 1:
        raise ValueError("need at least one source")
    if srcs.min() < 0 or srcs.max() >= n:
        raise ValueError(
            f"sources outside [0, {n}): {srcs[(srcs < 0) | (srcs >= n)]}"
        )
    return srcs, scalar


@partial(jax.jit, static_argnames=("n",))
def _init_dist(srcs, *, n):
    S = srcs.shape[0]
    dist = jnp.full((S, n), jnp.inf, jnp.float32)
    return dist.at[jnp.arange(S), srcs].set(0.0)


@partial(jax.jit, static_argnames=("bound",))
def _bf_dense(a, b, w, dist0, *, bound):
    """All-edges-every-round Bellman-Ford in one ``lax.while_loop``.
    Returns (dist, rounds, converged); ``converged`` is the fixpoint
    sentinel host callers turn into ``ConvergenceError``."""

    def cond(carry):
        _dist, s, changed = carry
        return jnp.logical_and(changed, s <= bound)

    def body(carry):
        dist, s, _changed = carry
        new = advance(dist, b, dist[:, a] + w, monoid=MIN)
        return new, s + 1, jnp.any(new < dist)

    dist, s, changed = jax.lax.while_loop(
        cond, body, (dist0, jnp.int32(1), jnp.bool_(True))
    )
    return dist, s - 1, jnp.logical_not(changed)


@jax.jit
def _min_parents(a, b, w, dist, srcs):
    """Deterministic parent recovery (one full-edge pass, after the
    distance fixpoint): ``parent[v] = min{u : dist[u] + w(u,v) ==
    dist[v], u != v}`` via min-CRCW scatter; sources point at
    themselves, unreachable nodes at ``UNREACHABLE``. At the fixpoint
    every reachable non-source node has at least one optimal incoming
    edge (float add is monotonic), so the min is never vacuous."""
    S, n = dist.shape
    opt = (dist[:, a] + w == dist[:, b]) & (a != b)[None, :]
    cand = jnp.where(opt, a[None, :], n)
    parent = advance(
        jnp.full((S, n), n, jnp.int32), b, cand, monoid=MIN
    )
    parent = jnp.where(parent < n, parent, UNREACHABLE)
    parent = jnp.where(jnp.isinf(dist), UNREACHABLE, parent)
    return parent.at[jnp.arange(S), srcs].set(srcs)


@jax.jit
def _edge_frontier(a, changed_nodes):
    """Edge slots whose (oriented) source node improved last round --
    the union over source rows, so one mask serves the whole batch."""
    return changed_nodes[a]


# The weighted compaction primitive moved to core/operators.py with the
# rest of the filter machinery; the alias keeps the engine-local name.
_compact_weighted = compact_weighted


@jax.jit
def _relax_level(ca, cb, cw, dist):
    """One relax round over a compacted edge buffer (a MIN-monoid
    advance of the per-edge candidates). Returns the new distance
    matrix and the (n,) any-row node-improved mask that seeds the next
    level's frontier."""
    new = advance(dist, cb, dist[:, ca] + cw, monoid=MIN)
    return new, jnp.any(new < dist, axis=0)


def bellman_ford(
    src: Array,
    dst: Array,
    weights: Array | None,
    num_nodes: int,
    *,
    sources=0,
    max_rounds: int | None = None,
    with_stats: bool = False,
):
    """Dense Bellman-Ford: relax all 2m oriented edges per round until
    the distance fixpoint. Returns ``(dist, parent, rounds)`` --
    ``dist`` float32 with ``+inf`` for unreachable nodes, ``parent``
    int32 per ``_min_parents`` -- shaped ``(n,)`` for a scalar source,
    ``(S, n)`` for an array of sources (one batched compile; rows are
    bit-exact vs solo runs). ``with_stats`` appends ``SsspStats``.

    Hitting ``max_rounds`` before the fixpoint raises
    ``ConvergenceError`` (host calls; a jit trace keeps the documented
    return-at-bound -- a device value cannot raise). The default bound
    ``sssp_round_bound(n)`` always suffices.
    """
    from repro.compat import is_tracer

    n = num_nodes
    a, b, w2 = _prep_edges(src, dst, weights)
    m2 = int(a.shape[0])
    srcs, scalar = _prep_sources(sources, n)
    bound = max_rounds if max_rounds is not None else sssp_round_bound(n)
    # Whole-run device span; blocks at close on the same terminal sync
    # the convergence-sentinel read below already pays.
    with trace.span(
        "sssp.dense", device=True, n=n, m2=m2, sources=int(srcs.shape[0]),
        bound=bound,
    ) as sp:
        dist0 = _init_dist(jnp.asarray(srcs), n=n)
        dist, rounds, converged = _bf_dense(a, b, w2, dist0, bound=bound)
        parent = _min_parents(a, b, w2, dist, jnp.asarray(srcs))
        if not is_tracer(converged):
            sp.block_on(dist)
    if not is_tracer(converged):
        # Intentional terminal sync: the sentinel must be read before
        # wrong distances can escape (core.components.ConvergenceError).
        if not bool(converged):  # repro-lint: disable=host-sync
            raise ConvergenceError(
                f"bellman_ford hit max_rounds={bound} before the "
                f"distance fixpoint on {n} nodes; raise max_rounds (the "
                f"safe bound is sssp_round_bound(n)={sssp_round_bound(n)})"
            )
    if scalar:
        dist, parent = dist[0], parent[0]
    if with_stats:
        # Terminal readback only when stats are asked for.
        r = int(rounds)  # repro-lint: disable=host-sync
        stats = SsspStats(
            rounds=r, relax_visits=m2 * r, mask_visits=0, m2=m2,
            num_sources=int(srcs.shape[0]),
        )
        return (dist, parent, rounds, stats)
    return (dist, parent, rounds)


def frontier_bellman_ford(
    src: Array,
    dst: Array,
    weights: Array | None,
    num_nodes: int,
    *,
    sources=0,
    max_rounds: int | None = None,
    min_bucket: int = 1024,
    with_stats: bool = False,
):
    """Level-synchronous frontier Bellman-Ford: each level relaxes only
    the edges out of nodes whose distance improved last round, gathered
    into a ``next_pow2`` size bucket (shape-static compiles, the CC
    frontier engine's ladder). Distances and parents are bit-exact vs
    ``bellman_ford`` (see module docstring); return convention and the
    ``ConvergenceError`` sentinel match it too. The level loop is
    host-driven (one live-count sync per level -- the paper's
    level-synchronous design), so it cannot run inside ``jax.jit``.
    """
    n = num_nodes
    a, b, w2 = _prep_edges(src, dst, weights)
    m2 = int(a.shape[0])
    srcs, scalar = _prep_sources(sources, n)
    S = int(srcs.shape[0])
    bound = max_rounds if max_rounds is not None else sssp_round_bound(n)
    dist = _init_dist(jnp.asarray(srcs), n=n)
    # Level 0 frontier: the source rows' one-hot improvement mask.
    changed_nodes = (
        jnp.zeros((n,), bool).at[jnp.asarray(srcs)].set(True)
    )
    stats = SsspStats(
        rounds=0, relax_visits=0, mask_visits=0, m2=m2, num_sources=S
    )
    fmask = None
    # Spans attach at the per-level syncs the bucket ladder already
    # pays (the int() live-count reads), so tracing adds zero extra
    # device round-trips -- same policy as cc.frontier. The loop shape
    # is operators.run_rebuild_loop: unlike CC's permanent compaction,
    # every level re-masks the FULL edge list (a settled edge wakes up
    # when its source's distance later drops -- module docstring).
    with trace.span("sssp.frontier", n=n, m2=m2, sources=S) as run_sp:

        def live_edges():
            nonlocal fmask
            if m2 == 0:
                return 0
            fmask = _edge_frontier(a, changed_nodes)
            stats.mask_visits += m2
            # The level-synchronous sync: the host reads the live count
            # to pick the next power-of-two bucket.
            return int(jnp.sum(fmask.astype(jnp.int32)))  # repro-lint: disable=host-sync

        def relax(live):
            nonlocal dist, changed_nodes
            size = bucket_size(live, min_bucket=min_bucket, cap=m2)
            with trace.span("sssp.level", bucket=size, live=live):
                ca, cb, cw = compact_weighted(a, b, w2, fmask, size=size)
                dist, changed_nodes = _relax_level(ca, cb, cw, dist)
            stats.relax_visits += size
            stats.levels.append((size, live))

        def bound_hit(live, _rounds):
            # Frontier still live at the round bound: distances would
            # be wrong, so fail loudly (the convergence sentinel; see
            # core.components.ConvergenceError).
            raise ConvergenceError(
                f"frontier_bellman_ford hit its round bound "
                f"({bound}) with {live} frontier edges still live "
                f"on {n} nodes; raise max_rounds (the safe bound "
                f"is sssp_round_bound(n)={sssp_round_bound(n)})"
            )

        rounds = run_rebuild_loop(
            bound=bound, live_count=live_edges, run_level=relax,
            on_bound=bound_hit,
        )
        run_sp.tag(rounds=rounds, levels=len(stats.levels))
    stats.rounds = rounds
    parent = _min_parents(a, b, w2, dist, jnp.asarray(srcs))
    if scalar:
        dist, parent = dist[0], parent[0]
    out = (dist, parent, jnp.int32(rounds))
    if with_stats:
        out = out + (stats,)
    return out


def shortest_paths(
    src,
    dst,
    weights=None,
    num_nodes: int | None = None,
    *,
    sources=0,
    max_rounds: int | None = None,
    engine: str = "auto",
    **kwargs,
):
    """Single/multi-source shortest paths with engine dispatch -- the
    ``connected_components`` convention for the SSSP workload. Returns
    ``(dist, parent, rounds)``: float32 distances (``+inf`` =
    unreachable), deterministic min-id parent tree (``parent[source] =
    source``, unreachable ``-1``), and the relax-round count. A scalar
    ``sources`` gives ``(n,)`` arrays, an array ``(S, n)`` -- all S
    sources share one padded compile and are bit-exact vs solo runs.
    ``weights=None`` means unit weights: BFS.

    ``engine=`` -- ``"auto"`` (default), ``"frontier"``, ``"dense"``
    (full matrix: ``docs/engines.md``, knob ``sssp_engine``):

    * ``"auto"``: the frontier engine, except under a ``jax.jit``
      trace, where the host-driven level loop is impossible and the
      fully-traceable dense walk runs instead.
    * ``"frontier"``: pin the level-synchronous frontier engine
      (``min_bucket=`` sizes its smallest bucket; rejects tracing).
    * ``"dense"``: the all-edges-every-round walk (the serve path's
      engine -- one compile per shape bucket).

    Both engines raise ``ConvergenceError`` when ``max_rounds`` cuts
    the relax loop before the distance fixpoint (host calls), and both
    support ``with_stats=True`` (``SsspStats`` relax/mask visit
    counters).
    """
    from repro.compat import is_tracer

    if num_nodes is None:
        raise TypeError("shortest_paths requires num_nodes")
    check_choice("sssp_engine", engine, SSSP_ENGINES)
    tracing = is_tracer(src) or is_tracer(dst) or is_tracer(weights)
    if engine == "auto":
        engine = "dense" if tracing else "frontier"
    if engine == "frontier":
        if tracing:
            raise ValueError(
                "the frontier SSSP engine's level loop is host-driven "
                "and cannot run inside jit; call it outside jit or use "
                "engine='dense'"
            )
        return frontier_bellman_ford(
            src, dst, weights, num_nodes, sources=sources,
            max_rounds=max_rounds, **kwargs,
        )
    if "min_bucket" in kwargs:
        raise ValueError(
            "min_bucket= is a frontier-engine option; use "
            "engine='frontier' (or 'auto')"
        )
    return bellman_ford(
        src, dst, weights, num_nodes, sources=sources,
        max_rounds=max_rounds, **kwargs,
    )
