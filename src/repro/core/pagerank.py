"""PageRank as an advance/filter/compute composition (~50 lines).

The proof-of-unlock for ``core/operators.py``: where CC and SSSP ride
the MIN monoid, PageRank is the repo's first ADD-monoid workload --
push-style mass propagation, ``r' = (1-d) * t + d * sum_{(u,v)} w(u,v)
* r[u] / deg(u)`` over the undirected 2m arc walk -- and the whole
algorithm is one ``advance`` (scatter-add of out-mass), one ``compute``
(per-node out-mass split), and the shared ``run_rebuild_loop`` driver.
An ADD frontier cannot skip edges (every contribution is part of the
sum -- see docs/operators.md), so the filter here gates *termination*
only: the tolerance mask ``|r' - r| > tol`` is the live set.

**Exactness.** Everything is float32, and every multiply is rounded
separately before the scatter-add folds contributions in edge-slot
order (the teleport term is the scatter's *base*, not a post-add --
that keeps XLA from contracting a multiply-add into an FMA, which
would unpin the serial oracle). ``core.serial.serial_pagerank``
mirrors the exact op sequence with ``np.add.at``, whose accumulation
order matches the XLA scatter-add on the CPU/TPU backends, so engine
scores are bit-identical to the oracle, iteration for iteration.
Per-node ``teleport`` vectors make the serve path's disjoint-union
packing decompose: a request's slice of the packed union sees exactly
its solo teleport mass, pad nodes carry zero and stay zero. Dangling
mass (weighted degree 0) leaks by design -- redistribution would
couple packed requests through a global sum.

Two engines share the iteration body (bit-identical trajectories):

* ``frontier`` -- the host tolerance loop on ``run_rebuild_loop``:
  iterate until no node moves more than ``tol``, ``ConvergenceError``
  at the iteration bound (``pagerank_iter_bound``).
* ``dense`` -- fixed ``num_iters`` iterations in one traceable
  ``lax.fori_loop``: one compile per shape, no per-iteration host
  sync, and -- because the iteration count is data-independent --
  batched disjoint unions stay bit-exact vs solo runs. This is the
  serve path's engine (``kind="pagerank"`` waves): damping and
  iteration count are wave-uniform engine knobs there, never
  per-request, precisely so packing cannot change any member's bits.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.components import ConvergenceError, check_choice
from repro.core.operators import ADD, advance, compute, run_rebuild_loop
from repro.obs import trace

Array = jax.Array

# pagerank(engine=) choices (RL004: registered as "pagerank_engine" in
# tools/lint/passes/choice_set.py; docs/engines.md choice-matrix).
PAGERANK_ENGINES = ("auto", "frontier", "dense")

DEFAULT_DAMPING = 0.85
DEFAULT_TOL = 1e-6


def pagerank_iter_bound(
    damping: float = DEFAULT_DAMPING, tol: float = DEFAULT_TOL
) -> int:
    """Iteration ceiling for the tolerance loop: per-node scores are
    bounded by the total mass (<= 1) and the update contracts by
    ``damping`` per iteration, so the residual undercuts ``tol`` within
    ``log(tol * (1 - damping)) / log(damping)`` iterations. Also the
    dense engine's default ``num_iters``."""
    if not 0.0 < damping < 1.0:
        raise ValueError(f"damping must be in (0, 1), got {damping}")
    if not tol > 0.0:
        raise ValueError(f"tol must be > 0, got {tol}")
    return max(
        int(math.ceil(math.log(tol * (1.0 - damping)) / math.log(damping)))
        + 1,
        1,
    )


@dataclass
class PageRankStats:
    """Work accounting (benchmarks/pagerank). ``edges_touched`` counts
    edge-slot visits like ``SsspStats.relax_visits``: the degree pass
    walks the 2m arcs once, then every iteration gathers + scatters all
    of them (an ADD frontier never compacts -- module docstring), so
    the total is ``m2 * (iterations + 1)`` on both engines."""

    iterations: int
    edges_touched: int
    m2: int  # oriented arc count (every iteration walks all of it)
    levels: list = field(default_factory=list)  # live (>tol) nodes per iter

    def publish(self, registry=None, prefix: str = "pagerank.frontier") -> None:
        """Publish into the metrics registry (``repro.obs.metrics``)."""
        from repro.obs.metrics import publish_stats

        publish_stats(self, prefix, registry)


def _prep_mass_edges(src, dst, weights):
    """Both-orientation (a, b, w2) arc arrays. Unlike SSSP's prep,
    +inf is rejected too: mass MULTIPLIES along edges, so a non-finite
    weight poisons every score it can reach (0 * inf = NaN)."""
    src = jnp.asarray(src, jnp.int32).ravel()
    dst = jnp.asarray(dst, jnp.int32).ravel()
    if weights is None:
        w = jnp.ones(src.shape, jnp.float32)
    else:
        wh = np.asarray(weights, np.float32).ravel()
        if not np.isfinite(wh).all():
            raise ValueError("pagerank weights must be finite")
        if (wh < 0).any():
            raise ValueError("pagerank weights must be >= 0")
        w = jnp.asarray(wh)
    if w.shape != src.shape:
        raise ValueError(
            f"weights length {w.shape[0]} != edge count {src.shape[0]}"
        )
    return (
        jnp.concatenate([src, dst]),
        jnp.concatenate([dst, src]),
        jnp.concatenate([w, w]),
    )


@jax.jit
def _degrees(a, w2, t):
    """Weighted out-degree per node (ADD-monoid advance of the weight
    lane; ``t`` only supplies the (n,) float32 shape)."""
    return advance(jnp.zeros_like(t), a, w2, monoid=ADD)


def _mass_step(a, b, w2, deg, t, r, dmp, omd):
    """One push iteration: compute per-node out-mass, advance it along
    every arc under ADD *onto the teleport base* ``(1-d) * t`` -- the
    base-not-post-add form that keeps every multiply separately rounded
    (no FMA contraction), which is what pins the NumPy oracle."""
    out = compute(
        lambda ri, di: jnp.where(di > 0, ri / di, 0.0), r, deg
    )
    return advance(omd * t, b, dmp * (out[a] * w2), monoid=ADD)


@jax.jit
def _pr_iterate(a, b, w2, deg, t, r, dmp, omd, tol):
    """One host-loop iteration: new scores + the tolerance filter mask
    (the ADD frontier's live set -- gates termination, not the walk)."""
    new = _mass_step(a, b, w2, deg, t, r, dmp, omd)
    return new, jnp.abs(new - r) > tol


@partial(jax.jit, static_argnames=("num_iters",))
def _pr_fixed(a, b, w2, deg, t, r0, dmp, omd, *, num_iters):
    """``num_iters`` iterations in one fori_loop: the traceable dense
    engine, bit-identical to the host loop's first ``num_iters`` steps."""
    return jax.lax.fori_loop(
        0,
        num_iters,
        lambda _, r: _mass_step(a, b, w2, deg, t, r, dmp, omd),
        r0,
    )


def _prep_teleport(teleport, n: int):
    if teleport is None:
        return jnp.full((n,), 1.0 / n, jnp.float32)
    th = np.asarray(teleport, np.float32).ravel()
    if th.shape != (n,):
        raise ValueError(f"teleport shape {th.shape} != ({n},)")
    if not np.isfinite(th).all() or (th < 0).any():
        raise ValueError("teleport mass must be finite and >= 0")
    return jnp.asarray(th)


def pagerank(
    src: Array,
    dst: Array,
    weights: Array | None = None,
    num_nodes: int | None = None,
    *,
    damping: float = DEFAULT_DAMPING,
    tol: float = DEFAULT_TOL,
    teleport: Array | None = None,
    num_iters: int | None = None,
    max_rounds: int | None = None,
    engine: str = "auto",
    with_stats: bool = False,
):
    """Weighted PageRank over the undirected 2m arc walk. Returns
    ``(scores, iterations)`` -- float32 scores, int32 iteration count
    -- plus ``PageRankStats`` when ``with_stats``. ``weights=None``
    means unit weights; ``teleport`` (default uniform ``1/n``) is the
    per-node restart mass. Dangling mass leaks (module docstring).

    ``engine=`` -- ``"auto"`` (default), ``"frontier"``, ``"dense"``
    (full matrix: ``docs/engines.md``, knob ``pagerank_engine``):

    * ``"auto"``: the frontier tolerance loop, except under a
      ``jax.jit`` trace, where the host-driven loop is impossible and
      the fully-traceable fixed-iteration dense engine runs instead.
    * ``"frontier"``: iterate until every node moves <= ``tol``;
      ``max_rounds`` (default ``pagerank_iter_bound(damping, tol)``)
      is the ``ConvergenceError`` bound. Rejects ``num_iters``.
    * ``"dense"``: exactly ``num_iters`` iterations (default
      ``pagerank_iter_bound(damping, tol)``), one compile per shape,
      no per-iteration sync -- the serve path's engine. ``max_rounds``
      below ``num_iters`` caps the iterations and then *checks*: a
      still-moving score vector raises ``ConvergenceError`` (the serve
      chaos harness's real nonconvergence sentinel; under a trace the
      check is skipped -- a device value cannot raise).
    """
    if num_nodes is None:
        raise TypeError("pagerank requires num_nodes")
    from repro.compat import is_tracer

    n = int(num_nodes)
    check_choice("pagerank_engine", engine, PAGERANK_ENGINES)
    bound = (
        max_rounds if max_rounds is not None
        else pagerank_iter_bound(damping, tol)
    )
    dmp = np.float32(damping)
    omd = np.float32(1.0) - dmp  # oracle computes 1 - d the same way
    tolv = np.float32(tol)
    a, b, w2 = _prep_mass_edges(src, dst, weights)
    m2 = int(a.shape[0])
    t = _prep_teleport(teleport, n)
    tracing = is_tracer(src) or is_tracer(dst) or is_tracer(weights)
    if engine == "auto":
        engine = "dense" if tracing else "frontier"
    deg = _degrees(a, w2, t)
    r = t  # iteration 0 state: all mass at its teleport slot
    stats = PageRankStats(iterations=0, edges_touched=m2, m2=m2)

    if engine == "dense":
        iters = (
            num_iters if num_iters is not None
            else pagerank_iter_bound(damping, tol)
        )
        run_iters = min(iters, bound) if max_rounds is not None else iters
        with trace.span(
            "pagerank.dense", device=True, n=n, m2=m2, iters=run_iters,
        ) as sp:
            r = _pr_fixed(a, b, w2, deg, t, r, dmp, omd,
                          num_iters=run_iters)
            if not is_tracer(r):
                sp.block_on(r)
        if max_rounds is not None and run_iters < iters and not is_tracer(r):
            # The budget cut the fixed schedule short: probe one extra
            # iteration and fail loudly if scores are still moving (the
            # convergence sentinel; core.components.ConvergenceError).
            _new, mask = _pr_iterate(a, b, w2, deg, t, r, dmp, omd, tolv)
            live = int(jnp.sum(mask.astype(jnp.int32)))  # repro-lint: disable=host-sync
            if live:
                raise ConvergenceError(
                    f"pagerank hit its iteration budget ({bound}) with "
                    f"{live} nodes still above tol={tol} on {n} nodes; "
                    f"raise max_rounds (the tolerance bound is "
                    f"pagerank_iter_bound={pagerank_iter_bound(damping, tol)})"
                )
        stats.iterations = run_iters
        stats.edges_touched += m2 * run_iters
        out = (r, jnp.int32(run_iters))
        return out + (stats,) if with_stats else out

    if tracing:
        raise ValueError(
            "the frontier PageRank engine's tolerance loop is "
            "host-driven and cannot run inside jit; call it outside "
            "jit or use engine='dense'"
        )
    if num_iters is not None:
        raise ValueError(
            "num_iters= is a dense-engine option (fixed schedule); the "
            "frontier engine iterates to tol -- use engine='dense'"
        )
    live_mask = None
    # Spans attach at the per-iteration syncs the tolerance loop
    # already pays (the int() live reads) -- same policy as cc.frontier.
    with trace.span("pagerank.frontier", n=n, m2=m2) as run_sp:

        def live_nodes():
            if live_mask is None:
                return n  # every node is live before the first push
            # The level-synchronous sync: the host reads the tolerance
            # filter's live count to decide termination.
            return int(jnp.sum(live_mask.astype(jnp.int32)))  # repro-lint: disable=host-sync

        def push_level(live):
            nonlocal r, live_mask
            with trace.span("pagerank.level", live=live):
                r, live_mask = _pr_iterate(
                    a, b, w2, deg, t, r, dmp, omd, tolv
                )
            stats.edges_touched += m2
            stats.levels.append(live)

        def bound_hit(live, _rounds):
            raise ConvergenceError(
                f"pagerank hit its iteration bound ({bound}) with "
                f"{live} nodes still above tol={tol} on {n} nodes; "
                f"raise max_rounds (the tolerance bound is "
                f"pagerank_iter_bound={pagerank_iter_bound(damping, tol)})"
            )

        iters = run_rebuild_loop(
            bound=bound, live_count=live_nodes, run_level=push_level,
            on_bound=bound_hit,
        )
        run_sp.tag(iterations=iters)
    stats.iterations = iters
    out = (r, jnp.int32(iters))
    return out + (stats,) if with_stats else out
