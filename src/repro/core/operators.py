"""Gunrock-style advance / filter / compute operators for frontier engines.

The paper's PRAM algorithms share one irregular-access skeleton --
gather values along edges, combine, scatter back -- and the non-trivial
accelerator adaptations (frontier compaction, power-of-two size
buckets, deterministic min-scatters, host-driven level synchronization)
attach to that skeleton, not to any one algorithm. Gunrock (PAPERS.md,
arxiv 1701.01170) showed a small advance/filter/compute operator set
expresses BFS, SSSP, CC, PageRank and BC on GPUs; this module is that
operator set for the repo, and every frontier engine
(``core.frontier.frontier_shiloach_vishkin``,
``core.sssp.frontier_bellman_ford``,
``distributed.graph.sharded_frontier_shiloach_vishkin``,
``core.pagerank.pagerank``) is a composition over it.

Three operator groups (see docs/operators.md for the full contract):

* **advance** -- one gather-apply-scatter step over an edge buffer,
  with scatter collisions resolved by a pluggable commutative
  :class:`Monoid`. ``MIN`` (CC labels, SSSP distances) is idempotent
  min-CRCW: any collision order gives the same bits, the RL002
  scatter-determinism discipline. ``ADD`` (PageRank mass) is
  commutative but float-add is not associative, so its determinism
  contract is weaker: bit-stable for a fixed edge-slot order on a
  backend with deterministic scatter accumulation (CPU/TPU XLA), which
  is exactly what the serial oracle mirrors via ``np.add.at``.
* **filter** -- the frontier machinery: ``next_pow2`` size buckets,
  ``compact_frontier`` / ``compact_weighted`` (gather the masked live
  edges into a fixed-size buffer padded with inert self-loops), and
  ``bucket_size`` tying them together. MIN-monoid frontiers come in two
  flavours: CC's compaction is **permanent** (label equality never
  un-happens) so the buffer only shrinks, while SSSP must **re-compact
  from the full edge list** every level (a settled edge wakes up when
  its source's distance later drops). ADD-monoid frontiers cannot skip
  edges at all -- every contribution is part of the sum -- so for
  PageRank the filter only gates *termination* (the tolerance mask),
  never the edge walk.
* **compute** -- a per-node map over node-indexed arrays; trivially
  parallel, no collisions.

plus the two **host drivers** the engines share: ``run_bucket_ladder``
(CC's shrinking power-of-two levels) and ``run_rebuild_loop`` (SSSP's
and PageRank's rebuild-every-level loop). Both are host-driven (bucket
sizes are compiled shapes -- they cannot run under ``jax.jit``), sync
with the device once per LEVEL (the paper's level-synchronous design),
and guarantee the ``ConvergenceError`` sentinel: a loop that stops
before its fixpoint raises rather than returning wrong results. Spans
(``repro.obs``) and stats stay in the engine-supplied closures so each
engine keeps its exact span vocabulary, pinned counters, and host-sync
pragma sites -- the drivers only own the loop structure, which is how
the refactor keeps every engine bit-exact by construction.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.components import ConvergenceError

Array = jax.Array


# ---------------------------------------------------------------------------
# advance: gather-apply-scatter with a pluggable commutative monoid
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Monoid:
    """A commutative monoid resolving ``advance`` scatter collisions.

    ``scatter(target, index, values)`` folds ``values`` into
    ``target[..., index]`` under the monoid's combine; ``identity`` is
    the pad value that makes a buffer slot inert (``+inf`` for min,
    ``0.0`` for add -- the compaction pads rely on this). The combine
    must be commutative (scatter collision order is unspecified);
    idempotent combines (min) are additionally order-free in float,
    non-idempotent ones (add) are bit-stable only per fixed edge-slot
    order -- see docs/operators.md for the exact contract.
    """

    name: str
    identity: float
    scatter: Callable[[Array, Array, Array], Array]


# ``...`` indexing keeps one scatter form for (n,) node vectors and
# (S, n) batched rows (sources/batch lead, node axis last everywhere).
MIN = Monoid(
    "min", float("inf"), lambda t, i, v: t.at[..., i].min(v)
)
ADD = Monoid(
    "add", 0.0, lambda t, i, v: t.at[..., i].add(v)
)


def advance(target: Array, index: Array, values: Array, *, monoid: Monoid):
    """One advance step: scatter ``values`` into ``target`` at ``index``
    (the last -- node -- axis), collisions resolved by ``monoid``.

    Callers gather/apply first (``values`` is already the per-edge
    candidate, e.g. ``dist[:, a] + w``), so this is the scatter half of
    gather-apply-scatter; keeping it a single primitive is what lets
    the RL002 lint reason about every frontier engine's determinism in
    one place. Traceable: safe inside ``jax.jit`` / ``lax`` loops and
    inside ``shard_map`` blocks (it only touches the buffer it is
    handed -- the shard-local rule, docs/operators.md).
    """
    return monoid.scatter(target, index, values)


# ---------------------------------------------------------------------------
# compute: per-node map
# ---------------------------------------------------------------------------


def compute(fn: Callable, *arrays: Array):
    """Per-node map: apply elementwise ``fn`` over node-indexed arrays.

    Trivially parallel (no collisions, no monoid); exists so operator
    compositions read as advance/filter/compute end to end."""
    return fn(*arrays)


# ---------------------------------------------------------------------------
# filter: power-of-two size buckets + frontier compaction
# ---------------------------------------------------------------------------


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (1 for x <= 0): the bucket ladder every
    frontier engine -- single-device and sharded -- sizes its compacted
    edge buffers on, so compiled shapes stay static per level."""
    return 1 << max(x - 1, 0).bit_length() if x > 0 else 1


def bucket_size(live: int, *, min_bucket: int, cap: int | None = None) -> int:
    """The filter's bucket rule: the ``next_pow2`` ceiling of the live
    count, floored at ``min_bucket`` (tiny buckets recompile for no
    win) and clipped to ``cap`` (usually the full edge-buffer size --
    never compact into a bucket larger than the data)."""
    size = max(min_bucket, next_pow2(live))
    return size if cap is None else min(cap, size)


@partial(jax.jit, static_argnames=("size",))
def compact_frontier(a, b, fmask, *, size):
    """Gather the masked frontier into a ``size``-slot buffer, padding
    with inert (0, 0) self-loops. ``size`` must cover the mask count.

    This is the **shard-local compaction primitive**: it only ever looks
    at the edge buffer it is handed, so the sharded frontier engine
    (``repro.distributed.graph.sharded_frontier_shiloach_vishkin``) runs
    it unchanged inside ``shard_map`` -- each device compacts its own
    edge shard into a bucket sized by the global (pmax'd) live count, so
    every shard keeps one common compiled shape per level."""
    m = a.shape[0]
    idx = jnp.nonzero(fmask, size=size, fill_value=m)[0]
    valid = idx < m
    ic = jnp.minimum(idx, max(m - 1, 0))
    return jnp.where(valid, a[ic], 0), jnp.where(valid, b[ic], 0)


@partial(jax.jit, static_argnames=("size",))
def compact_weighted(a, b, w, fmask, *, size):
    """``compact_frontier`` with a weight lane: gather the masked
    frontier into a ``size``-slot buffer, padding with inert (0, 0)
    zero-weight self-loops (a self-relax can never improve, and 0.0 is
    the ADD identity, so the pads are inert under both monoids)."""
    m = a.shape[0]
    idx = jnp.nonzero(fmask, size=size, fill_value=m)[0]
    valid = idx < m
    ic = jnp.minimum(idx, max(m - 1, 0))
    return (
        jnp.where(valid, a[ic], 0),
        jnp.where(valid, b[ic], 0),
        jnp.where(valid, w[ic], 0.0),
    )


# ---------------------------------------------------------------------------
# host drivers: the two level-loop shapes every frontier engine runs
# ---------------------------------------------------------------------------


def run_bucket_ladder(
    *,
    bucket: int,
    min_bucket: int,
    run_level: Callable[[int, int | None], tuple[bool, bool]],
    live_count: Callable[[], int],
    compact: Callable[[int], None],
    on_shrink: Callable[[int], None] | None = None,
    on_nonconverged: Callable[[], None] | None = None,
) -> None:
    """The MONOTONE frontier loop (CC's shrinking bucket ladder): run
    levels at a fixed buffer size, shrink the buffer to the live
    frontier's ``next_pow2`` bucket between levels, never re-expand
    (compaction is permanent -- see docs/operators.md).

    ``run_level(bucket, shrink_at)`` runs one level and returns
    ``(converged, stop)``; ``shrink_at`` is the half-buffer watermark
    the level's device loop may exit early on (``None`` = run to
    convergence/bound: the bucket is already at ``min_bucket``, or a
    previous shrink attempt failed). ``live_count()`` reads the live
    frontier size (the per-level host sync -- only called when a shrink
    is still possible), ``on_shrink(new_bucket)`` is the stats hook
    charged before ``compact(new_bucket)`` rebuilds the buffer. A
    ladder that stops without converging calls ``on_nonconverged``
    (expected to raise the engine's own ``ConvergenceError``) and
    otherwise raises a generic one -- wrong labels never escape.
    """
    force_converge = False
    while True:
        shrink_at = (
            None if (bucket <= min_bucket or force_converge)
            else bucket // 2
        )
        converged, stop = run_level(bucket, shrink_at)
        if converged or stop:
            break
        live = live_count()
        new_bucket = max(min_bucket, next_pow2(live))
        if new_bucket >= bucket:  # can't shrink: run to convergence
            force_converge = True
            continue
        if on_shrink is not None:
            on_shrink(new_bucket)
        compact(new_bucket)
        bucket = new_bucket
    if not converged:
        if on_nonconverged is not None:
            on_nonconverged()
        raise ConvergenceError(
            "bucket ladder stopped before convergence"
        )


def run_rebuild_loop(
    *,
    bound: int,
    live_count: Callable[[], int],
    run_level: Callable[[int], None],
    on_bound: Callable[[int, int], None] | None = None,
) -> int:
    """The REBUILDING frontier loop (SSSP, PageRank): every level asks
    ``live_count()`` for the current live size (SSSP re-masks the FULL
    edge list -- settled edges wake up; PageRank counts above-tolerance
    nodes), stops at zero, and otherwise runs ``run_level(live)``.
    Returns the number of levels run.

    Hitting ``bound`` with a live frontier calls ``on_bound(live,
    rounds)`` (expected to raise the engine's ``ConvergenceError``) and
    otherwise raises a generic one -- the sentinel fires before wrong
    distances/scores can escape."""
    rounds = 0
    while True:
        live = live_count()
        if not live:
            return rounds
        if rounds >= bound:
            if on_bound is not None:
                on_bound(live, rounds)
            raise ConvergenceError(
                f"rebuild loop hit its round bound ({bound}) with "
                f"{live} live"
            )
        run_level(live)
        rounds += 1
