"""Sequential oracles: the paper's CPU baselines, used by tests/benchmarks."""
from __future__ import annotations

import numpy as np


def serial_list_rank(succ: np.ndarray, head: int = 0) -> np.ndarray:
    """O(n) single-thread traversal (the paper's sequential CPU baseline).

    rank[j] = number of edges from j to the last element (rank[last] = 0).
    """
    n = len(succ)
    order = np.empty(n, dtype=np.int64)
    j = head
    for i in range(n):
        order[i] = j
        nxt = succ[j]
        if nxt == j:
            assert i == n - 1, "list does not cover all nodes"
            break
        j = nxt
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n - 1, -1, -1)
    return rank


class UnionFind:
    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)

    def find(self, x: int) -> int:
        p = self.parent
        root = x
        while p[root] != root:
            root = p[root]
        while p[x] != root:  # path compression
            p[x], x = root, p[x]
        return root

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[max(ra, rb)] = min(ra, rb)


def serial_connected_components(edges: np.ndarray, n: int) -> np.ndarray:
    """Union-find labels; canonical label = min node id in the component."""
    uf = UnionFind(n)
    for a, b in edges:
        uf.union(int(a), int(b))
    return np.array([uf.find(i) for i in range(n)], dtype=np.int64)


def canonicalize_labels(labels: np.ndarray) -> np.ndarray:
    """Map each component label to the min node id inside it (for equality
    testing across algorithms that pick different representatives)."""
    labels = np.asarray(labels)
    n = len(labels)
    rep: dict[int, int] = {}
    for i in range(n):
        l = int(labels[i])
        if l not in rep:
            rep[l] = i
    return np.array([rep[int(l)] for l in labels], dtype=np.int64)
