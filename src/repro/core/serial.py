"""Sequential oracles: the paper's CPU baselines, used by tests/benchmarks."""
from __future__ import annotations

import numpy as np


def serial_list_rank(succ: np.ndarray, head: int = 0) -> np.ndarray:
    """O(n) single-thread traversal (the paper's sequential CPU baseline).

    rank[j] = number of edges from j to the last element (rank[last] = 0).
    """
    n = len(succ)
    order = np.empty(n, dtype=np.int64)
    j = head
    for i in range(n):
        order[i] = j
        nxt = succ[j]
        if nxt == j:
            assert i == n - 1, "list does not cover all nodes"
            break
        j = nxt
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n - 1, -1, -1)
    return rank


class UnionFind:
    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)

    def find(self, x: int) -> int:
        p = self.parent
        root = x
        while p[root] != root:
            root = p[root]
        while p[x] != root:  # path compression
            p[x], x = root, p[x]
        return root

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[max(ra, rb)] = min(ra, rb)


def serial_connected_components(edges: np.ndarray, n: int) -> np.ndarray:
    """Union-find labels; canonical label = min node id in the component."""
    uf = UnionFind(n)
    for a, b in edges:
        uf.union(int(a), int(b))
    return np.array([uf.find(i) for i in range(n)], dtype=np.int64)


def _sssp_arcs(edges: np.ndarray, weights: np.ndarray | None):
    """Both-orientation (u, v, w) arcs in float32 -- the engines'
    undirected 2m walk. ``weights=None`` means unit weights (BFS)."""
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    m = len(edges)
    w = (
        np.ones(m, np.float32)
        if weights is None
        else np.asarray(weights, np.float32).ravel()
    )
    assert len(w) == m, "weights length != edge count"
    u = np.concatenate([edges[:, 0], edges[:, 1]])
    v = np.concatenate([edges[:, 1], edges[:, 0]])
    return u, v, np.concatenate([w, w])


def serial_sssp_parents(
    edges: np.ndarray,
    weights: np.ndarray | None,
    dist: np.ndarray,
    source: int,
) -> np.ndarray:
    """The engines' deterministic parent rule, serially: ``parent[v] =
    min{u : u != v, dist[u] + w(u, v) == dist[v]}`` (float32 compare,
    both edge orientations), ``parent[source] = source``, unreachable
    ``-1``. Shared by both oracles so the tie-break matches
    ``repro.core.sssp._min_parents`` bit-for-bit."""
    n = len(dist)
    u, v, w = _sssp_arcs(edges, weights)
    parent = np.full(n, n, np.int64)
    for ui, vi, wi in zip(u, v, w):
        if ui == vi:
            continue  # self-relaxes never parent (engine rule)
        if np.float32(dist[ui] + wi) == dist[vi]:
            parent[vi] = min(parent[vi], ui)
    parent[parent == n] = -1
    parent[np.isinf(dist)] = -1
    parent[source] = source
    return parent.astype(np.int64)


def serial_dijkstra(
    edges: np.ndarray,
    weights: np.ndarray | None,
    n: int,
    source: int,
):
    """Binary-heap Dijkstra in float32 (the sequential CPU baseline for
    ``repro.core.sssp``; weights must be >= 0). Returns ``(dist,
    parent)``: float32 distances with ``+inf`` for unreachable nodes,
    parents per ``serial_sssp_parents``. Float32 addition is monotonic
    and every path cost accumulates left-to-right one edge at a time --
    the same operations the relax-min engines perform -- so distances
    are bit-identical to Bellman-Ford's fixpoint."""
    import heapq

    u, v, w = _sssp_arcs(edges, weights)
    adj: list[list[tuple[int, np.float32]]] = [[] for _ in range(n)]
    for ui, vi, wi in zip(u, v, w):
        adj[ui].append((int(vi), wi))
    dist = np.full(n, np.inf, np.float32)
    dist[source] = np.float32(0.0)
    heap = [(np.float32(0.0), source)]
    done = np.zeros(n, bool)
    while heap:
        d, x = heapq.heappop(heap)
        if done[x]:
            continue
        done[x] = True
        for y, wy in adj[x]:
            nd = np.float32(dist[x] + wy)
            if nd < dist[y]:
                dist[y] = nd
                heapq.heappush(heap, (nd, y))
    return dist, serial_sssp_parents(edges, weights, dist, source)


def serial_bellman_ford(
    edges: np.ndarray,
    weights: np.ndarray | None,
    n: int,
    source: int,
):
    """Round-synchronous serial Bellman-Ford in float32: relax every
    arc each round until the fixpoint (at most n - 1 improving rounds).
    Returns ``(dist, parent)`` exactly like ``serial_dijkstra`` -- the
    two oracles agree bit-for-bit, and both pin the engines."""
    u, v, w = _sssp_arcs(edges, weights)
    dist = np.full(n, np.inf, np.float32)
    dist[source] = np.float32(0.0)
    for _ in range(max(n, 1)):
        cand = (dist[u] + w).astype(np.float32)
        new = dist.copy()
        np.minimum.at(new, v, cand)
        if (new == dist).all():
            break
        dist = new
    return dist, serial_sssp_parents(edges, weights, dist, source)


def serial_pagerank(
    edges: np.ndarray,
    weights: np.ndarray | None,
    n: int,
    *,
    damping: float = 0.85,
    num_iters: int,
    teleport: np.ndarray | None = None,
) -> np.ndarray:
    """NumPy mirror of ``repro.core.pagerank`` at a fixed iteration
    count: the exact float32 op sequence -- separately-rounded
    multiplies, teleport as the scatter BASE, ``np.add.at``
    accumulation in edge-slot order (which matches the XLA scatter-add
    on the CPU/TPU backends) -- so scores pin both device engines
    bit-for-bit, iteration for iteration. ``weights=None`` means unit
    weights; dangling mass leaks exactly like the engines'."""
    u, v, w = _sssp_arcs(edges, weights)
    dmp = np.float32(damping)
    omd = np.float32(1.0) - dmp
    t = (
        np.full(n, 1.0 / n, np.float32)
        if teleport is None
        else np.asarray(teleport, np.float32).ravel()
    )
    deg = np.zeros(n, np.float32)
    np.add.at(deg, u, w)
    r = t.copy()
    for _ in range(num_iters):
        with np.errstate(divide="ignore", invalid="ignore"):
            out = np.where(deg > 0, r / deg, np.float32(0.0)).astype(
                np.float32
            )
        r = (omd * t).astype(np.float32)
        np.add.at(r, v, (dmp * (out[u] * w)).astype(np.float32))
    return r


def canonicalize_labels(labels: np.ndarray) -> np.ndarray:
    """Map each component label to the min node id inside it (for equality
    testing across algorithms that pick different representatives)."""
    labels = np.asarray(labels)
    n = len(labels)
    rep: dict[int, int] = {}
    for i in range(n):
        l = int(labels[i])
        if l not in rep:
            rep[l] = i
    return np.array([rep[int(l)] for l in labels], dtype=np.int64)
