"""Parallel list ranking on TPU (paper section 3).

Two algorithms, as in the paper:

* ``wylie_rank`` -- Wylie's pointer jumping. O(n log n) work, O(log n)
  steps. Each step follows every node's pointer: two irregular gathers per
  step in SoA layout, or ONE row gather in AoS layout (the paper's 64-bit
  union packing of (rank, last), guideline G5).

* ``random_splitter_rank`` -- Reid-Miller's parallel random splitter
  algorithm (paper Algorithm 1/3). O(n + p log p) work. Five phases mapped
  from the paper's five kernels RS1..RS5:
    RS1/RS2  init + splitter selection (KISS RNG, one stream per lane),
    RS3      lockstep masked sub-list walk (the irregular-access hot spot),
    RS4      pointer jumping on the p-node splitter list (fits in VMEM ->
             single Pallas kernel, the paper's "single thread block +
             __syncthreads" fast path),
    RS5      streaming rank aggregation (the coalescing-friendly kernel; a
             blocked Pallas kernel keeps the splitter table VMEM-resident).

rank[j] = number of edges from j to the last list element (rank[last] = 0).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.components import check_choice
from repro.core.pram import lockstep_walk
from repro.ops.kiss import KissRng

Array = jax.Array

PACK_MODES = ("aos", "soa", "word64")
# wylie_rank's subset: pointer jumping has no word64-packed variant.
WYLIE_PACK_MODES = ("aos", "soa")
KERNEL_IMPLS = ("auto", "xla", "pallas", "pallas_interpret")


def max_splitters_for_linear_work(n: int) -> int:
    """Largest p with p*log2(p) <= n (paper: keeps total work O(n))."""
    p = max(2, n)
    while p * math.log2(max(p, 2)) > n and p > 2:
        p //= 2
    return p


# ---------------------------------------------------------------------------
# Wylie's algorithm (pointer jumping)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("pack_mode", "num_iters"))
def wylie_rank(
    succ: Array, *, pack_mode: str = "aos", num_iters: int | None = None
) -> Array:
    n = succ.shape[0]
    iters = num_iters if num_iters is not None else max(1, math.ceil(math.log2(max(n, 2))))
    lane = jnp.arange(n, dtype=succ.dtype)
    rank0 = (succ != lane).astype(jnp.int32)

    check_choice("pack_mode", pack_mode, WYLIE_PACK_MODES)
    if pack_mode == "soa":

        def body(_, st):
            rank, last = st
            # two independent irregular gathers per step
            return rank + rank[last], last[last]

        rank, _ = jax.lax.fori_loop(0, iters, body, (rank0, succ.astype(jnp.int32)))
        return rank

    if pack_mode == "aos":
        packed0 = jnp.stack([rank0, succ.astype(jnp.int32)], axis=-1)

        def body(_, packed):
            # ONE row gather fetches (rank[last], last[last]) together:
            # the paper's 64-bit union trick as an (n, 2) AoS row.
            row = jnp.take(packed, packed[:, 1], axis=0)
            return jnp.stack([packed[:, 0] + row[:, 0], row[:, 1]], axis=-1)

        packed = jax.lax.fori_loop(0, iters, body, packed0)
        return packed[:, 0]

    raise AssertionError("unreachable: pack_mode validated above")


# ---------------------------------------------------------------------------
# Reid-Miller's parallel random splitter algorithm
# ---------------------------------------------------------------------------


@dataclass
class SplitterStats:
    """Observables the paper reports in Tables 2/3."""

    splitters: np.ndarray  # (p,) node ids
    sublist_lengths: np.ndarray  # (p,) walk lengths (= RS4 weights)
    walk_steps: int  # lockstep trip count = max sub-list length
    expected_mean: float  # n / p (Table 3 "Mean")

    def publish(self, registry=None, prefix: str = "rank.splitter") -> None:
        """Publish into the metrics registry (``repro.obs.metrics``)."""
        from repro.obs.metrics import publish_stats

        publish_stats(self, prefix, registry)


def select_splitters(n: int, p: int, seed: int = 0, head: int = 0) -> np.ndarray:
    """RS2: one KISS stream per lane picks a splitter in its n/p block.

    Lane 0's pick is replaced by the list head so every node is covered
    (Reid-Miller's convention; the head starts the first sub-list).
    """
    if p < 1 or p > n:
        raise ValueError(f"need 1 <= p <= n, got p={p} n={n}")
    block = n // p
    rng = KissRng(seed, n_streams=p)
    offs = rng.next_u32().astype(np.int64) % max(block, 1)
    spl = np.minimum(np.arange(p, dtype=np.int64) * block + offs, n - 1)
    spl[0] = head
    # Ensure distinctness (head may collide with lane 0's block anyway).
    spl = np.unique(spl)
    if len(spl) < p:  # refill collisions deterministically
        missing = p - len(spl)
        pool = np.setdiff1d(np.arange(n, dtype=np.int64), spl, assume_unique=True)
        spl = np.concatenate([spl, pool[:missing]])
    return np.sort(spl)


def even_splitters(succ: np.ndarray, p: int, head: int = 0) -> np.ndarray:
    """Perfect splitters for the Table-3 control: every n/p-th list node."""
    n = len(succ)
    order = np.empty(n, dtype=np.int64)
    j = head
    for i in range(n):
        order[i] = j
        j = succ[j]
    return np.sort(order[:: max(n // p, 1)][:p])


def _splitter_list_rank(w_adj: Array, spsucc: Array, iters: int) -> Array:
    """RS4: weighted pointer jumping over the p-node splitter list.

    Returns final splitter ranks: rank_sp[s] = edges from s to the last
    list element. Terminal splitters (spsucc == self) carry their residual
    walk length in w_adj.
    """
    p = w_adj.shape[0]
    lanes = jnp.arange(p, dtype=spsucc.dtype)
    is_term = spsucc == lanes
    r = jnp.where(is_term, 0, w_adj)
    nxt = spsucc

    def body(_, st):
        r, nxt = st
        return r + r[nxt], nxt[nxt]

    r, nxt = jax.lax.fori_loop(0, iters, body, (r, nxt))
    # nxt now points at each chain's terminal; add its residual once.
    return r + w_adj[nxt]


def aos_walk_fns(succ: Array, is_stop: Array, lanes: Array, valid=None):
    """RS3 active/step functions for the AoS store.

    Shared by the single-device core and the sharded engine (which
    passes offset global lane ids plus a ``valid`` mask for padded
    lanes) -- one copy of the walk predicate and scatter keeps the two
    engines bit-identical by construction.
    """
    n = succ.shape[0]

    def active_fn(st):
        act = jnp.logical_and(~is_stop[st["nxt"]], st["nxt"] != st["cur"])
        return act if valid is None else jnp.logical_and(valid, act)

    def step_fn(st, active):
        (packed,) = st["store"]
        nxt, cur, dist = st["nxt"], st["cur"], st["dist"]
        tgt = jnp.where(active, nxt, n)  # OOB rows are dropped (branch-free)
        rows = jnp.stack([dist, lanes], axis=-1)
        packed = packed.at[tgt].set(rows, mode="drop")
        return dict(
            store=(packed,),
            cur=jnp.where(active, nxt, cur),
            nxt=jnp.where(active, succ[nxt], nxt),
            dist=dist + active.astype(jnp.int32),
        )

    return active_fn, step_fn


@partial(jax.jit, static_argnames=("pack_mode", "max_steps", "kernel_impl"))
def _random_splitter_core(
    succ: Array,
    splitters: Array,
    *,
    pack_mode: str = "aos",
    max_steps: int | None = None,
    kernel_impl: str = "xla",  # "pallas": RS4/RS5 via the Pallas kernels
):
    n = succ.shape[0]
    p = splitters.shape[0]
    succ = succ.astype(jnp.int32)
    splitters = splitters.astype(jnp.int32)
    lanes = jnp.arange(p, dtype=jnp.int32)

    is_stop = jnp.zeros((n,), jnp.bool_).at[splitters].set(True)

    if pack_mode == "soa":
        owner = jnp.full((n,), -1, jnp.int32).at[splitters].set(lanes)
        local = jnp.zeros((n,), jnp.int32)
        store = (owner, local)
    elif pack_mode in ("aos", "word64"):
        # AoS rows [local_rank, owner]; word64 packs the same pair into one
        # integer word when x64 is enabled (benchmarks only).
        packed = jnp.full((n, 2), -1, jnp.int32)
        packed = packed.at[:, 0].set(0)
        packed = packed.at[splitters, 1].set(lanes)
        store = (packed,)
    else:
        raise ValueError(f"unknown pack_mode {pack_mode!r}")

    # --- RS3: lockstep masked walk --------------------------------------
    state = dict(
        store=store,
        cur=splitters,
        nxt=succ[splitters],
        dist=jnp.ones((p,), jnp.int32),
    )

    if pack_mode == "soa":

        def active_fn(st):
            return jnp.logical_and(~is_stop[st["nxt"]], st["nxt"] != st["cur"])

        def step_fn(st, active):
            owner, local = st["store"]
            nxt, cur, dist = st["nxt"], st["cur"], st["dist"]
            tgt = jnp.where(active, nxt, n)  # OOB rows dropped (branch-free)
            owner = owner.at[tgt].set(lanes, mode="drop")
            local = local.at[tgt].set(dist, mode="drop")
            return dict(
                store=(owner, local),
                cur=jnp.where(active, nxt, cur),
                nxt=jnp.where(active, succ[nxt], nxt),
                dist=dist + active.astype(jnp.int32),
            )

    else:
        active_fn, step_fn = aos_walk_fns(succ, is_stop, lanes)

    final, steps, converged = lockstep_walk(
        state, active_fn, step_fn, max_steps=max_steps
    )

    if pack_mode == "soa":
        owner, local = final["store"]
    else:
        (packed,) = final["store"]
        local, owner = packed[:, 0], packed[:, 1]

    # --- RS4: rank the splitter linked list ------------------------------
    # The splitter list fits VMEM: with kernel_impl="pallas" ALL O(log p)
    # jumping steps run inside one Pallas kernel (the paper's single-block
    # __syncthreads() fast path; see kernels/pointer_jump).
    spsucc = owner[final["nxt"]]
    is_term = spsucc == lanes
    w_adj = final["dist"] - is_term.astype(jnp.int32)
    iters = max(1, math.ceil(math.log2(max(p, 2))))
    if kernel_impl != "xla":
        from repro.kernels.pointer_jump.ops import pointer_jump

        r, nxt_final = pointer_jump(
            spsucc, jnp.where(is_term, 0, w_adj),
            iters=iters, impl=kernel_impl,
        )
        rank_sp = r + w_adj[nxt_final]
    else:
        rank_sp = _splitter_list_rank(w_adj, spsucc, iters)

    # --- RS5: streaming aggregation (coalesced: pure striding access) ----
    if kernel_impl != "xla":
        from repro.kernels.splitter_aggregate.ops import splitter_aggregate

        if pack_mode == "soa":
            packed_rs5 = jnp.stack([local, owner], axis=-1)
        else:
            packed_rs5 = jnp.stack([packed[:, 0], packed[:, 1]], axis=-1)
        rank = splitter_aggregate(packed_rs5, rank_sp, impl=kernel_impl)
    elif pack_mode == "soa":
        rank = rank_sp[owner] - local
    else:
        # one row gather yields (local, owner) together
        rank = rank_sp[packed[:, 1]] - packed[:, 0]

    return rank, final["dist"], steps, converged


def random_splitter_rank(
    succ: Array | np.ndarray,
    num_splitters: int | None = None,
    *,
    splitters: np.ndarray | None = None,
    head: int = 0,
    seed: int = 0,
    pack_mode: str = "aos",
    max_steps: int | None = None,
    kernel_impl: str = "xla",
    with_stats: bool = False,
):
    """Rank a linked list with Reid-Miller's random splitter algorithm.

    ``kernel_impl`` routes the RS4/RS5 phases through the Pallas
    kernels: "auto" compiles them on a real TPU backend and keeps plain
    XLA elsewhere; "pallas"/"pallas_interpret" force the kernel path
    (interpreted off-TPU). Unknown strings raise (they used to fall
    through to the XLA path silently).

    If ``max_steps`` cuts the lockstep walk off before every lane
    reaches its splitter, the ranks would be wrong -- host calls raise
    ``ConvergenceError`` instead of returning them (under a ``jax.jit``
    trace the sentinel cannot raise; the bounded state is returned).
    """
    from repro.compat import is_tracer
    from repro.core.components import ConvergenceError
    from repro.kernels import on_tpu

    check_choice("pack_mode", pack_mode, PACK_MODES)
    check_choice("kernel_impl", kernel_impl, KERNEL_IMPLS)
    if kernel_impl == "auto":
        kernel_impl = "pallas" if on_tpu() else "xla"
    succ = jnp.asarray(succ)
    n = int(succ.shape[0])
    if splitters is None:
        p = num_splitters or min(4096, max_splitters_for_linear_work(n))
        p = min(p, n)
        splitters = select_splitters(n, p, seed=seed, head=head)
    splitters = np.asarray(splitters)
    rank, sublens, steps, converged = _random_splitter_core(
        succ, jnp.asarray(splitters), pack_mode=pack_mode,
        max_steps=max_steps, kernel_impl=kernel_impl,
    )
    if max_steps is not None and not is_tracer(converged):
        # Intentional terminal sync: the walk sentinel must be read
        # before truncated (wrong) ranks can escape.
        if not bool(converged):  # repro-lint: disable=host-sync
            raise ConvergenceError(
                f"random_splitter_rank walk hit max_steps={max_steps} "
                "with lanes still active; ranks would be truncated -- "
                "raise max_steps or add splitters"
            )
    if not with_stats:
        return rank
    # Opt-in stats materialization after the walk finished.
    stats = SplitterStats(
        splitters=np.asarray(splitters),  # repro-lint: disable=host-sync
        sublist_lengths=np.asarray(sublens),  # repro-lint: disable=host-sync
        walk_steps=int(steps),  # repro-lint: disable=host-sync
        expected_mean=n / len(splitters),
    )
    return rank, stats
