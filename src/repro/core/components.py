"""Shiloach-Vishkin connected components on TPU (paper section 4).

The paper's seven CUDA kernels SV0..SV5 (Algorithm 4) become seven
functional phases inside one ``lax.while_loop`` round. Adaptations per
DESIGN.md section 2:

* arbitrary-CRCW concurrent writes -> deterministic **min-CRCW** scatter
  (``.at[].min``). Any arbitrary-write resolution is a valid hook; choosing
  the minimum keeps runs reproducible and still satisfies the paper's
  O(log_{3/2} n) + 2 round bound.
* the SV1a/SV1b kernel split (barrier between short-cutting and marking) is
  structural here: ``D_new`` is a fresh functional value, so the data race
  the paper warns about cannot occur. We keep the phases separate anyway so
  per-phase work counts match Table 4.
* SV5's parallel-OR through racing writes to one word becomes ``jnp.any``.

The round body is built once by ``sv_round_fns`` and shared by THREE
engines so their hook semantics stay bit-identical by construction:

* ``sv_run`` / ``shiloach_vishkin`` -- the dense single-device loop;
* ``repro.core.frontier.frontier_shiloach_vishkin`` -- the
  frontier-compacted engine (same body over a shrinking edge buffer);
* ``repro.distributed.graph.sharded_shiloach_vishkin`` -- the
  edge-partitioned engine (same body plus per-round label exchanges).

Cross-replica merges use the convention ``fn(arr, base, aux, s) ->
(arr, aux)``: ``base`` is the replicated pre-scatter array (what every
device agreed on before this phase's min-scatter), which is what lets
the sparse frontier exchange send only the (index, label) pairs that
changed; ``aux`` threads exchange statistics through the round loop.

``label_propagation`` is the simple O(diameter)-round alternative used as a
baseline in benchmarks (it wins on small-diameter random graphs, loses badly
on chains -- the same graph-family sensitivity as the paper's Figure 4).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def sv_round_bound(n: int) -> int:
    """Paper/[14]: at most floor(log_{3/2} n) + 2 rounds."""
    return int(math.floor(math.log(max(n, 2)) / math.log(1.5))) + 2


class ConvergenceError(RuntimeError):
    """A bounded round/walk loop hit its bound without reaching a
    fixpoint. Labels past the bound would be WRONG (an un-hooked edge
    still straddles two components), so every host-driven engine raises
    this instead of returning them -- a silent bound-hit is exactly how
    a broken invariant (e.g. a nondeterministic scatter, guideline G3 /
    RL002) would otherwise leak wrong results. Fully traced callers
    (``jax.jit`` over the dense walks) cannot raise on a device value;
    they keep the documented return-at-bound behavior, and the serve
    path fails just the offending wave (``docs/serving.md``)."""


def _identity_merge(arr, base, aux, s):
    del base, s
    return arr, aux


def check_choice(kind: str, value, choices) -> None:
    """Reject unknown dispatch strings loudly, naming the valid set.

    Shared by every ``engine=`` / ``kernel_impl=`` / ``hook_impl=``
    switch so a typo fails at the call site instead of silently falling
    through to a default path."""
    if value not in choices:
        raise ValueError(
            f"unknown {kind} {value!r}; valid choices: "
            + ", ".join(repr(c) for c in choices)
        )


HOOK_IMPLS = ("xla", "auto", "pallas", "pallas_interpret")


def _lift_merge(fn):
    """Adapt an engine merge fn (which owns only its engine aux) to the
    nested ``(hooks, engine_aux)`` aux used when ``record_hooks`` is on,
    so no engine's merge functions need to know about hook recording."""

    def lifted(arr, base, aux, s):
        hooks, inner = aux
        arr, inner = fn(arr, base, inner, s)
        return arr, (hooks, inner)

    return lifted


def init_hooks(n: int):
    """Fresh hook-recording state: ``(hook_u, hook_v)``, sentinel ``n``.

    Slot r holds the endpoints of the graph edge that won the min-CRCW
    hook of tree r (the round r's label slot changed), or ``n`` if tree
    r never hooked (component roots). Each slot hooks at most once over
    a whole run -- once D[r] drops below r, no node carries label r
    again after the round's short-cuts -- so the arrays are write-once
    and the recorded pairs form a spanning forest: one edge per hook
    event, hooks always point label-decreasing (acyclic), and a
    component of size c hooks exactly c - 1 times."""
    return jnp.full((n,), n, jnp.int32), jnp.full((n,), n, jnp.int32)


def _hook_phase_fns(a: Array, b: Array, n: int, hook_impl: str):
    """SV2/SV3 hook phases over the edge arrays: either inline XLA
    gathers + min-scatters, or the fused ``kernels/edge_hook`` Pallas
    kernel (one VMEM-resident pass per edge tile)."""
    if hook_impl != "xla":
        from repro.kernels.edge_hook.ops import edge_hook

        def sv2(D1, D, Q, s):
            return edge_hook(a, b, D1, Q, s, labels_prev=D, mode="sv2",
                             impl=hook_impl)

        def sv3(D2, Q, s):
            D3, _ = edge_hook(a, b, D2, Q, s, mode="sv3", impl=hook_impl)
            # the fused kernel doesn't export its compare mask (yet);
            # frontier callers recompute it (see sv_round_fns)
            return D3, None

        return sv2, sv3

    def sv2(D1, D, Q, s):
        # SV2: hook edges from trees that did NOT shrink onto smaller roots.
        Da, Db = D1[a], D1[b]
        stagnant_a = Da == D[a]
        cond2 = jnp.logical_and(stagnant_a, Db < Da)
        tgt2 = jnp.where(cond2, Da, n)
        D2 = D1.at[tgt2].min(jnp.where(cond2, Db, n), mode="drop")
        # Every winning lane writes the SAME scalar stamp s: duplicate
        # targets commute, so plain set is deterministic here.
        Q2 = Q.at[jnp.where(cond2, Db, n)].set(s, mode="drop")  # repro-lint: disable=scatter-determinism
        return D2, Q2

    def sv3(D2, Q, s):
        # SV3: hook stagnant roots (no activity this round) onto any
        # neighboring tree, breaking label-order ties via min-CRCW.
        Da3, Db3 = D2[a], D2[b]
        root_a = D2[Da3] == Da3
        stagnant = Q[Da3] < s
        live = Da3 != Db3
        cond3 = stagnant & root_a & live
        tgt3 = jnp.where(cond3, Da3, n)
        # ``live`` rides along as the frontier mask: a superset of the
        # edges still able to hook after this round (label equality is
        # permanent), read off SV3's own gathers at zero extra passes.
        return D2.at[tgt3].min(jnp.where(cond3, Db3, n), mode="drop"), live

    return sv2, sv3


def sv_round_fns(
    a: Array,
    b: Array,
    n: int,
    merge_labels=None,
    merge_stamps=None,
    hook_impl: str = "xla",
    with_frontier: bool = False,
    record_hooks: bool = False,
    merge_hooks=None,
):
    """Build the SV1a..SV5 round body over edge arrays ``(a, b)``.

    Returns ``round_body(carry) -> carry`` with carry
    ``(D, Q, aux, s, changed)``. This is THE round body: every engine
    (dense, frontier-compacted, sharded) runs it unmodified, so hook
    semantics -- min-CRCW resolution, Q stamps, the round bound -- are
    bit-identical across engines by construction.

    ``with_frontier=True`` appends a per-edge frontier mask to the carry
    (``(D, Q, aux, s, changed, fmask)``): a superset of the edges still
    able to hook, read off the SV3 phase's own D[a]/D[b] gathers (the
    pre-hook compare), so the frontier engine's shrink decisions cost no
    extra edge passes on the XLA path. The Pallas hook kernel doesn't
    export its compare mask, so that path recomputes the mask post-round
    (one extra pass).

    ``record_hooks=True`` records, for every hook event, the graph edge
    that won the min-CRCW scatter (the spanning-forest by-product the
    ``repro.trees`` subsystem consumes). The aux slot then carries
    ``((hook_u, hook_v), engine_aux)`` -- see ``init_hooks`` -- and
    ``merge_labels``/``merge_stamps`` are lifted automatically to their
    engine_aux component, so engines opt in without changing their merge
    functions. Recording only READS the label/stamp state (after each
    phase's merge) and writes the side arrays, so labels, stamps, and
    round counts are bit-identical with recording on or off, on every
    engine, by construction. ``merge_hooks`` is the cross-replica
    reduction for the candidate arrays (identity on a single device,
    pmin in the sharded engine); it runs twice per phase -- once to
    agree on the winning ``u``, once for the matching ``v`` -- so the
    recorded pair is a real edge even when the winner is on another
    device's shard.
    """
    ml = merge_labels if merge_labels is not None else _identity_merge
    mq = merge_stamps if merge_stamps is not None else _identity_merge
    if record_hooks:
        ml, mq = _lift_merge(ml), _lift_merge(mq)
    mh = merge_hooks if merge_hooks is not None else (lambda arr: arr)
    sv2_hook, sv3_hook = _hook_phase_fns(a, b, n, hook_impl)

    def record_phase(hooks, cond, tgt, val, D_before, D_after):
        """Record the winning edge of every slot this phase hooked.

        A slot r hooked iff its merged label changed; the winners are
        the edges that (a) satisfied the phase's hook condition, (b)
        targeted r, and (c) wrote exactly the value that survived the
        min. Ties (several edges writing the min label) break to the
        lexicographically smallest (u, v): one min-scatter picks u, a
        second -- conditioned on the merged u -- picks its v, which
        keeps the pair an actual edge and makes the recorded forest
        deterministic and engine-independent."""
        hook_u, hook_v = hooks
        tc = jnp.minimum(tgt, n - 1)  # clamped: non-winners masked below
        hooked = D_after[tc] != D_before[tc]
        win = cond & (val == D_after[tc]) & hooked
        cu = jnp.full((n,), n, jnp.int32).at[
            jnp.where(win, tgt, n)
        ].min(a, mode="drop")
        cu = mh(cu)
        win_v = win & (a == cu[tc])
        cv = jnp.full((n,), n, jnp.int32).at[
            jnp.where(win_v, tgt, n)
        ].min(b, mode="drop")
        cv = mh(cv)
        return jnp.where(cu < n, cu, hook_u), jnp.where(cv < n, cv, hook_v)

    def round_body(carry):
        if with_frontier:
            D, Q, aux, s, _changed, _fmask = carry
        else:
            D, Q, aux, s, _changed = carry

        # SV1a: short-cut.
        D1 = D[D]
        # SV1b: mark roots whose tree shrank. (Concurrent writes of the same
        # value s -> plain scatter-set with OOB drop for unmarked lanes.)
        mark = D1 != D
        Q = Q.at[jnp.where(mark, D1, n)].set(s, mode="drop")  # repro-lint: disable=scatter-determinism
        q_base = Q  # replicated: the shrink marks are device-independent

        D2, Q = sv2_hook(D1, D, Q, s)
        D2, aux = ml(D2, D1, aux, s)
        Q, aux = mq(Q, q_base, aux, s)
        if record_hooks:
            hooks, inner = aux
            Da, Db = D1[a], D1[b]
            cond2 = jnp.logical_and(Da == D[a], Db < Da)
            hooks = record_phase(
                hooks, cond2, jnp.where(cond2, Da, n), Db, D1, D2
            )
            aux = (hooks, inner)

        D3, fmask = sv3_hook(D2, Q, s)
        D3, aux = ml(D3, D2, aux, s)
        if record_hooks:
            hooks, inner = aux
            Da3, Db3 = D2[a], D2[b]
            cond3 = (
                (Q[Da3] < s) & (D2[Da3] == Da3) & (Da3 != Db3)
            )
            hooks = record_phase(
                hooks, cond3, jnp.where(cond3, Da3, n), Db3, D2, D3
            )
            aux = (hooks, inner)

        # SV4: short-cut again.
        D4 = D3[D3]

        # SV5: parallel OR "did anything change this round?".
        changed = jnp.any(Q == s)
        if with_frontier:
            if fmask is None:  # kernel path: mask needs its own compare
                fmask = D4[a] != D4[b]
            return D4, Q, aux, s + 1, changed, fmask
        return D4, Q, aux, s + 1, changed

    return round_body


def sv_compress(D: Array, n: int) -> Array:
    """Full path compression so labels are true roots (the paper reads
    D directly; min-hooking can leave 2-level trees on the last round)."""
    comp_iters = max(1, math.ceil(math.log2(max(n, 2))))
    return jax.lax.fori_loop(0, comp_iters, lambda _, d: d[d], D)


def sv_run(
    a: Array,
    b: Array,
    n: int,
    bound: int,
    merge_labels=None,
    merge_stamps=None,
    *,
    hook_impl: str = "xla",
    aux0=None,
    return_aux: bool = False,
    record_hooks: bool = False,
    merge_hooks=None,
):
    """The SV0..SV5 round loop over edge arrays (a, b).

    ``merge_labels`` / ``merge_stamps`` are cross-replica reductions
    ``fn(arr, base, aux, s) -> (arr, aux)`` applied right after each
    min-scatter phase; identity on a single device, pmin/pmax (or the
    sparse frontier exchange) in the sharded engine. ``base`` is the
    replicated pre-scatter array and ``aux`` threads per-round exchange
    stats. Keeping the round body in ONE place is what guarantees the
    engines stay bit-identical -- a min-scatter distributes over
    edge-shard unions, so inserting the merges at these two points
    changes who walks each edge and nothing else.

    Returns ``(D, rounds, converged[, hooks][, aux])``. ``converged``
    is the fixpoint sentinel carried out of the while-loop: True iff
    the loop exited because a round made no change (the final carried
    ``changed`` flag), False iff it exited at ``bound`` with changes
    still flowing -- the case host-driven callers turn into
    ``ConvergenceError`` instead of returning wrong labels.

    ``record_hooks=True`` additionally returns the ``(hook_u, hook_v)``
    winning-hook-edge arrays (see ``init_hooks``; ``merge_hooks`` is
    their cross-replica pmin in the sharded engine) right after
    ``converged``.
    """
    # SV0: D(0)[j] = j, Q[j] = 0
    D0 = jnp.arange(n, dtype=jnp.int32)
    Q0 = jnp.zeros(n, jnp.int32)
    aux = aux0 if aux0 is not None else jnp.int32(0)
    if record_hooks:
        aux = (init_hooks(n), aux)

    round_body = sv_round_fns(
        a, b, n, merge_labels, merge_stamps, hook_impl=hook_impl,
        record_hooks=record_hooks, merge_hooks=merge_hooks,
    )

    def cond(carry):
        _D, _Q, _aux, s, changed = carry
        return jnp.logical_and(changed, s <= bound)

    D, _Q, aux, s, changed = jax.lax.while_loop(
        cond, round_body, (D0, Q0, aux, jnp.int32(1), jnp.bool_(True))
    )
    D = sv_compress(D, n)
    # The loop exits with changed=False at a fixpoint, or changed=True
    # when round `bound` still hooked something -- NOT converged.
    out = (D, s - 1, jnp.logical_not(changed))
    if record_hooks:
        hooks, aux = aux
        out = out + (hooks,)
    if return_aux:
        out = out + (aux,)
    return out


def dedup_edges(
    src: Array | np.ndarray, dst: Array | np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Drop self-loops and duplicate undirected edges (host-side).

    Self-loops can never hook (SV2 needs Db < Da, SV3 Da != Db) and
    duplicates min-hook idempotently, so removing them changes neither
    labels nor round count -- it only shrinks the 2m edge walk.
    """
    e = np.stack(
        [np.asarray(src).ravel(), np.asarray(dst).ravel()], axis=1
    ).astype(np.int64)
    lo, hi = e.min(axis=1), e.max(axis=1)
    keep = lo != hi
    u = np.unique(np.stack([lo[keep], hi[keep]], axis=1), axis=0)
    return u[:, 0].astype(np.int32), u[:, 1].astype(np.int32)


def _maybe_dedup(src, dst, dedup: bool):
    """Dedup host-side (numpy/list) edge inputs; pass device-resident or
    traced arrays through untouched -- dedup is label/round-neutral, so
    skipping it never changes results, and forcing a device-to-host sync
    on every call would dominate hot loops. Device-array callers who
    want the smaller walk dedup once via ``dedup_edges`` up front."""
    host = isinstance(src, (np.ndarray, list, tuple)) and isinstance(
        dst, (np.ndarray, list, tuple)
    )
    if not dedup or not host:
        return src, dst
    return dedup_edges(src, dst)


@partial(
    jax.jit,
    static_argnames=("num_nodes", "bound", "hook_impl", "record_hooks"),
)
def _sv_dense(src, dst, num_nodes, bound, hook_impl, record_hooks=False):
    a = jnp.concatenate([src, dst]).astype(jnp.int32)
    b = jnp.concatenate([dst, src]).astype(jnp.int32)
    return sv_run(
        a, b, num_nodes, bound, hook_impl=hook_impl,
        record_hooks=record_hooks,
    )


def shiloach_vishkin(
    src: Array,
    dst: Array,
    num_nodes: int,
    *,
    max_rounds: int | None = None,
    dedup: bool = True,
    hook_impl: str = "xla",
    record_hooks: bool = False,
):
    """Connected components. Edges are treated as undirected (both
    orientations are processed, matching the paper's 2m edge walk);
    self-loops and duplicate edges in host-side (numpy) inputs are
    dropped up front (``dedup=False`` restores the paper's raw walk for
    work-count experiments; device-resident inputs skip the host sync
    and can be pre-cleaned with ``dedup_edges``).

    Returns (labels, rounds). labels[i] is the component root id.
    ``record_hooks=True`` appends the spanning-forest hook record
    ``(hook_u, hook_v)`` (see ``init_hooks``) without changing labels
    or round counts; ``repro.trees.spanning_forest`` is the consumer.

    Hitting ``max_rounds`` without a fixpoint raises
    ``ConvergenceError`` instead of returning wrong labels (host calls
    only; under a ``jax.jit`` trace the sentinel cannot raise and the
    bounded result is returned as before). The default bound is the
    paper's proven ceiling, so the sentinel only ever fires on an
    explicit too-small ``max_rounds`` or a broken round invariant.
    """
    from repro.compat import is_tracer
    from repro.obs import trace

    n = num_nodes
    check_choice("hook_impl", hook_impl, HOOK_IMPLS)
    bound = max_rounds if max_rounds is not None else sv_round_bound(n)
    src, dst = _maybe_dedup(src, dst, dedup)
    # The whole-run device span blocks on the labels at close -- the
    # same terminal sync the convergence-sentinel read below already
    # pays, so tracing adds no new device round-trip. Under an outer
    # jit trace nothing is registered to block on (tracer values), so
    # the function stays traceable.
    with trace.span("cc.dense", device=True, n=n, bound=bound) as sp:
        out = _sv_dense(
            jnp.asarray(src), jnp.asarray(dst), n, bound, hook_impl,
            record_hooks,
        )
        labels, rounds, converged = out[0], out[1], out[2]
        if not is_tracer(converged):
            sp.block_on(labels)
    if not is_tracer(converged):
        # Intentional terminal sync: the sentinel must be read before
        # wrong labels can escape (docstring above).
        if not bool(converged):  # repro-lint: disable=host-sync
            raise ConvergenceError(
                f"shiloach_vishkin hit max_rounds={bound} before the "
                f"label fixpoint on {n} nodes; raise max_rounds (the "
                f"proven bound is sv_round_bound(n)={sv_round_bound(n)})"
            )
    return (labels, rounds) + out[3:]


@partial(jax.jit, static_argnames=("num_nodes", "max_rounds"))
def label_propagation(
    src: Array, dst: Array, num_nodes: int, *, max_rounds: int | None = None
) -> tuple[Array, Array]:
    """Min-label propagation baseline: O(diameter) rounds, O(m) work/round."""
    n = num_nodes
    bound = max_rounds if max_rounds is not None else n
    a = jnp.concatenate([src, dst]).astype(jnp.int32)
    b = jnp.concatenate([dst, src]).astype(jnp.int32)
    D0 = jnp.arange(n, dtype=jnp.int32)

    def body(carry):
        D, s, _changed = carry
        Dn = D.at[b].min(D[a])
        Dn = Dn[Dn]  # pointer-jump accelerates long chains
        return Dn, s + 1, jnp.any(Dn != D)

    D, s, _ = jax.lax.while_loop(
        lambda c: jnp.logical_and(c[2], c[1] < bound),
        body,
        (D0, jnp.int32(0), jnp.bool_(True)),
    )
    D = sv_compress(D, n)
    return D, s


def num_components(labels: Array | np.ndarray) -> int:
    return int(len(np.unique(np.asarray(labels))))
