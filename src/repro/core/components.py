"""Shiloach-Vishkin connected components on TPU (paper section 4).

The paper's seven CUDA kernels SV0..SV5 (Algorithm 4) become seven
functional phases inside one ``lax.while_loop`` round. Adaptations per
DESIGN.md section 2:

* arbitrary-CRCW concurrent writes -> deterministic **min-CRCW** scatter
  (``.at[].min``). Any arbitrary-write resolution is a valid hook; choosing
  the minimum keeps runs reproducible and still satisfies the paper's
  O(log_{3/2} n) + 2 round bound.
* the SV1a/SV1b kernel split (barrier between short-cutting and marking) is
  structural here: ``D_new`` is a fresh functional value, so the data race
  the paper warns about cannot occur. We keep the phases separate anyway so
  per-phase work counts match Table 4.
* SV5's parallel-OR through racing writes to one word becomes ``jnp.any``.

``label_propagation`` is the simple O(diameter)-round alternative used as a
baseline in benchmarks (it wins on small-diameter random graphs, loses badly
on chains -- the same graph-family sensitivity as the paper's Figure 4).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def sv_round_bound(n: int) -> int:
    """Paper/[14]: at most floor(log_{3/2} n) + 2 rounds."""
    return int(math.floor(math.log(max(n, 2)) / math.log(1.5))) + 2


def sv_run(
    a: Array,
    b: Array,
    n: int,
    bound: int,
    merge_labels=None,
    merge_stamps=None,
) -> tuple[Array, Array]:
    """The SV0..SV5 round loop over edge arrays (a, b).

    ``merge_labels`` / ``merge_stamps`` are cross-replica reductions
    applied right after each min-scatter phase; identity on a single
    device, pmin/pmax in the sharded engine. Keeping the round body in
    ONE place is what guarantees the two engines stay bit-identical --
    a min-scatter distributes over edge-shard unions, so inserting the
    merges at these two points changes who walks each edge and nothing
    else.
    """
    ml = merge_labels if merge_labels is not None else (lambda d: d)
    mq = merge_stamps if merge_stamps is not None else (lambda q: q)

    # SV0: D(0)[j] = j, Q[j] = 0
    D0 = jnp.arange(n, dtype=jnp.int32)
    Q0 = jnp.zeros(n, jnp.int32)

    def round_body(carry):
        D, Q, s, _changed = carry

        # SV1a: short-cut.
        D1 = D[D]
        # SV1b: mark roots whose tree shrank. (Concurrent writes of the same
        # value s -> plain scatter-set with OOB drop for unmarked lanes.)
        mark = D1 != D
        Q = Q.at[jnp.where(mark, D1, n)].set(s, mode="drop")

        # SV2: hook edges from trees that did NOT shrink onto smaller roots.
        Da, Db = D1[a], D1[b]
        stagnant_a = D1[a] == D[a]
        cond2 = jnp.logical_and(stagnant_a, Db < Da)
        tgt2 = jnp.where(cond2, Da, n)
        D2 = D1.at[tgt2].min(jnp.where(cond2, Db, n), mode="drop")
        Q = Q.at[jnp.where(cond2, Db, n)].set(s, mode="drop")
        D2 = ml(D2)
        Q = mq(Q)

        # SV3: hook stagnant roots (no activity this round) onto any
        # neighboring tree, breaking label-order ties via min-CRCW.
        Da3, Db3 = D2[a], D2[b]
        root_a = D2[Da3] == Da3
        stagnant = Q[Da3] < s
        cond3 = stagnant & root_a & (Da3 != Db3)
        tgt3 = jnp.where(cond3, Da3, n)
        D3 = D2.at[tgt3].min(jnp.where(cond3, Db3, n), mode="drop")
        D3 = ml(D3)

        # SV4: short-cut again.
        D4 = D3[D3]

        # SV5: parallel OR "did anything change this round?".
        changed = jnp.any(Q == s)
        return D4, Q, s + 1, changed

    def cond(carry):
        _D, _Q, s, changed = carry
        return jnp.logical_and(changed, s <= bound)

    D, Q, s, _ = jax.lax.while_loop(
        cond, round_body, (D0, Q0, jnp.int32(1), jnp.bool_(True))
    )

    # Final full path compression so labels are true roots (the paper reads
    # D directly; min-hooking can leave 2-level trees on the last round).
    comp_iters = max(1, math.ceil(math.log2(max(n, 2))))
    D = jax.lax.fori_loop(0, comp_iters, lambda _, d: d[d], D)
    return D, s - 1


@partial(jax.jit, static_argnames=("num_nodes", "max_rounds"))
def shiloach_vishkin(
    src: Array, dst: Array, num_nodes: int, *, max_rounds: int | None = None
) -> tuple[Array, Array]:
    """Connected components. Edges are treated as undirected (both
    orientations are processed, matching the paper's 2m edge walk).

    Returns (labels, rounds). labels[i] is the component root id.
    """
    n = num_nodes
    bound = max_rounds if max_rounds is not None else sv_round_bound(n)
    a = jnp.concatenate([src, dst]).astype(jnp.int32)
    b = jnp.concatenate([dst, src]).astype(jnp.int32)
    return sv_run(a, b, n, bound)


@partial(jax.jit, static_argnames=("num_nodes", "max_rounds"))
def label_propagation(
    src: Array, dst: Array, num_nodes: int, *, max_rounds: int | None = None
) -> tuple[Array, Array]:
    """Min-label propagation baseline: O(diameter) rounds, O(m) work/round."""
    n = num_nodes
    bound = max_rounds if max_rounds is not None else n
    a = jnp.concatenate([src, dst]).astype(jnp.int32)
    b = jnp.concatenate([dst, src]).astype(jnp.int32)
    D0 = jnp.arange(n, dtype=jnp.int32)

    def body(carry):
        D, s, _changed = carry
        Dn = D.at[b].min(D[a])
        Dn = Dn[Dn]  # pointer-jump accelerates long chains
        return Dn, s + 1, jnp.any(Dn != D)

    D, s, _ = jax.lax.while_loop(
        lambda c: jnp.logical_and(c[2], c[1] < bound),
        body,
        (D0, jnp.int32(0), jnp.bool_(True)),
    )
    comp_iters = max(1, math.ceil(math.log2(max(n, 2))))
    D = jax.lax.fori_loop(0, comp_iters, lambda _, d: d[d], D)
    return D, s


def num_components(labels: Array | np.ndarray) -> int:
    return int(len(np.unique(np.asarray(labels))))
