"""The paper's contribution: PRAM graph algorithms adapted for TPU."""
from repro.core.list_ranking import (
    wylie_rank,
    random_splitter_rank,
    select_splitters,
    even_splitters,
    max_splitters_for_linear_work,
    SplitterStats,
)
from repro.core.components import (
    shiloach_vishkin,
    label_propagation,
    sv_round_bound,
    num_components,
    dedup_edges,
    check_choice,
    ConvergenceError,
)
from repro.core.frontier import frontier_shiloach_vishkin, FrontierStats
from repro.core.sssp import (
    SSSP_ENGINES,
    SsspStats,
    bellman_ford,
    frontier_bellman_ford,
    shortest_paths,
    sssp_round_bound,
)
from repro.core.pagerank import (
    PAGERANK_ENGINES,
    PageRankStats,
    pagerank,
    pagerank_iter_bound,
)
from repro.core.pram import (
    striding_indices,
    partitioning_indices,
    strided_view,
    partitioned_view,
    lockstep_walk,
)


# Engine-specific tuning knobs: naming one pins the dispatch to that
# engine (regardless of device count), so the same call behaves
# identically on any machine -- the list_rank pack_mode convention.
# The sampling pre-pass (sample_rounds/seed) exists only on the
# single-device frontier engine; min_bucket and hook_impl are honoured
# by BOTH frontier engines (single-device and sharded), so with a mesh
# they steer toward engine="sharded_frontier" instead of raising.
_SAMPLING_KW = frozenset({"sample_rounds", "seed"})
_FRONTIER_KW = _SAMPLING_KW | {"min_bucket"}
_SINGLE_KW = _FRONTIER_KW | {"hook_impl"}
_SHARDED_KW = frozenset({"exchange", "sparse_capacity", "axis"})
_CC_ENGINES = ("auto", "frontier", "dense", "sharded_frontier")

# Sampling policy (ROADMAP decision, PR 3): when the auto dispatch
# lands on the frontier engine and the graph is edge-heavy -- at least
# AUTO_SAMPLE_DENSITY input edges per node -- the Afforest-style
# pre-pass is enabled automatically with AUTO_SAMPLE_ROUNDS rounds: on
# dense graphs the giant component(s) resolve at O(n)/round and the
# first compaction drops most of the edge walk, while the labels remain
# a correct partition (representatives may differ from the dense
# engine's -- the reason the pre-pass stays off for sparse graphs and
# for explicit ``engine=``). Pass ``sample_rounds=0`` (or any explicit
# value) to override, or ``engine="frontier"``/``"dense"`` to pin the
# exact dense-engine representatives.
AUTO_SAMPLE_DENSITY = 8.0
AUTO_SAMPLE_ROUNDS = 2


def _auto_sample_rounds(src, num_nodes):
    """Afforest pre-pass rounds for the auto dispatch: 0 unless the
    input is host-visible and edge-heavy (m/n >= AUTO_SAMPLE_DENSITY)."""
    shape = getattr(src, "shape", None)
    if shape is not None:
        m = shape[0] if len(shape) else 0
    else:
        m = len(src) if hasattr(src, "__len__") else 0
    if num_nodes > 0 and m / num_nodes >= AUTO_SAMPLE_DENSITY:
        return AUTO_SAMPLE_ROUNDS
    return 0


def connected_components(
    src, dst, num_nodes, *, max_rounds=None, mesh=None, engine="auto", **kwargs
):
    """Connected components with automatic engine dispatch.

    Returns ``(labels, rounds)`` -- identical on every path --
    ``labels[i]`` being the component root id. The full engine matrix
    (valid values, defaults, auto rules, exactness guarantees) lives in
    ``docs/engines.md``; summary:

    ``engine=`` -- one of ``"auto"`` (default), ``"frontier"``,
    ``"dense"``, ``"sharded_frontier"``:

    * ``"auto"``: an explicit ``mesh=`` picks the **sharded frontier**
      engine (each device compacts its own edge shard between rounds);
      otherwise one visible device runs the single-device
      frontier-compacted engine (``repro.core.frontier``) and several
      visible devices the edge-partitioned sharded engine
      (``repro.distributed.graph``). The two frontier engines' level
      loops are host-driven, so inside a ``jax.jit`` trace auto falls
      back to the fully-traceable dense walks.
    * ``"frontier"``: pin the single-device frontier engine (rejects
      ``mesh=``).
    * ``"dense"``: the all-edges-every-round escape hatch (single
      device: ``sv_run``; with a mesh or several devices: the sharded
      engine, which IS the dense walk).
    * ``"sharded_frontier"``: pin the per-shard frontier engine
      (``mesh=`` optional -- defaults to all visible devices).

    Engine kwargs (each steers the auto dispatch toward an engine that
    honours it; every string is validated against the sets in
    ``docs/engines.md``):

    * ``sample_rounds=`` (int, default 0) / ``seed=`` (int, default 0)
      -- the Afforest-style sampling pre-pass; single-device frontier
      engine only.
    * ``min_bucket=`` (int, default 1024) -- smallest frontier bucket;
      both frontier engines (per-device in the sharded one).
    * ``hook_impl=`` -- ``"xla"`` (default), ``"auto"``, ``"pallas"``,
      ``"pallas_interpret"``: the SV2/SV3 hook-phase implementation
      (``kernels/edge_hook``); dense, frontier, and sharded-frontier
      engines (shard-local in the latter).
    * ``exchange=`` -- ``"dense"`` or ``"sparse"``: the cross-device
      label exchange; sharded engines only. Defaults: ``"dense"`` on
      the dense sharded engine, ``"sparse"`` on the sharded frontier
      engine. ``sparse_capacity=`` (int, default: frontier-sized with
      an ``n/8`` cap) bounds the per-device (index, label) buffer.
    * ``axis=`` (str, default ``"graph"``) -- mesh axis name carrying
      the edge partition; sharded engines only.
    * ``dedup=`` (bool, default True), ``record_hooks=`` (bool, default
      False), ``with_stats=`` (bool, default False) -- every engine;
      ``record_hooks`` appends the spanning-forest hook record (see
      ``repro.trees``) without changing labels or rounds.

    On the auto path, edge-heavy graphs (>= ``AUTO_SAMPLE_DENSITY``
    input edges per node) reaching the single-device frontier engine
    enable the sampling pre-pass automatically (``AUTO_SAMPLE_ROUNDS``
    rounds): labels stay a correct partition but representatives may
    differ from the dense engine's; pass ``sample_rounds=`` explicitly
    (0 disables) or pin ``engine=`` to opt out. Every other
    engine/kwarg combination is bit-exact in labels, round counts, and
    recorded hook forests against every other.
    """
    import jax

    from repro.compat import is_tracer

    check_choice("engine", engine, _CC_ENGINES)
    single_kw = _SINGLE_KW & kwargs.keys()
    sharded_kw = _SHARDED_KW & kwargs.keys()
    sampling_kw = _SAMPLING_KW & kwargs.keys()
    tracing = is_tracer(src) or is_tracer(dst)
    if sampling_kw and (
        sharded_kw or mesh is not None or engine == "sharded_frontier"
    ):
        trigger = (
            sorted(sharded_kw) if sharded_kw
            else "mesh=" if mesh is not None
            else "engine='sharded_frontier'"
        )
        raise ValueError(
            f"{sorted(sampling_kw)} are single-device frontier options "
            "(the sampling pre-pass has no sharded counterpart); drop "
            f"them or drop {trigger}"
        )
    if engine == "auto":
        if mesh is not None:
            # The sharded-frontier auto rule: an explicit mesh gets the
            # composed per-shard frontier engine. Its level loop is
            # host-driven, so a jit trace falls back to the traceable
            # dense sharded walk (which rejects the frontier knobs).
            engine = "_sharded" if tracing else "sharded_frontier"
        elif _FRONTIER_KW & kwargs.keys() and not sharded_kw:
            engine = "frontier"
        elif single_kw and not sharded_kw:
            # hook_impl alone: dense sv_run honours it too and is fully
            # traceable, so a jit trace falls back there
            engine = "dense" if tracing else "frontier"
        elif sharded_kw:
            # bucket/hook knobs + exchange knobs only meet in the
            # composed engine (default mesh over all visible devices)
            engine = (
                "sharded_frontier" if (single_kw and not tracing)
                else "_sharded"
            )
        elif jax.device_count() > 1:
            engine = "_sharded"
        else:
            engine = "dense" if tracing else "frontier"
        if engine == "frontier" and "sample_rounds" not in kwargs:
            auto_k = _auto_sample_rounds(src, num_nodes)
            if auto_k:
                kwargs["sample_rounds"] = auto_k
    if engine == "frontier":
        if sharded_kw:
            raise ValueError(
                f"{sorted(sharded_kw)} are sharded-engine options; drop "
                "them or use engine='auto'/'sharded_frontier'"
            )
        if mesh is not None:
            raise ValueError(
                "the frontier engine is single-device; drop mesh= or use "
                "engine='auto'/'sharded_frontier'"
            )
        if tracing:
            raise ValueError(
                "the frontier engine's shrink loop is host-driven and "
                "cannot run inside jit; call it outside jit or use "
                "engine='dense'"
            )
        return frontier_shiloach_vishkin(
            src, dst, num_nodes, max_rounds=max_rounds, **kwargs
        )
    if engine == "sharded_frontier":
        if tracing:
            raise ValueError(
                "the sharded frontier engine's level loop is host-driven "
                "and cannot run inside jit; call it outside jit or use "
                "engine='dense'"
            )
        from repro.distributed.graph import sharded_frontier_shiloach_vishkin

        return sharded_frontier_shiloach_vishkin(
            src, dst, num_nodes, mesh=mesh, max_rounds=max_rounds, **kwargs
        )
    if engine == "dense":
        fkw = _FRONTIER_KW & kwargs.keys()
        if fkw:
            raise ValueError(
                f"{sorted(fkw)} are frontier-engine options; use "
                "engine='frontier' or engine='sharded_frontier'"
            )
        if single_kw and (mesh is not None or sharded_kw):
            # only hook_impl can land here: the dense sharded engine has
            # no kernel hook path
            raise ValueError(
                f"{sorted(single_kw)} with a mesh needs "
                "engine='sharded_frontier' (the dense sharded engine "
                "walks every edge through plain XLA scatters)"
            )
        if single_kw or (mesh is None and not sharded_kw
                         and jax.device_count() == 1):
            # hook_impl pins the single-device sv_run loop on any machine
            return shiloach_vishkin(
                src, dst, num_nodes, max_rounds=max_rounds, **kwargs
            )
    elif single_kw:  # engine == "_sharded" off the auto path
        raise ValueError(
            f"{sorted(single_kw)} cannot run inside jit with a mesh: the "
            "frontier level loop is host-driven; call outside jit or "
            "drop them"
        )
    # multi-device (or sharded knobs): the sharded engine IS the dense walk
    from repro.distributed.graph import sharded_shiloach_vishkin

    return sharded_shiloach_vishkin(
        src, dst, num_nodes, mesh=mesh, max_rounds=max_rounds, **kwargs
    )


_SINGLE_ENGINE_KW = frozenset({"pack_mode"})


def list_rank(succ, num_splitters=None, *, mesh=None, **kwargs):
    """List ranking with automatic engine dispatch: the random-splitter
    engine on one device, its edge-partitioned sharded counterpart when
    a ``mesh=`` is given or several devices are visible. Returns the
    exact integer ranks (bit-identical on every path). The full matrix
    lives in ``docs/engines.md``; keywords:

    * ``num_splitters=`` (int, default: ``min(4096,
      max_splitters_for_linear_work(n))``) -- RS1 splitter count.
    * ``kernel_impl=`` -- ``"auto"`` (default), ``"xla"``, ``"pallas"``,
      ``"pallas_interpret"``: routes the RS4/RS5 phases through the
      Pallas kernels; honoured by BOTH engines ("auto" compiles them on
      real TPUs and keeps plain XLA elsewhere).
    * ``pack_mode=`` -- ``"aos"`` (default), ``"soa"``, ``"word64"``:
      single-device walk-state packing (Table 2); when given without a
      mesh it pins the single-device engine on any machine, combining
      it WITH a mesh raises.
    * ``splitters=``/``seed=``/``head=``/``max_steps=``/``with_stats=``
      -- forwarded to the chosen engine unchanged (same KISS streams on
      both, so default splitter selection agrees bit-exactly).

    Unknown dispatch strings raise naming the valid choices.
    """
    import jax

    from repro.core.list_ranking import KERNEL_IMPLS, PACK_MODES

    if "kernel_impl" in kwargs:
        check_choice("kernel_impl", kwargs["kernel_impl"], KERNEL_IMPLS)
    if "pack_mode" in kwargs:
        check_choice("pack_mode", kwargs["pack_mode"], PACK_MODES)
    single_only = _SINGLE_ENGINE_KW & kwargs.keys()
    if mesh is not None or (jax.device_count() > 1 and not single_only):
        if single_only:
            raise ValueError(
                f"{sorted(single_only)} are single-device options; drop "
                "them or drop mesh="
            )
        from repro.distributed.graph import sharded_random_splitter_rank

        return sharded_random_splitter_rank(
            succ, num_splitters, mesh=mesh, **kwargs
        )
    return random_splitter_rank(succ, num_splitters, **kwargs)


def spanning_forest(src, dst, num_nodes, **kwargs):
    """Spanning forest from CC hook decisions -- see
    ``repro.trees.spanning_forest`` (engine dispatch as above)."""
    from repro.trees import spanning_forest as _sf

    return _sf(src, dst, num_nodes, **kwargs)


def euler_tour(edge_u, edge_v, num_nodes, **kwargs):
    """Euler tour of a spanning forest -- see ``repro.trees.euler_tour``;
    the returned tour's ``succ`` feeds ``list_rank``/``wylie_rank``."""
    from repro.trees import euler_tour as _et

    return _et(edge_u, edge_v, num_nodes, **kwargs)


def root_tree(tour, **kwargs):
    """Parent array of a toured forest -- see ``repro.trees.root_tree``;
    ``rank_engine=``/``kernel_impl=``/``mesh=`` dispatch the underlying
    list ranking exactly like ``list_rank``."""
    from repro.trees import root_tree as _rt

    return _rt(tour, **kwargs)


def tree_analytics(src, dst, num_nodes, **kwargs):
    """One-shot graph -> forest -> tour -> tree computations pipeline --
    see ``repro.trees.tree_analytics``."""
    from repro.trees import tree_analytics as _ta

    return _ta(src, dst, num_nodes, **kwargs)


def serve_graphs(requests, **kwargs):
    """Serve many small graph requests wave-batched: one padded
    disjoint-union engine call per wave, bit-exact vs issuing each
    request alone -- see ``repro.serve.graph.GraphServeEngine``.

    ``requests`` is an iterable of ``repro.serve.GraphRequest``;
    ``kwargs`` are the engine knobs (``engine=`` / ``rank_engine=`` /
    ``kernel_impl=`` / ``mesh=`` dispatch exactly as in the functions
    above, plus the wave/bucket capacity knobs -- full matrix in
    ``docs/engines.md`` and ``docs/serving.md``). Returns the finished
    requests with ``result`` populated, in completion order.
    """
    from repro.serve.graph import GraphServeEngine

    eng = GraphServeEngine(**kwargs)
    for r in requests:
        eng.submit(r)
    return eng.run()


__all__ = [
    "connected_components",
    "list_rank",
    "spanning_forest",
    "euler_tour",
    "root_tree",
    "tree_analytics",
    "serve_graphs",
    "check_choice",
    "wylie_rank",
    "random_splitter_rank",
    "select_splitters",
    "even_splitters",
    "max_splitters_for_linear_work",
    "SplitterStats",
    "shiloach_vishkin",
    "frontier_shiloach_vishkin",
    "FrontierStats",
    "shortest_paths",
    "bellman_ford",
    "frontier_bellman_ford",
    "SsspStats",
    "SSSP_ENGINES",
    "sssp_round_bound",
    "pagerank",
    "pagerank_iter_bound",
    "PageRankStats",
    "PAGERANK_ENGINES",
    "label_propagation",
    "sv_round_bound",
    "ConvergenceError",
    "num_components",
    "dedup_edges",
    "striding_indices",
    "partitioning_indices",
    "strided_view",
    "partitioned_view",
    "lockstep_walk",
]
