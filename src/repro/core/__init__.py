"""The paper's contribution: PRAM graph algorithms adapted for TPU."""
from repro.core.list_ranking import (
    wylie_rank,
    random_splitter_rank,
    select_splitters,
    even_splitters,
    max_splitters_for_linear_work,
    SplitterStats,
)
from repro.core.connected_components import (
    shiloach_vishkin,
    label_propagation,
    sv_round_bound,
    num_components,
)
from repro.core.pram import (
    striding_indices,
    partitioning_indices,
    strided_view,
    partitioned_view,
    lockstep_walk,
)

__all__ = [
    "wylie_rank",
    "random_splitter_rank",
    "select_splitters",
    "even_splitters",
    "max_splitters_for_linear_work",
    "SplitterStats",
    "shiloach_vishkin",
    "label_propagation",
    "sv_round_bound",
    "num_components",
    "striding_indices",
    "partitioning_indices",
    "strided_view",
    "partitioned_view",
    "lockstep_walk",
]
