"""The paper's contribution: PRAM graph algorithms adapted for TPU."""
from repro.core.list_ranking import (
    wylie_rank,
    random_splitter_rank,
    select_splitters,
    even_splitters,
    max_splitters_for_linear_work,
    SplitterStats,
)
from repro.core.components import (
    shiloach_vishkin,
    label_propagation,
    sv_round_bound,
    num_components,
    dedup_edges,
    check_choice,
)
from repro.core.frontier import frontier_shiloach_vishkin, FrontierStats
from repro.core.pram import (
    striding_indices,
    partitioning_indices,
    strided_view,
    partitioned_view,
    lockstep_walk,
)


# Engine-specific tuning knobs: naming one pins the dispatch to that
# engine (regardless of device count), so the same call behaves
# identically on any machine -- the list_rank pack_mode convention.
# hook_impl is shared by the two single-device engines (dense sv_run and
# frontier), so it pins "single-device" rather than "frontier".
_FRONTIER_KW = frozenset({"sample_rounds", "min_bucket", "seed"})
_SINGLE_KW = _FRONTIER_KW | {"hook_impl"}
_SHARDED_KW = frozenset({"exchange", "sparse_capacity", "axis"})
_CC_ENGINES = ("auto", "frontier", "dense")

# Sampling policy (ROADMAP decision, PR 3): when the auto dispatch
# lands on the frontier engine and the graph is edge-heavy -- at least
# AUTO_SAMPLE_DENSITY input edges per node -- the Afforest-style
# pre-pass is enabled automatically with AUTO_SAMPLE_ROUNDS rounds: on
# dense graphs the giant component(s) resolve at O(n)/round and the
# first compaction drops most of the edge walk, while the labels remain
# a correct partition (representatives may differ from the dense
# engine's -- the reason the pre-pass stays off for sparse graphs and
# for explicit ``engine=``). Pass ``sample_rounds=0`` (or any explicit
# value) to override, or ``engine="frontier"``/``"dense"`` to pin the
# exact dense-engine representatives.
AUTO_SAMPLE_DENSITY = 8.0
AUTO_SAMPLE_ROUNDS = 2


def _auto_sample_rounds(src, num_nodes):
    """Afforest pre-pass rounds for the auto dispatch: 0 unless the
    input is host-visible and edge-heavy (m/n >= AUTO_SAMPLE_DENSITY)."""
    shape = getattr(src, "shape", None)
    if shape is not None:
        m = shape[0] if len(shape) else 0
    else:
        m = len(src) if hasattr(src, "__len__") else 0
    if num_nodes > 0 and m / num_nodes >= AUTO_SAMPLE_DENSITY:
        return AUTO_SAMPLE_ROUNDS
    return 0


def connected_components(
    src, dst, num_nodes, *, max_rounds=None, mesh=None, engine="auto", **kwargs
):
    """Connected components with automatic engine dispatch.

    Routes to the edge-partitioned multi-device engine
    (``repro.distributed.graph``) when a mesh is given or more than one
    device is visible; otherwise runs the **frontier-compacted** engine
    (``repro.core.frontier``), the single-device fast path. All paths
    return identical (labels, rounds). ``engine="dense"`` is the escape
    hatch back to the all-edges-every-round walk (single device:
    ``sv_run``; with a mesh or several devices: the sharded engine,
    which IS the dense walk). ``engine="frontier"`` forces the frontier
    engine even when several devices are visible, but rejects an
    explicit ``mesh=`` (no sharded frontier yet).

    Extra kwargs go to the chosen engine and steer the auto dispatch:
    frontier knobs (e.g. ``sample_rounds=2`` for the Afforest pre-pass)
    pick the frontier engine on any machine, sharded knobs (e.g.
    ``exchange="sparse"``) the sharded engine; mixing the two raises.
    The frontier engine's shrink loop is host-driven, so inside a
    ``jax.jit`` trace the auto path falls back to the (fully traceable)
    dense ``sv_run`` loop.

    On the auto path, edge-heavy graphs (>= ``AUTO_SAMPLE_DENSITY``
    input edges per node) enable the Afforest sampling pre-pass
    automatically (``AUTO_SAMPLE_ROUNDS`` rounds): labels stay a correct
    partition but representatives may differ from the dense engine's;
    pass ``sample_rounds=`` explicitly (0 disables) or pin ``engine=``
    to opt out. ``record_hooks=True`` works on every engine and appends
    the spanning-forest hook record (see ``repro.trees``).
    """
    import jax

    from repro.compat import is_tracer

    check_choice("engine", engine, _CC_ENGINES)
    single_kw = _SINGLE_KW & kwargs.keys()
    sharded_kw = _SHARDED_KW & kwargs.keys()
    if single_kw and (sharded_kw or mesh is not None):
        raise ValueError(
            f"{sorted(single_kw)} are single-device options; drop them or "
            f"drop {sorted(sharded_kw) or 'mesh='}"
        )
    tracing = is_tracer(src) or is_tracer(dst)
    if engine == "auto":
        if _FRONTIER_KW & kwargs.keys():
            engine = "frontier"
        elif single_kw:
            # hook_impl alone: dense sv_run honours it too and is fully
            # traceable, so a jit trace falls back there
            engine = "dense" if tracing else "frontier"
        elif mesh is not None or sharded_kw or jax.device_count() > 1:
            engine = "_sharded"
        else:
            engine = "dense" if tracing else "frontier"
        if engine == "frontier" and "sample_rounds" not in kwargs:
            auto_k = _auto_sample_rounds(src, num_nodes)
            if auto_k:
                kwargs["sample_rounds"] = auto_k
    if engine == "frontier":
        if sharded_kw:
            raise ValueError(
                f"{sorted(sharded_kw)} are sharded-engine options; drop "
                "them or use engine='auto'"
            )
        if mesh is not None:
            raise ValueError(
                "the frontier engine is single-device; drop mesh= or use "
                "engine='auto'/'dense'"
            )
        if tracing:
            raise ValueError(
                "the frontier engine's shrink loop is host-driven and "
                "cannot run inside jit; call it outside jit or use "
                "engine='dense'"
            )
        return frontier_shiloach_vishkin(
            src, dst, num_nodes, max_rounds=max_rounds, **kwargs
        )
    if engine == "dense":
        fkw = _FRONTIER_KW & kwargs.keys()
        if fkw:
            raise ValueError(
                f"{sorted(fkw)} are frontier-engine options; use "
                "engine='frontier'"
            )
        if single_kw or (mesh is None and not sharded_kw
                         and jax.device_count() == 1):
            # hook_impl pins the single-device sv_run loop on any machine
            return shiloach_vishkin(
                src, dst, num_nodes, max_rounds=max_rounds, **kwargs
            )
    # multi-device (or sharded knobs): the sharded engine IS the dense walk
    from repro.distributed.graph import sharded_shiloach_vishkin

    return sharded_shiloach_vishkin(
        src, dst, num_nodes, mesh=mesh, max_rounds=max_rounds, **kwargs
    )


_SINGLE_ENGINE_KW = frozenset({"pack_mode"})


def list_rank(succ, num_splitters=None, *, mesh=None, **kwargs):
    """List ranking with automatic engine dispatch (see
    ``connected_components``).

    ``pack_mode`` is a single-device tuning knob: when given (without an
    explicit mesh) the single-device engine runs regardless of device
    count, so the same call behaves identically on any machine;
    combining it WITH a mesh raises. ``kernel_impl`` is honoured by BOTH
    engines (the sharded engine routes its RS4/RS5 phases through the
    same Pallas kernels); unknown strings raise naming the choices.
    """
    import jax

    from repro.core.list_ranking import KERNEL_IMPLS, PACK_MODES

    if "kernel_impl" in kwargs:
        check_choice("kernel_impl", kwargs["kernel_impl"], KERNEL_IMPLS)
    if "pack_mode" in kwargs:
        check_choice("pack_mode", kwargs["pack_mode"], PACK_MODES)
    single_only = _SINGLE_ENGINE_KW & kwargs.keys()
    if mesh is not None or (jax.device_count() > 1 and not single_only):
        if single_only:
            raise ValueError(
                f"{sorted(single_only)} are single-device options; drop "
                "them or drop mesh="
            )
        from repro.distributed.graph import sharded_random_splitter_rank

        return sharded_random_splitter_rank(
            succ, num_splitters, mesh=mesh, **kwargs
        )
    return random_splitter_rank(succ, num_splitters, **kwargs)


def spanning_forest(src, dst, num_nodes, **kwargs):
    """Spanning forest from CC hook decisions -- see
    ``repro.trees.spanning_forest`` (engine dispatch as above)."""
    from repro.trees import spanning_forest as _sf

    return _sf(src, dst, num_nodes, **kwargs)


def euler_tour(edge_u, edge_v, num_nodes, **kwargs):
    """Euler tour of a spanning forest -- see ``repro.trees.euler_tour``;
    the returned tour's ``succ`` feeds ``list_rank``/``wylie_rank``."""
    from repro.trees import euler_tour as _et

    return _et(edge_u, edge_v, num_nodes, **kwargs)


def root_tree(tour, **kwargs):
    """Parent array of a toured forest -- see ``repro.trees.root_tree``;
    ``rank_engine=``/``kernel_impl=``/``mesh=`` dispatch the underlying
    list ranking exactly like ``list_rank``."""
    from repro.trees import root_tree as _rt

    return _rt(tour, **kwargs)


def tree_analytics(src, dst, num_nodes, **kwargs):
    """One-shot graph -> forest -> tour -> tree computations pipeline --
    see ``repro.trees.tree_analytics``."""
    from repro.trees import tree_analytics as _ta

    return _ta(src, dst, num_nodes, **kwargs)


__all__ = [
    "connected_components",
    "list_rank",
    "spanning_forest",
    "euler_tour",
    "root_tree",
    "tree_analytics",
    "check_choice",
    "wylie_rank",
    "random_splitter_rank",
    "select_splitters",
    "even_splitters",
    "max_splitters_for_linear_work",
    "SplitterStats",
    "shiloach_vishkin",
    "frontier_shiloach_vishkin",
    "FrontierStats",
    "label_propagation",
    "sv_round_bound",
    "num_components",
    "dedup_edges",
    "striding_indices",
    "partitioning_indices",
    "strided_view",
    "partitioned_view",
    "lockstep_walk",
]
