"""The paper's contribution: PRAM graph algorithms adapted for TPU."""
from repro.core.list_ranking import (
    wylie_rank,
    random_splitter_rank,
    select_splitters,
    even_splitters,
    max_splitters_for_linear_work,
    SplitterStats,
)
from repro.core.components import (
    shiloach_vishkin,
    label_propagation,
    sv_round_bound,
    num_components,
)
from repro.core.pram import (
    striding_indices,
    partitioning_indices,
    strided_view,
    partitioned_view,
    lockstep_walk,
)


def connected_components(src, dst, num_nodes, *, max_rounds=None, mesh=None):
    """Connected components with automatic engine dispatch.

    Routes to the edge-partitioned multi-device engine
    (``repro.distributed.graph``) when a mesh is given or more than one
    device is visible; otherwise runs the single-device kernel. Both
    paths return identical (labels, rounds).
    """
    import jax

    if mesh is not None or jax.device_count() > 1:
        from repro.distributed.graph import sharded_shiloach_vishkin

        return sharded_shiloach_vishkin(
            src, dst, num_nodes, mesh=mesh, max_rounds=max_rounds
        )
    return shiloach_vishkin(src, dst, num_nodes, max_rounds=max_rounds)


_SINGLE_ENGINE_KW = frozenset({"pack_mode", "kernel_impl"})


def list_rank(succ, num_splitters=None, *, mesh=None, **kwargs):
    """List ranking with automatic engine dispatch (see
    ``connected_components``).

    ``pack_mode`` / ``kernel_impl`` are single-device tuning knobs: when
    given (without an explicit mesh) the single-device engine runs
    regardless of device count, so the same call behaves identically on
    any machine; combining them WITH a mesh raises.
    """
    import jax

    single_only = _SINGLE_ENGINE_KW & kwargs.keys()
    if mesh is not None or (jax.device_count() > 1 and not single_only):
        if single_only:
            raise ValueError(
                f"{sorted(single_only)} are single-device options; drop "
                "them or drop mesh="
            )
        from repro.distributed.graph import sharded_random_splitter_rank

        return sharded_random_splitter_rank(
            succ, num_splitters, mesh=mesh, **kwargs
        )
    return random_splitter_rank(succ, num_splitters, **kwargs)


__all__ = [
    "connected_components",
    "list_rank",
    "wylie_rank",
    "random_splitter_rank",
    "select_splitters",
    "even_splitters",
    "max_splitters_for_linear_work",
    "SplitterStats",
    "shiloach_vishkin",
    "label_propagation",
    "sv_round_bound",
    "num_components",
    "striding_indices",
    "partitioning_indices",
    "strided_view",
    "partitioned_view",
    "lockstep_walk",
]
