"""Per-phase summary table for an exported Chrome trace.

    python -m repro.obs.summarize trace.json
    python -m repro.obs.summarize trace.json --require serve.wave

Reads the ``{"traceEvents": [...]}`` JSON written by
``repro.obs.trace.export_chrome`` (a bare event list also works),
aggregates the complete events (``ph="X"``) by span name, and prints
count / total / mean / max wall time per phase, widest total first --
the quick answer to "where did the time go" without opening Perfetto.

``--require SUBSTR`` (repeatable) exits nonzero unless at least one
complete event's name contains the substring: CI's traced-smoke step
uses it to assert the serve lifecycle spans (wave, retry, bisection
probe) actually appeared in the trace.

Pure stdlib -- no jax, no repro imports -- so it runs anywhere the
JSON does.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_events(path: str) -> list[dict]:
    """The event list from a Chrome-trace JSON file (object or list)."""
    with open(path) as f:
        payload = json.load(f)
    events = payload.get("traceEvents") if isinstance(payload, dict) else payload
    if not isinstance(events, list):
        raise ValueError(f"{path}: not a Chrome trace (no traceEvents list)")
    return events


def summarize(events: list[dict]) -> list[tuple]:
    """[(name, count, total_us, mean_us, max_us)] sorted by total desc."""
    agg: dict[str, list[float]] = {}
    for ev in events:
        if ev.get("ph") != "X":
            continue
        dur = float(ev.get("dur", 0.0))
        row = agg.setdefault(ev["name"], [0, 0.0, 0.0])
        row[0] += 1
        row[1] += dur
        row[2] = max(row[2], dur)
    return sorted(
        (
            (name, int(cnt), total, total / cnt, mx)
            for name, (cnt, total, mx) in agg.items()
        ),
        key=lambda r: -r[2],
    )


def format_table(rows: list[tuple]) -> str:
    if not rows:
        return "(no complete spans in trace)"
    w = max(len(r[0]) for r in rows)
    lines = [
        f"{'span':<{w}}  {'count':>7}  {'total_ms':>10}  "
        f"{'mean_us':>10}  {'max_us':>10}"
    ]
    for name, cnt, total, mean, mx in rows:
        lines.append(
            f"{name:<{w}}  {cnt:>7}  {total / 1e3:>10.3f}  "
            f"{mean:>10.1f}  {mx:>10.1f}"
        )
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.summarize", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("trace", help="Chrome-trace JSON from trace.export_chrome")
    ap.add_argument(
        "--require", action="append", default=[], metavar="SUBSTR",
        help="fail unless a complete span name contains SUBSTR "
             "(repeatable; CI's traced-smoke assertion)",
    )
    args = ap.parse_args(argv)

    events = load_events(args.trace)
    rows = summarize(events)
    print(format_table(rows))
    n_inst = sum(1 for ev in events if ev.get("ph") == "i")
    print(f"# {len(rows)} phases, {sum(r[1] for r in rows)} spans, "
          f"{n_inst} instant events")
    missing = [
        s for s in args.require if not any(s in r[0] for r in rows)
    ]
    if missing:
        print(f"# REQUIRE FAIL: no span matching {missing}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
