"""Host-side span tracer for the level-synchronous engines.

The repo's engines are host-driven by design: a level loop (frontier
buckets), an exchange round, or a serve wave runs on device, the host
syncs once to read the live count / convergence flag / unpacked
results, and decides the next compiled shape. Those syncs are exactly
the timeline the ROADMAP wants to see (it suspects per-level host
round-trips dominate small-n frontier wall-clock) -- so this tracer
attaches spans ONLY at boundaries that already sync and never adds a
device->host read of its own (RL001 stays clean by construction).

Usage::

    from repro.obs import trace

    trace.configure(trace="on")            # or REPRO_TRACE=1
    with trace.span("cc.frontier.level", bucket=4096) as sp:
        ...                                # host-driven work
        sp.tag(rounds=int(rounds))         # values the host ALREADY read
    trace.event("serve.quarantine", uid=7) # instant marker
    trace.export_chrome("trace.json")      # Chrome/Perfetto timeline

* **Disabled is free.** ``span()`` returns one shared ``_NULL_SPAN``
  singleton when tracing is off -- no allocation, no clock read, no
  list append -- so instrumented hot loops cost nothing by default.
* **Device spans.** ``span(..., device=True)`` calls
  ``jax.block_until_ready`` at close on the value registered via
  ``sp.block_on(x)`` -- the RL006 block-timer discipline, applied at
  close so the span's duration covers the device work it launched.
  Tracer values pass through ``block_until_ready`` untouched, so
  instrumented functions stay safely traceable under ``jax.jit``.
* **Timer spans.** ``span(..., timer=True)`` returns a real timing
  span even when tracing is disabled (it times and blocks but records
  nothing): callers that need the duration regardless -- the training
  loop's straggler watchdog -- read ``sp.duration`` after the block.
* **Profiler interplay.** ``span(..., profile=True)`` wraps the span
  in ``jax.profiler.TraceAnnotation`` when the global ``profile``
  knob is ``"on"``, so host spans line up with device traces in a
  ``jax.profiler`` capture. Off by default: annotations are cheap but
  not free, and only useful under an active profiler session.

Exported Chrome-trace JSON (``{"traceEvents": [...]}``, complete
events ``ph="X"``, instants ``ph="i"``, microsecond timestamps) loads
directly in ``chrome://tracing`` / Perfetto; ``python -m
repro.obs.summarize trace.json`` prints the per-phase aggregate table.

This module imports nothing from ``repro`` at module level (the
engines it instruments import it), and never imports ``jax`` unless a
device span actually has something to block on.
"""
from __future__ import annotations

import json
import os
import threading
import time

# The RL004 choice sets for the tracing knobs (docs/engines.md matrix;
# registered in tools/lint/passes/choice_set.py KNOBS).
TRACE_MODES = ("off", "on")
PROFILE_MODES = ("off", "on")


class _NullSpan:
    """The shared disabled-path span: every method is a no-op and
    ``span()`` hands out the one module singleton, so a disabled
    tracer allocates nothing per span."""

    __slots__ = ()
    duration = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def tag(self, **attrs):
        return self

    def block_on(self, value):
        return value


_NULL_SPAN = _NullSpan()


class Span:
    """One live span. Use as a context manager; see module docstring."""

    __slots__ = (
        "_tracer", "name", "attrs", "device", "profile", "_blockee",
        "_ann", "_t0", "duration",
    )

    def __init__(self, tracer, name, attrs, device, profile):
        self._tracer = tracer  # None: timer-only span (tracing disabled)
        self.name = name
        self.attrs = attrs
        self.device = device
        self.profile = profile
        self._blockee = None
        self._ann = None
        self._t0 = 0
        self.duration = 0.0

    def tag(self, **attrs) -> "Span":
        """Attach attributes the host has ALREADY read (round counts,
        live sizes, failure classes) -- never pass a device value."""
        self.attrs.update(attrs)
        return self

    def block_on(self, value):
        """Register the device value this span's close blocks on
        (``device=True`` spans only). Returns ``value`` unchanged."""
        self._blockee = value
        return value

    def __enter__(self):
        if self.profile and self._tracer is not None:
            from jax.profiler import TraceAnnotation

            self._ann = TraceAnnotation(self.name)
            self._ann.__enter__()
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self.device and self._blockee is not None:
            import jax

            jax.block_until_ready(self._blockee)
        end = time.perf_counter_ns()
        self.duration = (end - self._t0) * 1e-9
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
        if self._tracer is not None:
            if exc_type is not None:
                self.attrs.setdefault("exception", exc_type.__name__)
            self._tracer._record(self.name, self._t0, end, self.attrs)
        return False


class Tracer:
    """Span/event collector. The module-level functions drive one
    process-global instance; tests may build their own."""

    def __init__(self, *, trace: str = "off", profile: str = "off"):
        self.events: list[dict] = []
        self._origin = time.perf_counter_ns()
        self._pid = os.getpid()
        self.configure(trace=trace, profile=profile)

    # -- knobs ---------------------------------------------------------
    def configure(
        self, *, trace: str | None = None, profile: str | None = None
    ) -> None:
        """Set the ``trace=`` / ``profile=`` modes (``docs/engines.md``
        matrix; unknown strings raise like every other dispatch knob)."""
        # check_choice imports lazily, and only to raise: the engines
        # this module instruments import it, so a module-level (or
        # valid-path) import of repro.core here would be a cycle.
        if trace is not None:
            if trace not in TRACE_MODES:
                from repro.core.components import check_choice

                check_choice("trace", trace, TRACE_MODES)
            self.trace = trace
        if profile is not None:
            if profile not in PROFILE_MODES:
                from repro.core.components import check_choice

                check_choice("profile", profile, PROFILE_MODES)
            self.profile = profile

    @property
    def enabled(self) -> bool:
        return self.trace == "on"

    def reset(self) -> None:
        """Drop recorded events (fresh timeline, same knobs)."""
        self.events = []
        self._origin = time.perf_counter_ns()

    # -- recording -----------------------------------------------------
    def span(
        self,
        name: str,
        *,
        device: bool = False,
        profile: bool = False,
        timer: bool = False,
        **attrs,
    ):
        """A context-managed span. Disabled tracing returns the no-op
        singleton unless ``timer=True`` (see module docstring)."""
        if not self.enabled:
            if not timer:
                return _NULL_SPAN
            return Span(None, name, attrs, device, False)
        return Span(
            self, name, attrs, device,
            profile and self.profile == "on",
        )

    def event(self, name: str, **attrs) -> None:
        """An instant marker (Chrome-trace ``ph="i"``)."""
        if not self.enabled:
            return
        now = time.perf_counter_ns()
        self.events.append({
            "name": name, "ph": "i", "s": "t",
            "ts": (now - self._origin) / 1e3,
            "pid": self._pid, "tid": threading.get_ident(),
            "args": attrs,
        })

    def _record(self, name, t0_ns, end_ns, attrs) -> None:
        self.events.append({
            "name": name, "ph": "X",
            "ts": (t0_ns - self._origin) / 1e3,  # Chrome wants microseconds
            "dur": (end_ns - t0_ns) / 1e3,
            "pid": self._pid, "tid": threading.get_ident(),
            "args": attrs,
        })

    # -- export --------------------------------------------------------
    def chrome_trace(self) -> dict:
        """The Chrome-trace/Perfetto JSON object."""
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> int:
        """Write the timeline as Chrome-trace JSON; returns the number
        of events written (loads in chrome://tracing / Perfetto)."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1, default=str)
        return len(self.events)


# The process-global tracer the engines record into. REPRO_TRACE=1 (or
# "on") enables tracing from the environment -- the benchmark / CI
# hook; REPRO_PROFILE=1 additionally arms TraceAnnotation wrapping.
_ON = ("1", "on", "true", "yes")
_GLOBAL = Tracer(
    trace="on" if os.environ.get("REPRO_TRACE", "").lower() in _ON else "off",
    profile=(
        "on" if os.environ.get("REPRO_PROFILE", "").lower() in _ON else "off"
    ),
)


def configure(*, trace: str | None = None, profile: str | None = None):
    _GLOBAL.configure(trace=trace, profile=profile)


def enabled() -> bool:
    return _GLOBAL.enabled


def reset() -> None:
    _GLOBAL.reset()


# Bound-method aliases, not wrapper defs: the disabled path must stay
# near-free in the engines' hot loops, and a wrapper would pay a second
# call frame + kwargs packing per span. _GLOBAL is never reassigned
# (configure mutates it), so the bindings cannot go stale.
span = _GLOBAL.span
event = _GLOBAL.event


def chrome_trace() -> dict:
    return _GLOBAL.chrome_trace()


def export_chrome(path: str) -> int:
    return _GLOBAL.export_chrome(path)
