"""Observability: unified span tracing + metrics registry.

``repro.obs.trace`` records host-side spans at the boundaries the
level-synchronous engines ALREADY sync on (frontier levels, exchange
rounds, serve waves, train steps) and exports Chrome-trace/Perfetto
JSON; ``repro.obs.metrics`` is the central counter/gauge/histogram
registry all six stats dataclasses publish into through one shared
path. ``python -m repro.obs.summarize trace.json`` prints the
per-phase table. Full model: ``docs/observability.md``.
"""
from repro.obs import metrics, trace
from repro.obs.metrics import Registry, publish_stats
from repro.obs.trace import PROFILE_MODES, TRACE_MODES, Tracer

__all__ = [
    "trace",
    "metrics",
    "Tracer",
    "Registry",
    "publish_stats",
    "TRACE_MODES",
    "PROFILE_MODES",
]
