"""Central metrics registry: one namespace for every engine's counters.

The repo grew six disconnected stats dataclasses (``FrontierStats``,
``CCExchangeStats``, ``ShardedFrontierStats``, ``SplitterStats``,
``WaveRecord``, ``HealthRecord``) -- six formats for
``benchmarks/run.py --check`` to pin. This module gives them ONE
publish path: a :class:`Registry` of counters / gauges / histograms
whose ``snapshot()`` is a flat, deterministically-ordered
``{dotted.name: number}`` dict, so benchmark ``derived`` fields and CI
counter guards speak a single namespace (``docs/observability.md``).

* **counter** (``inc``): monotone accumulation -- round counts, edge
  visits, wave runs. Integer-valued fields of published stats objects
  land here (repeat publishes accumulate, so a serve engine's
  per-wave records sum naturally).
* **gauge** (``gauge``): last-write-wins level -- fractions, ratios.
  Float-valued stats fields land here.
* **histogram** (``observe``): distribution summary; ``snapshot()``
  expands it to ``name.count`` / ``name.sum`` / ``name.min`` /
  ``name.max``.

A name is permanently bound to its first kind; reusing it as another
kind raises (silent kind aliasing is how counters go wrong quietly).

``publish_stats(stats, prefix)`` is THE shared path the stats
dataclasses' ``publish()`` methods delegate to: it walks the
dataclass fields and maps bool -> counter (0/1), int -> counter,
float -> gauge, ndarray -> ``field.total`` counter (element sum),
list/tuple -> ``field.count`` counter, str/None -> skipped. Every
mapping is a pure function of the stats values, so two identical runs
produce identical snapshots (asserted by ``tests/test_obs.py``).

No ``repro`` or ``jax`` imports at module level -- the engines import
this module.
"""
from __future__ import annotations

import dataclasses

_KINDS = ("counter", "gauge", "histogram")


class Registry:
    """Counters/gauges/histograms with a flat deterministic snapshot."""

    def __init__(self):
        self._kinds: dict[str, str] = {}
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        # name -> [count, sum, min, max]
        self._hists: dict[str, list[float]] = {}

    def _claim(self, name: str, kind: str) -> None:
        have = self._kinds.setdefault(name, kind)
        if have != kind:
            raise ValueError(
                f"metric {name!r} is already a {have}, not a {kind}; "
                "pick one kind per name"
            )

    def inc(self, name: str, value: float = 1) -> None:
        """Accumulate onto a counter (create at 0)."""
        self._claim(name, "counter")
        self._counters[name] = self._counters.get(name, 0) + value

    def gauge(self, name: str, value: float) -> None:
        """Set a gauge (last write wins)."""
        self._claim(name, "gauge")
        self._gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        """Record one sample into a histogram."""
        self._claim(name, "histogram")
        h = self._hists.get(name)
        if h is None:
            self._hists[name] = [1, value, value, value]
        else:
            h[0] += 1
            h[1] += value
            h[2] = min(h[2], value)
            h[3] = max(h[3], value)

    def snapshot(self) -> dict:
        """Flat ``{name: number}`` in deterministic (sorted) order.
        Histograms expand to ``.count`` / ``.sum`` / ``.min`` /
        ``.max``; values stay int where they accumulated as ints."""
        out: dict = {}
        out.update(self._counters)
        out.update(self._gauges)
        for name, (cnt, total, lo, hi) in self._hists.items():
            out[f"{name}.count"] = cnt
            out[f"{name}.sum"] = total
            out[f"{name}.min"] = lo
            out[f"{name}.max"] = hi
        return {k: out[k] for k in sorted(out)}

    def reset(self) -> None:
        """Drop all values AND name->kind bindings."""
        self.__init__()


# The process-global registry (engine instances that need isolated
# deterministic snapshots -- the serve schedulers -- own their own).
_GLOBAL = Registry()


def inc(name: str, value: float = 1) -> None:
    _GLOBAL.inc(name, value)


def gauge(name: str, value: float) -> None:
    _GLOBAL.gauge(name, value)


def observe(name: str, value: float) -> None:
    _GLOBAL.observe(name, value)


def snapshot() -> dict:
    return _GLOBAL.snapshot()


def reset() -> None:
    _GLOBAL.reset()


def publish_stats(stats, prefix: str, registry: Registry | None = None,
                  exclude: tuple = ()) -> None:
    """Publish a stats dataclass into a registry under ``prefix``.

    The one shared path behind every stats object's ``publish()``
    method; see the module docstring for the field-type mapping."""
    import numpy as np

    reg = registry if registry is not None else _GLOBAL
    for f in dataclasses.fields(stats):
        if f.name in exclude:
            continue
        v = getattr(stats, f.name)
        name = f"{prefix}.{f.name}"
        if v is None or isinstance(v, str):
            continue
        if isinstance(v, bool):
            reg.inc(name, int(v))
        elif isinstance(v, (int, np.integer)):
            reg.inc(name, int(v))
        elif isinstance(v, (float, np.floating)):
            reg.gauge(name, float(v))
        elif isinstance(v, np.ndarray):
            reg.inc(f"{name}.total", float(v.sum()) if v.size else 0.0)
        elif isinstance(v, (list, tuple)):
            reg.inc(f"{name}.count", len(v))


def derived_fragment(snap: dict, prefix: str = "") -> str:
    """Render snapshot entries whose name starts with ``prefix`` as a
    benchmark ``derived`` fragment (``a=1;b=2.5``) -- the bridge into
    ``benchmarks/run.py --check``'s counter pinning. Entries render in
    sorted name order regardless of input order; floats keep three
    decimals; integral values print as ints so snapshots stay stable."""
    parts = []
    for k, v in sorted(snap.items()):
        if not k.startswith(prefix):
            continue
        if float(v) == int(v):
            parts.append(f"{k}={int(v)}")
        else:
            parts.append(f"{k}={v:.3f}")
    return ";".join(parts)
