"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 (data, model) single pod, or 2x16x16 (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)}; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)."
        )
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_test_mesh(
    shape: tuple[int, ...] = (1, 1), axes: tuple[str, ...] = ("data", "model")
) -> Mesh:
    """Small mesh over however many devices the test process has."""
    n = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev, axes)


def mesh_num_chips(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
