"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).

All construction routes through ``repro.compat.make_mesh`` so the same
builders work on jax 0.4.x (no AxisType / axis_types kwarg) and current.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.compat import Mesh, make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 (data, model) single pod, or 2x16x16 (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)}; set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)."
        )
    return make_mesh(shape, axes, devices=devices[:n])


def make_test_mesh(
    shape: tuple[int, ...] = (1, 1), axes: tuple[str, ...] = ("data", "model")
) -> Mesh:
    """Small mesh over however many devices the test process has."""
    n = int(np.prod(shape))
    return make_mesh(shape, axes, devices=jax.devices()[:n])


def make_graph_mesh(num_devices: int | None = None) -> Mesh:
    """1-D edge-partitioning mesh for the sharded graph engine."""
    from repro.distributed.graph import graph_mesh

    return graph_mesh(num_devices)


def mesh_num_chips(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
