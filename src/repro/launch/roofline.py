"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), in seconds:

  compute    = step_FLOPs_total / (chips x peak_FLOPs_chip)
  memory     = HBM_bytes_per_device / HBM_bw_chip
  collective = collective_bytes_per_device / ICI_link_bw

FLOPs and HBM bytes come from the analytic perfmodel (launch/perfmodel.py)
because XLA's cost_analysis counts each while/scan body ONCE -- a layer-
scanned, microbatched step is undercounted ~100x (validated in
tests/test_perfmodel.py against unscanned 1-layer probes). Collective bytes
are parsed from the post-SPMD HLO with TRIP-COUNT AWARENESS: collectives
inside a while body are multiplied by the loop's trip count (recovered from
the loop condition's `compare(..., constant(N)), direction=LT`).

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (one-link conservative figure; a 2D torus has more
links, so the collective term is an upper bound).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link (conservative single-link)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>.+?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"\b([a-z]+\d*(?:e\d+m\d+(?:fn)?)?)\[([0-9,]*)\]")
_BLOCK_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=(%[\w\.\-]+), body=(%[\w\.\-]+)"
)
_TRIP_RE = re.compile(
    r"compare\(\s*s32\[\]\s*%[\w\.\-]+,\s*s32\[\]\s*%[\w\.\-]+\s*\),\s*direction=(LT|LE)"
)
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")

# per-device wire-traffic multiplier for ring implementations
_TRAFFIC_FACTOR = {
    "all-reduce": 2.0,  # reduce-scatter + all-gather phases
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> its lines."""
    blocks: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo.splitlines():
        m = _BLOCK_RE.match(line.strip()) if "{" in line else None
        if m and ("->" in line or "ENTRY" in line):
            cur = m.group(1)
            blocks[cur] = []
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            blocks[cur].append(line)
    return blocks


def _loop_factors(blocks: dict[str, list[str]]) -> dict[str, float]:
    """Effective execution multiplicity per computation (nested loops
    multiply). Unrecognized conditions conservatively count once."""
    trip: dict[str, float] = {}
    parent: dict[str, str] = {}
    for name, lines in blocks.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if not m:
                continue
            cond, body = m.group(1), m.group(2)
            n = _cond_trip(blocks.get(cond, []))
            trip[body] = n
            parent[body] = name
            # the condition region executes n+1 times, no collectives there

    def factor(name: str, depth: int = 0) -> float:
        if depth > 10:
            return 1.0
        f = trip.get(name, 1.0)
        p = parent.get(name)
        return f * (factor(p, depth + 1) if p else 1.0)

    return {name: factor(name) for name in blocks}


def _cond_trip(cond_lines: list[str]) -> float:
    bound = None
    direction = None
    for line in cond_lines:
        c = _CONST_RE.search(line)
        if c:
            bound = int(c.group(1))
        t = _TRIP_RE.search(line)
        if t:
            direction = t.group(1)
    if bound is None:
        return 1.0
    if direction == "LE":
        return float(bound + 1)
    return float(bound)


@dataclass
class CollectiveStats:
    bytes_by_op: dict = field(default_factory=dict)
    count_by_op: dict = field(default_factory=dict)
    bf16_wire_bytes: float = 0.0
    loop_scaled: bool = True

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device collective traffic, loop-trip-count scaled.

    ``bf16_wire_bytes`` additionally halves every f32 payload: the XLA CPU
    backend legalizes bf16 compute to f32 (verified: even forward-pass
    activation all-reduces appear as f32 in CPU HLO), so raw byte counts
    double-count what a TPU would move in bf16. Raw numbers are therefore
    an upper bound; the corrected number assumes all f32 payloads would be
    bf16 on TPU (slightly optimistic for genuinely-f32 reductions such as
    fp32 gradient accumulators).
    """
    blocks = _split_computations(hlo_text)
    factors = _loop_factors(blocks)
    stats = CollectiveStats()
    for name, lines in blocks.items():
        f = factors.get(name, 1.0)
        for line in lines:
            if "-done(" in line:
                continue
            m = _COLL_RE.search(line)
            if not m:
                continue
            op = m.group("op")
            result = m.group("result")
            nbytes = _shape_bytes(result) * _TRAFFIC_FACTOR[op] * f
            # recompute with f32 payloads halved (bf16-on-the-wire estimate)
            half = 0
            for dtype, dims in _SHAPE_RE.findall(result):
                if dtype not in _DTYPE_BYTES:
                    continue
                n = 1
                if dims:
                    for d in dims.split(","):
                        n *= int(d)
                b = n * _DTYPE_BYTES[dtype]
                half += b // 2 if dtype == "f32" else b
            stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + nbytes
            stats.count_by_op[op] = stats.count_by_op.get(op, 0) + f
            stats.bf16_wire_bytes += half * _TRAFFIC_FACTOR[op] * f
    return stats


@dataclass
class Roofline:
    flops_total: float  # analytic, whole step, all chips
    hbm_bytes_per_device: float  # analytic
    collective_bytes_per_device: float  # HLO-parsed, loop-scaled
    collective_bytes_bf16_wire: float  # f32 payloads halved (CPU legalization)
    compute_s: float
    memory_s: float
    collective_s: float
    collective_s_bf16_wire: float
    bottleneck: str
    model_flops_total: float  # 6*N*D / 2*N*D "useful" flops
    useful_flops_fraction: float
    roofline_fraction: float  # step-time lower bound / dominant term
    collectives: dict
    memory_per_device: dict
    raw_cost_analysis: dict

    def as_dict(self) -> dict:
        return self.__dict__.copy()


def analyze(
    compiled,
    num_chips: int,
    *,
    model_flops_total: float,
    flops_total: float | None = None,
    hbm_bytes_per_device: float | None = None,
) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    raw = {
        "flops_per_device_unscaled": float(cost.get("flops", 0.0)),
        "bytes_per_device_unscaled": float(cost.get("bytes accessed", 0.0)),
    }
    flops_total = flops_total if flops_total is not None else model_flops_total
    if hbm_bytes_per_device is None:
        hbm_bytes_per_device = raw["bytes_per_device_unscaled"]

    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_bytes(hlo)

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
        ):
            v = getattr(ma, k, None)
            if v is not None:
                mem[k] = int(v)
    except Exception:
        pass

    compute_s = flops_total / (num_chips * PEAK_FLOPS)
    memory_s = hbm_bytes_per_device / HBM_BW
    collective_s = coll.total_bytes / ICI_BW
    collective_s_bf16 = coll.bf16_wire_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops_total / flops_total if flops_total else 0.0
    # fraction of the dominant-term bound that is useful compute time
    ideal_s = model_flops_total / (num_chips * PEAK_FLOPS)
    roofline_fraction = ideal_s / max(terms[bottleneck], 1e-30)
    return Roofline(
        flops_total=flops_total,
        hbm_bytes_per_device=hbm_bytes_per_device,
        collective_bytes_per_device=coll.total_bytes,
        collective_bytes_bf16_wire=coll.bf16_wire_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        collective_s_bf16_wire=collective_s_bf16,
        bottleneck=bottleneck,
        model_flops_total=model_flops_total,
        useful_flops_fraction=useful,
        roofline_fraction=roofline_fraction,
        collectives={
            "bytes_by_op": coll.bytes_by_op,
            "count_by_op": coll.count_by_op,
        },
        memory_per_device=mem,
        raw_cost_analysis=raw,
    )
