"""Render EXPERIMENTS.md roofline tables from dry-run JSON records."""
from __future__ import annotations

import json


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_markdown(path: str, mesh: str = "single") -> str:
    recs = [r for r in json.load(open(path)) if r["mesh"] == mesh]
    lines = [
        "| arch | shape | compute | memory | collective (raw / bf16-wire) "
        "| bottleneck | MFU-bound | useful/total flops |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skip":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skip | — | "
                f"{r['reason'][:58]} |"
            )
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | |")
            continue
        f = r["roofline"]
        coll_bf16 = f.get("collective_s_bf16_wire", f["collective_s"])
        dom = max(f["compute_s"], f["memory_s"], coll_bf16)
        ideal = f["model_flops_total"] / (r["chips"] * 197e12)
        frac = ideal / dom if dom else 0.0
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(f['compute_s'])} | "
            f"{_fmt_s(f['memory_s'])} | {_fmt_s(f['collective_s'])} / "
            f"{_fmt_s(coll_bf16)} | {f['bottleneck']} | {frac:.3f} | "
            f"{f['useful_flops_fraction']:.2f} |"
        )
    return "\n".join(lines)


def memory_markdown(path: str, mesh: str = "single") -> str:
    recs = [
        r for r in json.load(open(path))
        if r["mesh"] == mesh and r["status"] == "ok"
    ]
    lines = [
        "| arch | shape | args GB/dev | temp GB/dev | fits 16GB v5e |",
        "|---|---|---|---|---|",
    ]
    for r in recs:
        m = r["roofline"]["memory_per_device"]
        a = m.get("argument_size_in_bytes", 0) / 1e9
        t = m.get("temp_size_in_bytes", 0) / 1e9
        alias = m.get("alias_size_in_bytes", 0) / 1e9
        tot = a + t - 0  # aliased buffers reuse argument space
        lines.append(
            f"| {r['arch']} | {r['shape']} | {a:.2f} | {t:.2f} | "
            f"{'yes' if tot <= 16 else 'NO (' + f'{tot:.1f}GB' + ')'} |"
        )
    return "\n".join(lines)


def compare_markdown(base_path: str, opt_path: str, cells) -> str:
    base = {
        (r["arch"], r["shape"], r["mesh"]): r
        for r in json.load(open(base_path))
    }
    opt = {
        (r["arch"], r["shape"], r["mesh"]): r for r in json.load(open(opt_path))
    }
    lines = [
        "| cell | metric | baseline | optimized | gain |",
        "|---|---|---|---|---|",
    ]
    for key in cells:
        b, o = base.get(key), opt.get(key)
        if not (b and o and b["status"] == "ok" and o["status"] == "ok"):
            continue
        for metric in ("collective_s", "compute_s", "memory_s"):
            bb, oo = b["roofline"][metric], o["roofline"][metric]
            gain = bb / oo if oo else float("inf")
            lines.append(
                f"| {key[0]} x {key[1]} ({key[2]}) | {metric} | "
                f"{_fmt_s(bb)} | {_fmt_s(oo)} | {gain:.2f}x |"
            )
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    print(roofline_markdown(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"))
