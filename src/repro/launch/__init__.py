"""Launchers: production mesh, dry-run driver, roofline analysis, train CLI."""
