"""Analytic per-step FLOP / HBM-byte models for the roofline.

Why analytic: XLA's ``cost_analysis()`` counts each ``while`` (scan) body
ONCE, so layer-scanned + microbatched steps are undercounted by
L x num_microbatches (verified empirically: gemma-2b train_4k reports
2.1e12 flops/device vs the 6.2e13 true value). Rather than unrolling every
model (compile-time explodes at 512 devices), compute/memory terms use
exact closed forms below, validated against cost_analysis on 1-layer
unscanned probes in tests/test_perfmodel.py. Collective bytes use the
trip-count-aware HLO parser in roofline.py.

Conventions: matmul (m,k)x(k,n) = 2mkn flops; backward = 2x forward;
optimizer update ~ 12 flops/param (ignored: <0.1% of any cell here).
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass
class StepCost:
    flops_total: float  # whole-step, all chips
    hbm_bytes_per_device: float


def _dtype_bytes(dtype: str) -> int:
    return {"bfloat16": 2, "float32": 4, "float16": 2}[dtype]


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def lm_attention_flops(cfg, batch: int, seq: int, *, causal_avg: bool = True) -> float:
    """Score+context matmul flops for one forward pass (whole batch)."""
    if cfg.attention == "mla":
        qk_dim = cfg.num_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
        v_dim = cfg.num_heads * cfg.v_head_dim
    else:
        qk_dim = cfg.num_heads * cfg.head_dim
        v_dim = qk_dim
    kv_span = seq if cfg.sliding_window is None else min(seq, cfg.sliding_window)
    eff = kv_span / 2 if (causal_avg and cfg.sliding_window is None) else kv_span
    per_layer = 2 * batch * seq * eff * (qk_dim + v_dim)
    return per_layer * cfg.num_layers


def lm_train_flops(cfg, batch: int, seq: int) -> float:
    """6*N_active*T + 3x attention quadratic term (fwd=1x, bwd=2x)."""
    return 6.0 * cfg.active_params() * batch * seq + 3.0 * lm_attention_flops(
        cfg, batch, seq
    )


def lm_prefill_flops(cfg, batch: int, seq: int) -> float:
    return 2.0 * cfg.active_params() * batch * seq + lm_attention_flops(
        cfg, batch, seq
    )


def lm_decode_flops(cfg, batch: int, cache_len: int) -> float:
    """One new token per sequence against a cache of cache_len."""
    if cfg.attention == "mla":
        # absorbed path: scores vs latent (kv_lora+rope), ctx in latent
        span = cache_len
        per_layer = 2 * batch * span * cfg.num_heads * (
            cfg.kv_lora_rank + cfg.qk_rope_head_dim + cfg.kv_lora_rank
        )
    else:
        span = (
            min(cache_len, cfg.sliding_window)
            if cfg.sliding_window
            else cache_len
        )
        per_layer = 4 * batch * span * cfg.num_heads * cfg.head_dim
    return 2.0 * cfg.active_params() * batch + per_layer * cfg.num_layers


def lm_train_bytes_per_device(
    cfg, batch: int, seq: int, chips: int, *, moment_dtype: str = "float32",
    microbatches: int = 1,
) -> float:
    """HBM traffic model: params are read fwd + read bwd (+re-read under
    remat) and written once; grads accumulate rw per microbatch; moments rw
    once; activations rw ~ 12*B*S*d per layer (stored residuals + remat
    recompute traffic). Parameter traffic repeats per microbatch (weights
    re-streamed from HBM each pass)."""
    p_dev = 2.0 * cfg.total_params() / chips  # bf16 params, sharded
    mdt = _dtype_bytes(moment_dtype)
    g_dev = 4.0 * cfg.total_params() / chips  # fp32 grad accumulator
    m_dev = mdt * cfg.total_params() / chips
    weight_traffic = microbatches * 3.0 * p_dev  # fwd + bwd + remat re-read
    grad_traffic = microbatches * 2.0 * g_dev
    opt_traffic = 2.0 * p_dev + 4.0 * m_dev
    act_bytes = 2  # bf16 activations
    tokens_dev = batch * seq / max(chips // 16, 1) / 16  # dp-sharded tokens
    # per layer: ~6 tensor rw of size (tokens, d) fwd + 2x bwd under remat
    act_traffic = 18.0 * tokens_dev * cfg.d_model * act_bytes * cfg.num_layers
    return weight_traffic + grad_traffic + opt_traffic + act_traffic


def lm_decode_bytes_per_device(cfg, batch: int, cache_len: int, chips: int) -> float:
    """Decode is weight+cache streaming: every active param read once, the
    live KV cache read once, new KV written."""
    p_dev = 2.0 * cfg.active_params() / chips
    if cfg.attention == "mla":
        per_tok = cfg.kv_lora_rank + cfg.qk_rope_head_dim
    else:
        per_tok = 2 * cfg.num_kv_heads * cfg.head_dim
    span = (
        min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    )
    cache_dev = 2.0 * batch * span * per_tok * cfg.num_layers / chips
    return p_dev + cache_dev


def lm_prefill_bytes_per_device(cfg, batch: int, seq: int, chips: int) -> float:
    p_dev = 2.0 * cfg.total_params() / chips
    tokens_dev = batch * seq / chips * 16  # model-axis replicates activations
    act = 12.0 * tokens_dev * cfg.d_model * 2 * cfg.num_layers
    return p_dev + act


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


def gnn_train_flops(arch_name: str, cfg, n: int, m: int, d_in: int) -> float:
    """Message passing: per-edge gather+reduce plus per-node MLPs; x3 for
    fwd+bwd."""
    if arch_name == "gin-tu":
        h = cfg.d_hidden
        per_layer = 2 * n * (d_in * h if d_in else h * h) + 2 * n * h * h + 2 * m * h
        fwd = sum(
            2 * n * ((d_in if i == 0 else h) * h + h * h) + 2 * m * (d_in if i == 0 else h)
            for i in range(cfg.num_layers)
        )
        return 3.0 * fwd
    if arch_name == "gat-cora":
        h, k = cfg.d_hidden, cfg.num_heads
        fwd = 2 * n * d_in * h * k + 6 * m * h * k  # proj + edge scores + agg
        fwd += 2 * n * h * k * cfg.num_classes + 4 * m * cfg.num_classes
        return 3.0 * fwd
    if arch_name == "egnn":
        h = cfg.d_hidden
        per_layer = 2 * m * (2 * h + 1) * h + 2 * m * h * h  # edge mlp
        per_layer += 2 * m * h * h + 2 * m * h  # coord mlp
        per_layer += 2 * n * 2 * h * h + 2 * n * h * h  # node mlp
        return 3.0 * (2 * n * d_in * h + cfg.num_layers * per_layer)
    if arch_name == "mace":
        c = cfg.channels
        n_irr = (cfg.l_max + 1) ** 2  # 9 for l_max=2
        paths = 15  # msg paths at l_max=2 steady state
        per_layer = 2 * m * c * n_irr * paths  # CG message contractions
        per_layer += 2 * n * c * c * (cfg.l_max + 1) * 3  # channel mixes
        per_layer += 2 * n * c * n_irr * 40  # product basis (corr 2+3)
        per_layer += 2 * m * cfg.n_rbf * 64 + 2 * m * 64 * paths * c  # radial
        return 3.0 * cfg.num_layers * per_layer
    raise ValueError(arch_name)


def gnn_train_bytes_per_device(
    arch_name: str, cfg, n: int, m: int, d_in: int, chips: int
) -> float:
    """Edge tensors sharded over all chips; node tensors replicated.
    Traffic = edge gathers/scatters (sharded) + node feature rw (replicated,
    the baseline's cost -- this is what the channel-sharding hillclimb
    attacks)."""
    h = getattr(cfg, "d_hidden", getattr(cfg, "channels", 64))
    n_irr = (cfg.l_max + 1) ** 2 if arch_name == "mace" else 1
    edge_rw = 4.0 * (m / chips) * h * n_irr * 4 * cfg.num_layers
    node_rw = 8.0 * n * h * n_irr * 4 * cfg.num_layers  # replicated!
    feats = 4.0 * n * d_in
    return edge_rw + node_rw + feats


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------


def recsys_step_flops(cfg, batch: int, *, train: bool) -> float:
    m_f, d_e = cfg.n_fields, cfg.embed_dim
    cin = 0
    h_prev = m_f
    for h in cfg.cin_layers:
        cin += 2 * h * h_prev * m_f * d_e
        h_prev = h
    mlp = 0
    d_in = m_f * d_e
    for d_out in cfg.mlp_layers:
        mlp += 2 * d_in * d_out
        d_in = d_out
    per_ex = cin + mlp
    return (3.0 if train else 1.0) * per_ex * batch


def recsys_bytes_per_device(cfg, batch: int, chips: int, *, train: bool) -> float:
    # embedding rows touched: batch x fields x dim, gathered from the
    # row-sharded table (each chip reads its resident rows only ~1/chips)
    lookup = 4.0 * batch * cfg.n_fields * cfg.embed_dim / chips
    dense_params = 4.0 * (
        sum(cfg.cin_layers) * cfg.n_fields * 210 + 400 * 400 + 390 * 400
    )
    act = 4.0 * batch / max(chips // 16, 1) / 16 * (
        cfg.n_fields * cfg.embed_dim + sum(cfg.cin_layers) + sum(cfg.mlp_layers)
    )
    factor = 4.0 if train else 1.0
    return factor * (lookup + act) + dense_params
