import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver.

For every (architecture x input shape) cell, lower + compile the step on the
production mesh (single-pod 16x16 and multi-pod 2x16x16), print
memory_analysis / cost_analysis, and derive the roofline terms.

Run one cell:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
        --shape train_4k --mesh single
Run everything (per-cell subprocesses, results appended to a JSON file):
    PYTHONPATH=src python -m repro.launch.dryrun --all \
        --out results/dryrun.json

The XLA_FLAGS line above MUST stay the first statement: jax locks the device
count at first import.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax

from repro.configs import ARCH_NAMES, get_arch
from repro.launch.mesh import make_production_mesh, mesh_num_chips
from repro.launch import roofline as rl


def run_cell(arch_name: str, shape: str, multi_pod: bool, verbose: bool = True):
    arch = get_arch(arch_name)
    skip = arch.skip_reason(shape)
    mesh_name = "multi" if multi_pod else "single"
    base = {"arch": arch_name, "shape": shape, "mesh": mesh_name}
    if skip:
        return base | {"status": "skip", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_num_chips(mesh)
    t0 = time.time()
    spec = arch.build(shape, mesh)
    fn = jax.jit(
        spec.fn,
        in_shardings=spec.in_shardings,
        out_shardings=spec.out_shardings,
        donate_argnums=spec.donate_argnums,
    )
    lowered = fn.lower(*spec.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if verbose:
        print(f"== {arch_name} x {shape} on {mesh_name} ({chips} chips) ==")
        print("memory_analysis:", mem)
        print("cost_analysis flops:", cost.get("flops"),
              "bytes:", cost.get("bytes accessed"))

    roof = rl.analyze(
        compiled,
        chips,
        model_flops_total=spec.model_flops_total,
        flops_total=spec.flops_total,
        hbm_bytes_per_device=spec.hbm_bytes_per_device,
    )
    return base | {
        "status": "ok",
        "chips": chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "note": spec.note,
        "roofline": roof.as_dict(),
    }


def _run_all(out_path: str, meshes: list[str], only_arch: str | None = None):
    """Spawn one subprocess per cell (keeps compile memory bounded and one
    bad cell from killing the sweep)."""
    results = []
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}
    for arch_name in ARCH_NAMES:
        if only_arch and arch_name != only_arch:
            continue
        arch = get_arch(arch_name)
        for shape in arch.shapes():
            for mesh_name in meshes:
                key = (arch_name, shape, mesh_name)
                if key in done:
                    continue
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch_name, "--shape", shape,
                    "--mesh", mesh_name, "--json",
                ]
                print(">>", " ".join(cmd), flush=True)
                t0 = time.time()
                proc = subprocess.run(
                    cmd, capture_output=True, text=True, timeout=3600
                )
                dt = time.time() - t0
                rec = None
                for line in reversed(proc.stdout.splitlines()):
                    if line.startswith("{"):
                        try:
                            rec = json.loads(line)
                            break
                        except json.JSONDecodeError:
                            continue
                if rec is None:
                    rec = {
                        "arch": arch_name, "shape": shape, "mesh": mesh_name,
                        "status": "error",
                        "error": proc.stderr[-2000:],
                        "wall_s": round(dt, 1),
                    }
                results.append(rec)
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)
                print(f"   -> {rec['status']} ({dt:.0f}s)", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--json", action="store_true",
                    help="print a single JSON record on the last line")
    args = ap.parse_args()

    if args.all:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        meshes = ["single", "multi"]
        results = _run_all(args.out, meshes, only_arch=args.arch)
        ok = sum(r["status"] == "ok" for r in results)
        skip = sum(r["status"] == "skip" for r in results)
        err = sum(r["status"] == "error" for r in results)
        print(f"dry-run sweep: {ok} ok, {skip} skip, {err} error")
        sys.exit(1 if err else 0)

    try:
        rec = run_cell(
            args.arch, args.shape, args.mesh == "multi", verbose=not args.json
        )
    except Exception:
        traceback.print_exc()
        rec = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "status": "error", "error": traceback.format_exc()[-2000:],
        }
    print(json.dumps(rec))
    sys.exit(0 if rec["status"] in ("ok", "skip") else 1)


if __name__ == "__main__":
    main()
