"""Pure-jnp oracle for the blocked sorted segment sum."""
from __future__ import annotations

import jax


def segment_sum_sorted_ref(
    data: jax.Array, seg_ids: jax.Array, num_segments: int
) -> jax.Array:
    # Padding rows carry seg_id == num_segments and are dropped by scatter.
    return jax.ops.segment_sum(
        data, seg_ids, num_segments + 1, indices_are_sorted=True
    )[:num_segments]
