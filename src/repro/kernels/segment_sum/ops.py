"""Public wrapper: pads, derives per-output-block edge ranges, dispatches.

The eb_start/eb_count tables are the TPU analogue of CSR row pointers at
block granularity; they are computed with jnp (O(num_blocks) searchsorted)
so the whole op stays jit-compatible.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret, on_tpu
from repro.kernels.segment_sum.ref import segment_sum_sorted_ref
from repro.kernels.segment_sum.segment_sum import segment_sum_sorted_pallas


@partial(
    jax.jit,
    static_argnames=("num_segments", "impl", "block_e", "block_s", "max_steps"),
)
def segment_sum_sorted(
    data: jax.Array,
    seg_ids: jax.Array,
    num_segments: int,
    *,
    impl: str = "auto",
    block_e: int = 512,
    block_s: int = 256,
    max_steps: int | None = None,
) -> jax.Array:
    """Segment sum over rows already sorted by ``seg_ids``.

    Args:
        data: (m, d) float messages, sorted by segment.
        seg_ids: (m,) int32 sorted segment ids in [0, num_segments).
        num_segments: output rows.
        max_steps: static bound on edge blocks any output block spans; the
            default (all blocks) is safe but slow -- callers with degree
            bounds should pass ceil(max_in_degree_per_block / block_e) + 1.
    """
    if impl == "auto":
        impl = "pallas" if on_tpu() else "xla"
    if impl == "xla":
        return segment_sum_sorted_ref(data, seg_ids, num_segments)

    m, d = data.shape
    pad_m = (-m) % block_e
    pad_s = (-num_segments) % block_s
    ns_pad = num_segments + pad_s
    data_p = jnp.pad(data, ((0, pad_m), (0, 0)))
    # Padding rows get an out-of-range segment id -> one-hot rows of zeros.
    seg_p = jnp.pad(seg_ids, (0, pad_m), constant_values=ns_pad + block_s)
    mp = m + pad_m
    num_eb = mp // block_e
    num_ob = ns_pad // block_s

    # First/last edge touching each output block, via binary search over the
    # sorted ids sampled at block edges.
    block_first = seg_p[:: block_e]  # (num_eb,) first seg id in each block
    block_last = seg_p[block_e - 1 :: block_e]  # last seg id in each block
    ob_lo = jnp.arange(num_ob, dtype=jnp.int32) * block_s
    ob_hi = ob_lo + (block_s - 1)
    # edge block j intersects out block o iff block_first[j] <= ob_hi[o]
    # and block_last[j] >= ob_lo[o]; with sorted ids the j's are contiguous.
    eb_start = jnp.searchsorted(block_last, ob_lo, side="left").astype(jnp.int32)
    eb_end = jnp.searchsorted(block_first, ob_hi, side="right").astype(jnp.int32)
    eb_count = jnp.maximum(eb_end - eb_start, 0)
    eb_start = jnp.minimum(eb_start, num_eb - 1)

    steps = max_steps if max_steps is not None else num_eb
    out = segment_sum_sorted_pallas(
        data_p,
        seg_p.astype(jnp.int32),
        eb_start,
        eb_count,
        ns_pad,
        block_e=block_e,
        block_s=block_s,
        max_steps=steps,
        interpret=default_interpret() if impl == "pallas" else True,
    )
    return out[:num_segments]
