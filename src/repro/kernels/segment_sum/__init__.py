from repro.kernels.segment_sum.ops import segment_sum_sorted

__all__ = ["segment_sum_sorted"]
