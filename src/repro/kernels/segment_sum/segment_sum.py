"""Blocked sorted-segment reduction (the GNN/embedding scatter hot spot).

TPU adaptation of the paper's coalescing guideline applied to the scatter
side of message passing: edges are pre-sorted by destination (G1), so each
output block of segments receives contributions from a *contiguous* range of
edge blocks. The kernel walks that range with scalar-prefetched block
offsets and turns the per-block scatter into a dense one-hot matmul on the
MXU -- irregularity is confined to an on-chip (block_e, block_s) comparison,
while all HBM traffic is contiguous block DMA.

Grid: (num_out_blocks, max_edge_blocks_per_out). Output blocks are revisited
along the second grid axis and accumulated in place (init at j == 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _segsum_kernel(
    eb_start_ref,  # scalar-prefetch: (num_out_blocks,) first edge block
    eb_count_ref,  # scalar-prefetch: (num_out_blocks,) edge block count
    seg_ref,  # (block_e,) sorted segment ids for this edge block
    data_ref,  # (block_e, d) messages
    out_ref,  # (block_s, d) accumulated output block
    *,
    block_s: int,
):
    o = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(j < eb_count_ref[o])
    def _accumulate():
        seg = seg_ref[...]
        local = seg - o * block_s
        # (block_e, block_s) one-hot: rows outside this output block vanish.
        onehot = (
            local[:, None] == jax.lax.iota(jnp.int32, block_s)[None, :]
        ).astype(data_ref.dtype)
        # MXU matmul does the segment reduction densely.
        out_ref[...] += jnp.dot(
            onehot.T, data_ref[...], preferred_element_type=out_ref.dtype
        )


def segment_sum_sorted_pallas(
    data: jax.Array,  # (m, d), rows sorted by segment id
    seg_ids: jax.Array,  # (m,) sorted, int32; padding rows use num_segments
    eb_start: jax.Array,  # (num_out_blocks,) int32
    eb_count: jax.Array,  # (num_out_blocks,) int32
    num_segments: int,
    *,
    block_e: int = 512,
    block_s: int = 256,
    max_steps: int,
    interpret: bool = True,
) -> jax.Array:
    m, d = data.shape
    if m % block_e or num_segments % block_s:
        raise ValueError("pad m to block_e and num_segments to block_s")
    num_out_blocks = num_segments // block_s
    kernel = functools.partial(_segsum_kernel, block_s=block_s)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_out_blocks, max_steps),
        in_specs=[
            pl.BlockSpec(
                (block_e,), lambda o, j, eb_s, eb_c: (eb_s[o] + j,)
            ),
            pl.BlockSpec(
                (block_e, d), lambda o, j, eb_s, eb_c: (eb_s[o] + j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((block_s, d), lambda o, j, eb_s, eb_c: (o, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_segments, d), data.dtype),
        interpret=interpret,
    )(eb_start, eb_count, seg_ids, data)
