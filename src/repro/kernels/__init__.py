"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel directory holds:
  <name>.py  -- pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py     -- jit'd public wrapper (chooses pallas vs xla path)
  ref.py     -- pure-jnp oracle used by tests and by CPU dry-runs

Kernels are written for TPU as the target and validated with
``interpret=True`` on CPU (the kernel body runs as plain JAX ops).
"""
import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    """Interpret mode everywhere except a real TPU backend."""
    return not on_tpu()
