"""Pure-jnp oracle for the pointer_jump kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pointer_jump_ref(
    nxt: jax.Array, w: jax.Array, *, iters: int
) -> tuple[jax.Array, jax.Array]:
    def body(_, state):
        rank, nxt = state
        return rank + rank[nxt], nxt[nxt]

    rank, nxt = jax.lax.fori_loop(0, iters, body, (w, nxt))
    return rank, nxt
