from repro.kernels.pointer_jump.ops import pointer_jump

__all__ = ["pointer_jump"]
