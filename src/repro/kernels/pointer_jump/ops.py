"""Public wrapper: VMEM pointer jumping with automatic path choice."""
from __future__ import annotations

import math
from functools import partial

import jax

from repro.kernels import default_interpret, on_tpu
from repro.kernels.pointer_jump.pointer_jump import pointer_jump_pallas
from repro.kernels.pointer_jump.ref import pointer_jump_ref

# Above this many nodes the list no longer fits VMEM comfortably and the
# multi-"kernel" XLA path (HBM round trips per step) is used instead --
# the same small/large split as the paper's single- vs multi-kernel Wylie.
VMEM_NODE_LIMIT = 1 << 20


@partial(jax.jit, static_argnames=("iters", "impl"))
def pointer_jump(
    nxt: jax.Array,
    w: jax.Array,
    *,
    iters: int | None = None,
    impl: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    p = nxt.shape[0]
    iters = iters if iters is not None else max(1, math.ceil(math.log2(max(p, 2))))
    if impl == "auto":
        impl = "pallas" if (on_tpu() and p <= VMEM_NODE_LIMIT) else "xla"
    if impl == "pallas":
        return pointer_jump_pallas(nxt, w, iters=iters, interpret=default_interpret())
    if impl == "pallas_interpret":
        return pointer_jump_pallas(nxt, w, iters=iters, interpret=True)
    if impl == "xla":
        return pointer_jump_ref(nxt, w, iters=iters)
    raise ValueError(f"unknown impl {impl!r}")
