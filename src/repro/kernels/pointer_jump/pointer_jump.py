"""Single-kernel VMEM-resident pointer jumping.

TPU adaptation of the paper's "single thread block + __syncthreads()" fast
path (section 3.1): when the list fits on-chip, run ALL O(log p) jumping
steps inside one kernel so intermediate (rank, next) states never round-trip
to HBM. The paper uses this for the p-node splitter list in RS4; so do we.

The whole problem is one VMEM block (p <= ~1M int32 comfortably fits the
~16MB VMEM twice over); the PRAM synchronization barrier between steps is
the sequential `fori_loop` iteration boundary -- zero cost, exactly the
guideline-G4 win the paper measured.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pointer_jump_kernel(nxt_ref, w_ref, rank_ref, last_ref, *, iters: int):
    nxt = nxt_ref[...]
    rank = w_ref[...]

    def body(_, state):
        rank, nxt = state
        # VMEM gather: one row fetch per lane, on-chip (no HBM traffic).
        rank = rank + jnp.take(rank, nxt, axis=0)
        nxt = jnp.take(nxt, nxt, axis=0)
        return rank, nxt

    rank, nxt = jax.lax.fori_loop(0, iters, body, (rank, nxt))
    rank_ref[...] = rank
    last_ref[...] = nxt


def pointer_jump_pallas(
    nxt: jax.Array, w: jax.Array, *, iters: int, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """Jump `iters` times: returns (suffix_sums, final_pointers).

    rank[j] converges to the w-sum over the pointer path [j .. terminal)
    provided w[terminal] == 0 and nxt[terminal] == terminal.
    """
    p = nxt.shape[0]
    kernel = functools.partial(_pointer_jump_kernel, iters=iters)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((p,), lambda i: (0,)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((p,), lambda i: (0,)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), w.dtype),
            jax.ShapeDtypeStruct((p,), nxt.dtype),
        ],
        interpret=interpret,
    )(nxt, w)
