"""Pure-jnp oracle for splitter_aggregate."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def splitter_aggregate_ref(packed: jax.Array, sprank: jax.Array) -> jax.Array:
    return jnp.take(sprank, packed[:, 1], axis=0) - packed[:, 0]
