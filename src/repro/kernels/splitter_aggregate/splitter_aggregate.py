"""RS5 rank aggregation: the paper's "fast kernel" (Table 2).

rank[j] = sprank[owner[j]] - local[j], streamed over all n nodes.

This kernel is the coalescing best case the paper contrasts with RS3: the
(local, owner) pairs are read in pure striding order (one contiguous block
DMA per grid step) and the only irregular access -- the sprank gather -- hits
a table of p entries that is pinned whole in VMEM for every grid step. The
AoS (n, 2) row layout means one block fetch brings both fields (guideline
G5's 64-bit union, as a BlockSpec).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _agg_kernel(sprank_ref, packed_ref, out_ref):
    local = packed_ref[:, 0]
    owner = packed_ref[:, 1]
    # Irregular gather confined to the VMEM-resident splitter table.
    out_ref[...] = jnp.take(sprank_ref[...], owner, axis=0) - local


def splitter_aggregate_pallas(
    packed: jax.Array,
    sprank: jax.Array,
    *,
    block_n: int = 2048,
    interpret: bool = True,
) -> jax.Array:
    """packed: (n, 2) int32 [local_rank, owner]; sprank: (p,) int32."""
    n = packed.shape[0]
    p = sprank.shape[0]
    if n % block_n:
        raise ValueError(f"n={n} must be padded to a multiple of {block_n}")
    return pl.pallas_call(
        _agg_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((p,), lambda i: (0,)),  # whole table, every step
            pl.BlockSpec((block_n, 2), lambda i: (i, 0)),  # striding stream
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), sprank.dtype),
        interpret=interpret,
    )(sprank, packed)
