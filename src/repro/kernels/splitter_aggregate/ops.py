"""Public wrapper for the RS5 aggregation kernel (pads + dispatches)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret, on_tpu
from repro.kernels.splitter_aggregate.ref import splitter_aggregate_ref
from repro.kernels.splitter_aggregate.splitter_aggregate import (
    splitter_aggregate_pallas,
)


@partial(jax.jit, static_argnames=("impl", "block_n"))
def splitter_aggregate(
    packed: jax.Array,
    sprank: jax.Array,
    *,
    impl: str = "auto",
    block_n: int = 2048,
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if on_tpu() else "xla"
    if impl == "xla":
        return splitter_aggregate_ref(packed, sprank)
    n = packed.shape[0]
    pad = (-n) % block_n
    padded = jnp.pad(packed, ((0, pad), (0, 0)))  # owner 0 / local 0: harmless
    interpret = default_interpret() if impl == "pallas" else True
    out = splitter_aggregate_pallas(
        padded, sprank, block_n=block_n, interpret=interpret
    )
    return out[:n]
