from repro.kernels.splitter_aggregate.ops import splitter_aggregate

__all__ = ["splitter_aggregate"]
