from repro.kernels.edge_hook.ops import edge_hook

__all__ = ["edge_hook"]
