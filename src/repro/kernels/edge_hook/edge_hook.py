"""Fused SV hook kernel: gather labels -> compare -> min-scatter per edge tile.

The XLA lowering of the SV2/SV3 phases issues three separate gathers
(D[a], D[b], and the stagnant/root probe) plus a scatter per phase, each
a full HBM round trip over the label array. This kernel fuses the whole
hook into ONE pass per edge tile with the label array (and the Q stamp
array) pinned in VMEM across all grid steps -- the connected-components
analogue of the paper's "single thread block + __syncthreads" fast path
(guideline G4): the only HBM traffic is the streaming edge tiles.

Correctness note: every gather reads the *input* label block (the
pre-scatter D the XLA phases gather from), while the min-scatters
accumulate into a separate output block across sequential grid steps.
min is associative/commutative and the Q stamp writes all carry the same
round number s, so the tiled accumulation is bit-identical to the
monolithic XLA scatter regardless of tile order.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _edge_hook_kernel(
    s_ref, a_ref, b_ref, lab_ref, prev_ref, q_ref, lab_out_ref, q_out_ref,
    *, mode: str, n: int,
):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        lab_out_ref[...] = lab_ref[...]
        q_out_ref[...] = q_ref[...]

    s = s_ref[0]
    a = a_ref[...]
    b = b_ref[...]
    D = lab_ref[...]  # read-only pre-scatter labels: all gathers hit VMEM
    Da = jnp.take(D, a, axis=0)
    Db = jnp.take(D, b, axis=0)

    if mode == "sv2":
        # Hook edges from trees that did NOT shrink onto smaller roots,
        # stamping the winning roots' activity in Q.
        stagnant_a = Da == jnp.take(prev_ref[...], a, axis=0)
        cond = jnp.logical_and(stagnant_a, Db < Da)
        tgt = jnp.where(cond, Da, n)
        lab_out_ref[...] = lab_out_ref[...].at[tgt].min(
            jnp.where(cond, Db, n), mode="drop"
        )
        # Same-value stamp s from every winner: duplicates commute.
        # repro-lint: disable=scatter-determinism
        q_out_ref[...] = q_out_ref[...].at[jnp.where(cond, Db, n)].set(
            s, mode="drop"
        )
    elif mode == "sv3":
        # Hook stagnant roots onto any neighboring tree (min-CRCW ties).
        Q = q_ref[...]
        root_a = jnp.take(D, Da, axis=0) == Da
        stagnant = jnp.take(Q, Da, axis=0) < s
        cond = stagnant & root_a & (Da != Db)
        tgt = jnp.where(cond, Da, n)
        lab_out_ref[...] = lab_out_ref[...].at[tgt].min(
            jnp.where(cond, Db, n), mode="drop"
        )
    else:
        raise ValueError(f"unknown mode {mode!r}")


def edge_hook_pallas(
    a: jax.Array,
    b: jax.Array,
    labels: jax.Array,
    labels_prev: jax.Array,
    stamps: jax.Array,
    s: jax.Array,
    *,
    mode: str,
    block_e: int = 8192,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """One fused hook phase. a/b must be padded to a block_e multiple
    with inert (0, 0) self-loops. Returns (labels_out, stamps_out);
    stamps pass through untouched for mode="sv3"."""
    m = a.shape[0]
    n = labels.shape[0]
    if m % block_e:
        raise ValueError(f"m={m} must be padded to a multiple of {block_e}")
    kernel = functools.partial(_edge_hook_kernel, mode=mode, n=n)
    full = pl.BlockSpec((n,), lambda i: (0,))  # VMEM-resident, every step
    return pl.pallas_call(
        kernel,
        grid=(m // block_e,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_e,), lambda i: (i,)),  # streaming edge tiles
            pl.BlockSpec((block_e,), lambda i: (i,)),
            full,
            full,
            full,
        ],
        out_specs=[full, full],
        out_shape=[
            jax.ShapeDtypeStruct((n,), labels.dtype),
            jax.ShapeDtypeStruct((n,), stamps.dtype),
        ],
        interpret=interpret,
    )(jnp.reshape(s, (1,)).astype(jnp.int32), a, b, labels, labels_prev, stamps)
