"""Public wrapper: fused SV hook with automatic path choice.

``impl="auto"`` fuses on a real TPU whenever the label + stamp arrays
fit VMEM (same small/large split as ``kernels/pointer_jump``) and falls
back to the unfused XLA phases elsewhere; ``"pallas_interpret"`` runs
the kernel body as plain JAX ops for CPU validation.

The kernel is **shard-local by construction**: it reads only the edge
arrays it is handed and the replicated label/stamp state, so the
sharded frontier engine (``distributed/graph``, ``hook_impl=``) runs it
unchanged inside ``shard_map`` -- each device fuses the hook phases
over its own compacted edge bucket, and the per-round label exchanges
see identical arrays either way. The VMEM budget is per device, so
``VMEM_NODE_LIMIT`` needs no mesh scaling.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret, on_tpu
from repro.kernels.edge_hook.edge_hook import edge_hook_pallas
from repro.kernels.edge_hook.ref import edge_hook_ref

# Two int32 arrays (labels + stamps) resident plus streaming tiles; half
# the pointer_jump budget keeps headroom for the edge tiles.
VMEM_NODE_LIMIT = 1 << 19


@partial(jax.jit, static_argnames=("mode", "impl", "block_e"))
def edge_hook(
    a: jax.Array,
    b: jax.Array,
    labels: jax.Array,
    stamps: jax.Array,
    s: jax.Array,
    *,
    labels_prev: jax.Array | None = None,
    mode: str = "sv2",
    impl: str = "auto",
    block_e: int = 8192,
) -> tuple[jax.Array, jax.Array]:
    """Fused hook phase over all edges. Returns (labels_out, stamps_out).

    ``labels_prev`` (the pre-shortcut labels) is required for mode="sv2"
    (the stagnant-tree check); mode="sv3" ignores it.
    """
    n = labels.shape[0]
    prev = labels_prev if labels_prev is not None else labels
    if impl == "auto":
        impl = "pallas" if (on_tpu() and n <= VMEM_NODE_LIMIT) else "xla"
    if impl == "xla":
        return edge_hook_ref(a, b, labels, prev, stamps, s, mode=mode)
    if impl not in ("pallas", "pallas_interpret"):
        raise ValueError(f"unknown impl {impl!r}")
    interpret = default_interpret() if impl == "pallas" else True
    m = a.shape[0]
    pad = (-m) % block_e if m else block_e
    # (0, 0) self-loop padding is inert under both hook conditions.
    a = jnp.concatenate([a.astype(jnp.int32), jnp.zeros(pad, jnp.int32)])
    b = jnp.concatenate([b.astype(jnp.int32), jnp.zeros(pad, jnp.int32)])
    return edge_hook_pallas(
        a, b, labels, prev, stamps, s,
        mode=mode, block_e=block_e, interpret=interpret,
    )
