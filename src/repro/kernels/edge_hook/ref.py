"""Pure-jnp oracle for the edge_hook kernel (the unfused SV2/SV3 phases)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def edge_hook_ref(
    a: jax.Array,
    b: jax.Array,
    labels: jax.Array,
    labels_prev: jax.Array,
    stamps: jax.Array,
    s: jax.Array,
    *,
    mode: str,
) -> tuple[jax.Array, jax.Array]:
    n = labels.shape[0]
    Da, Db = labels[a], labels[b]
    if mode == "sv2":
        stagnant_a = Da == labels_prev[a]
        cond = jnp.logical_and(stagnant_a, Db < Da)
        tgt = jnp.where(cond, Da, n)
        out = labels.at[tgt].min(jnp.where(cond, Db, n), mode="drop")
        # Same-value stamp s from every winner: duplicates commute.
        q = stamps.at[jnp.where(cond, Db, n)].set(s, mode="drop")  # repro-lint: disable=scatter-determinism
        return out, q
    if mode == "sv3":
        root_a = labels[Da] == Da
        stagnant = stamps[Da] < s
        cond = stagnant & root_a & (Da != Db)
        tgt = jnp.where(cond, Da, n)
        out = labels.at[tgt].min(jnp.where(cond, Db, n), mode="drop")
        return out, stamps
    raise ValueError(f"unknown mode {mode!r}")
