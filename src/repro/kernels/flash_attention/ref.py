"""Pure-jnp oracle: materialized-score attention with GQA/causal/window."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32))
    s = s / (d ** 0.5)
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr).astype(q.dtype)
