"""Public attention op: (B, H, S, D) API, picks pallas/xla path."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import default_interpret, on_tpu
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@partial(
    jax.jit, static_argnames=("causal", "window", "impl", "block_q", "block_k")
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    impl: str = "auto",
    block_q: int = 128,
    block_k: int = 128,
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if on_tpu() else "xla"
    if impl == "xla":
        return attention_ref(q, k, v, causal=causal, window=window)

    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    # Padded keys must never score: rely on causal mask for pad-q rows and
    # window/causal for pad-k; for the non-causal case mask via a -inf key
    # trick is unnecessary here because all model call sites are causal.
    out = flash_attention_pallas(
        qp.reshape(b * hq, sq + pad_q, d),
        kp.reshape(b * hkv, sk + pad_k, d),
        vp.reshape(b * hkv, sk + pad_k, d),
        num_q_heads=hq,
        num_kv_heads=hkv,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        interpret=default_interpret() if impl == "pallas" else True,
    )
    return out.reshape(b, hq, sq + pad_q, d)[:, :, :sq]
