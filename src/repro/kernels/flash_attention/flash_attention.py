"""Blocked online-softmax attention (FlashAttention-style) for the LM archs.

VMEM tiling: each grid step holds one (block_q, d) query tile, one
(block_k, d) key tile and value tile; the (block_q, block_k) score tile is
the only quadratic intermediate and it never leaves VMEM. Accumulators
(m, l, acc) live in VMEM scratch across the kj grid axis.

Supports causal masking, sliding windows (Mixtral SWA; window w => score
kept iff 0 <= qpos - kpos < w), and GQA via the kv index_map (query head h
reads kv head h // group -- no materialized KV repetition in HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

    qpos = qi * block_q + jax.lax.iota(jnp.int32, block_q)[:, None]
    kpos = kj * block_k + jax.lax.iota(jnp.int32, block_k)[None, :]
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = alpha * acc_prev + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(kj == num_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_new, 1e-30)  # fully masked rows -> zeros
        o_ref[0] = (acc_new / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B*Hq, Sq, D)
    k: jax.Array,  # (B*Hkv, Sk, D)
    v: jax.Array,  # (B*Hkv, Sk, D)
    *,
    num_q_heads: int,
    num_kv_heads: int,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    if sq % block_q or sk % block_k:
        raise ValueError("pad sequence lengths to the block sizes")
    group = num_q_heads // num_kv_heads
    n_q, n_k = sq // block_q, sk // block_k
    scale = 1.0 / (d ** 0.5)

    def kv_index(bhi, qi, kj):
        b = bhi // num_q_heads
        h = bhi % num_q_heads
        return (b * num_kv_heads + h // group, kj, 0)

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=n_k,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, qi, kj: (b, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, qi, kj: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
