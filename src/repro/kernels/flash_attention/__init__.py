from repro.kernels.flash_attention.ops import flash_attention

__all__ = ["flash_attention"]
