"""Paper Figure 6: SV rounds per graph family (list k=1, trees k=2..20,
random d in {0.001, 0.01}) at fixed edge count, plus Table 4's per-kernel
global read/write counts (analytic, per round)."""
from __future__ import annotations

from benchmarks.common import SCALE, emit
from repro.core import shiloach_vishkin, sv_round_bound
from repro.ops.kiss import list_graph, random_graph, tree_graph


def table4_counts(n: int, m: int, p: int) -> dict[str, dict[str, float]]:
    """Paper Table 4 (global reads/writes per kernel per round)."""
    return {
        "SV0": {"reads": 0, "writes": 2 * n},
        "SV1a": {"reads": 2 * n, "writes": n},
        "SV1b": {"reads": 2 * n, "writes": n},
        "SV2": {"reads": 4 * m, "writes": 2 * n},
        "SV3": {"reads": 5 * m, "writes": n},
        "SV4": {"reads": 2 * n, "writes": n},
        "SV5": {"reads": n, "writes": p},
    }


def run(m_target: int | None = None) -> list[str]:
    m_target = m_target or int(400_000 * SCALE)
    lines = []
    cases = {"list-k1": list_graph(m_target + 4, 4, seed=1)}
    for k in (2, 3, 8, 20):
        cases[f"tree-k{k}"] = tree_graph(m_target + 1, k, seed=k)
    n_rand = int((2 * m_target / 0.001) ** 0.5)
    cases["random-d0.001"] = random_graph(n_rand, 0.001, seed=5)
    n_rand2 = int((2 * m_target / 0.01) ** 0.5)
    cases["random-d0.01"] = random_graph(n_rand2, 0.01, seed=6)

    rounds_by_family = {}
    for fam, edges in cases.items():
        n = int(edges.max()) + 1
        _, rounds = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
        rounds_by_family[fam] = int(rounds)
        counts = table4_counts(n, len(edges), 4096)
        total_rw = sum(c["reads"] + c["writes"] for c in counts.values())
        lines.append(
            emit(
                f"fig6/rounds/{fam}",
                float(rounds),
                f"n={n};m={len(edges)};bound={sv_round_bound(n)};"
                f"rw_per_round={total_rw}",
            )
        )
    # paper claim: random graphs need fewer rounds than trees/lists
    assert rounds_by_family["random-d0.01"] <= rounds_by_family["tree-k3"]
    return lines


if __name__ == "__main__":
    run()
