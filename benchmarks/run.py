# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV lines (benchmarks/common.emit).
#
#   python benchmarks/run.py                         # full sweep
#   python benchmarks/run.py --smoke                 # n <= 4096 compile check
#   python benchmarks/run.py --only cc_frontier,fig4_cc --json BENCH_cc.json
#   python benchmarks/run.py --smoke --check BENCH_smoke.json
#
# --json writes the emitted lines as a perf snapshot: a list of
# {suite, name, us_per_call, derived} records, so the repo's perf
# trajectory is diffable commit over commit.
#
# --check SNAPSHOT is the regression guard: it re-runs the snapshot's
# suites (unless --only narrows them) and compares every numeric
# ``key=value`` counter in the ``derived`` fields -- edge visits,
# exchange words, rounds, tree/arc counts -- against the snapshot
# within --check-tol relative tolerance. Wall times are never compared
# (CI machines vary); the counters are deterministic at a given scale,
# so the snapshot must have been produced at the same scale flags
# (CI checks a --smoke snapshot). A snapshot record whose (suite, name)
# is missing from the fresh run fails the check too: losing a counter
# silently is itself a regression.
from __future__ import annotations

import argparse
import json
import os
import traceback

SMOKE_SCALE = "0.005"  # largest suite base is 800_000 -> n=4000 caps the
# smoke lane at n <= 4096 while still compile-checking every perf path


def _parse_line(suite: str, line: str) -> dict:
    name, us, derived = line.split(",", 2)
    return {
        "suite": suite,
        "name": name,
        "us_per_call": float(us),
        "derived": derived,
    }


def _derived_counters(derived: str) -> dict:
    """Numeric key=value pairs from a derived field ("a=1;b=2.5;c=x").

    Keys starting with ``~`` (wall-time spread: ``~p10_us``/``~p90_us``
    from ``common.emit(..., spread=)``) are measurements, not
    deterministic counters -- they are excluded, so --check never
    compares them."""
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        k = k.strip()
        if k.startswith("~"):
            continue
        try:
            out[k] = float(v)
        except ValueError:
            continue
    return out


def check_records(
    snapshot: list[dict], fresh: list[dict], tol: float,
    suites_run: set[str] | None = None,
) -> list[str]:
    """Compare counters in ``fresh`` against ``snapshot``; returns a
    list of human-readable mismatch descriptions (empty = pass).
    Snapshot records from suites outside ``suites_run`` (an explicit
    --only narrowing) are skipped, not reported missing."""
    fresh_by_key = {(r["suite"], r["name"]): r for r in fresh}
    problems = []
    for rec in snapshot:
        if suites_run is not None and rec["suite"] not in suites_run:
            continue
        key = (rec["suite"], rec["name"])
        now = fresh_by_key.get(key)
        where = f"{rec['suite']}/{rec['name']}"
        if now is None:
            problems.append(
                f"{where}: record missing from fresh run"
                f"\n  snapshot derived: {rec['derived']}"
            )
            continue
        want = _derived_counters(rec["derived"])
        got = _derived_counters(now["derived"])
        for k, old in want.items():
            if k not in got:
                problems.append(
                    f"{where}: counter {k} disappeared "
                    f"(snapshot had {k}={old:g})"
                    f"\n  snapshot derived: {rec['derived']}"
                    f"\n  fresh    derived: {now['derived']}"
                )
                continue
            new = got[k]
            if abs(new - old) > tol * max(abs(old), 1.0):
                rel = (new - old) / abs(old) if old else float("inf")
                problems.append(
                    f"{where}: counter {k} expected {old:g}, got {new:g} "
                    f"(rel delta {rel:+.2%}, tol {tol:.0%})"
                    f"\n  snapshot derived: {rec['derived']}"
                    f"\n  fresh    derived: {now['derived']}"
                )
    return problems


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the emitted records as a JSON perf snapshot")
    ap.add_argument("--smoke", action="store_true",
                    help=f"tiny inputs (REPRO_BENCH_SCALE={SMOKE_SCALE}): "
                         "compile-check every perf path in CI minutes")
    ap.add_argument("--only", metavar="SUITES", default=None,
                    help="comma-separated suite subset to run")
    ap.add_argument("--check", metavar="SNAPSHOT", default=None,
                    help="compare fresh derived counters against this "
                         "snapshot (same scale!); implies --only the "
                         "snapshot's suites unless --only is given")
    ap.add_argument("--check-tol", type=float, default=0.05,
                    help="relative tolerance for --check counters "
                         "(default 0.05)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable repro.obs span tracing for the run and "
                         "write a Chrome-trace JSON here (inspect with "
                         "python -m repro.obs.summarize PATH)")
    args = ap.parse_args(argv)

    if args.smoke:  # must land before benchmarks.common reads the env
        os.environ["REPRO_BENCH_SCALE"] = SMOKE_SCALE

    if args.trace:
        from repro.obs import trace as obs_trace

        obs_trace.configure(trace="on")

    snapshot = None
    if args.check:
        with open(args.check) as f:
            snapshot = json.load(f)
        if args.only is None:
            args.only = ",".join(sorted({r["suite"] for r in snapshot}))

    from benchmarks import (
        cc_frontier,
        fig2_scaling,
        fig3_per_element,
        fig4_cc,
        fig5_parallelism,
        fig6_rounds,
        graph_serve,
        moe_dispatch,
        multidev_scaling,
        pagerank,
        roofline_table,
        serve_chaos,
        sssp_frontier,
        table2_packing,
        table3_splitters,
        tree_ops,
    )

    suites = [
        ("table2_packing", table2_packing.run),
        ("table3_splitters", table3_splitters.run),
        ("fig2_scaling", fig2_scaling.run),
        ("fig3_per_element", fig3_per_element.run),
        ("fig4_cc", fig4_cc.run),
        ("cc_frontier", cc_frontier.run),
        ("sssp_frontier", sssp_frontier.run),
        ("pagerank", pagerank.run),
        ("tree_ops", tree_ops.run),
        ("graph_serve", graph_serve.run),
        ("serve_chaos", serve_chaos.run),
        ("fig5_parallelism", fig5_parallelism.run),
        ("fig6_rounds", fig6_rounds.run),
        ("moe_dispatch", moe_dispatch.run),
        ("roofline_table", roofline_table.run),
        # reports this process's device count; run standalone for the
        # 8-fake-device scaling table (see module docstring)
        ("multidev_scaling", multidev_scaling.run),
    ]
    if args.only:
        wanted = {s.strip() for s in args.only.split(",")}
        unknown = wanted - {name for name, _ in suites}
        if unknown:
            raise SystemExit(f"unknown suites: {sorted(unknown)}")
        suites = [(name, fn) for name, fn in suites if name in wanted]

    print("name,us_per_call,derived")
    records, failures = [], []
    for name, fn in suites:
        print(f"# === {name} ===", flush=True)
        try:
            lines = fn() or []
            records.extend(_parse_line(name, ln) for ln in lines)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"# wrote {len(records)} records to {args.json}", flush=True)
    if args.trace:
        from repro.obs import trace as obs_trace

        n_events = obs_trace.export_chrome(args.trace)
        print(
            f"# wrote {n_events} trace events to {args.trace} "
            "(chrome://tracing / Perfetto; summarize with "
            f"python -m repro.obs.summarize {args.trace})",
            flush=True,
        )
    if snapshot is not None and not failures:
        ran = {name for name, _ in suites}
        problems = check_records(
            snapshot, records, args.check_tol, suites_run=ran
        )
        if problems:
            for p in problems:
                # continuation lines stay comment-prefixed so the
                # output remains a valid CSV-with-comments stream
                print("# CHECK FAIL " + p.replace("\n", "\n#"), flush=True)
            raise SystemExit(
                f"--check {args.check}: {len(problems)} counter "
                "regressions (see CHECK FAIL lines)"
            )
        compared = sum(r["suite"] in ran for r in snapshot)
        print(
            f"# check passed: {compared} records within "
            f"{args.check_tol:.0%} of {args.check}",
            flush=True,
        )
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")


if __name__ == "__main__":
    main()
