# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV lines (benchmarks/common.emit).
#
#   python benchmarks/run.py                         # full sweep
#   python benchmarks/run.py --smoke                 # n <= 4096 compile check
#   python benchmarks/run.py --only cc_frontier,fig4_cc --json BENCH_cc.json
#
# --json writes the emitted lines as a perf snapshot: a list of
# {suite, name, us_per_call, derived} records, so the repo's perf
# trajectory is diffable commit over commit.
from __future__ import annotations

import argparse
import json
import os
import traceback

SMOKE_SCALE = "0.005"  # largest suite base is 800_000 -> n=4000 caps the
# smoke lane at n <= 4096 while still compile-checking every perf path


def _parse_line(suite: str, line: str) -> dict:
    name, us, derived = line.split(",", 2)
    return {
        "suite": suite,
        "name": name,
        "us_per_call": float(us),
        "derived": derived,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the emitted records as a JSON perf snapshot")
    ap.add_argument("--smoke", action="store_true",
                    help=f"tiny inputs (REPRO_BENCH_SCALE={SMOKE_SCALE}): "
                         "compile-check every perf path in CI minutes")
    ap.add_argument("--only", metavar="SUITES", default=None,
                    help="comma-separated suite subset to run")
    args = ap.parse_args(argv)

    if args.smoke:  # must land before benchmarks.common reads the env
        os.environ["REPRO_BENCH_SCALE"] = SMOKE_SCALE

    from benchmarks import (
        cc_frontier,
        fig2_scaling,
        fig3_per_element,
        fig4_cc,
        fig5_parallelism,
        fig6_rounds,
        moe_dispatch,
        multidev_scaling,
        roofline_table,
        table2_packing,
        table3_splitters,
    )

    suites = [
        ("table2_packing", table2_packing.run),
        ("table3_splitters", table3_splitters.run),
        ("fig2_scaling", fig2_scaling.run),
        ("fig3_per_element", fig3_per_element.run),
        ("fig4_cc", fig4_cc.run),
        ("cc_frontier", cc_frontier.run),
        ("fig5_parallelism", fig5_parallelism.run),
        ("fig6_rounds", fig6_rounds.run),
        ("moe_dispatch", moe_dispatch.run),
        ("roofline_table", roofline_table.run),
        # reports this process's device count; run standalone for the
        # 8-fake-device scaling table (see module docstring)
        ("multidev_scaling", multidev_scaling.run),
    ]
    if args.only:
        wanted = {s.strip() for s in args.only.split(",")}
        unknown = wanted - {name for name, _ in suites}
        if unknown:
            raise SystemExit(f"unknown suites: {sorted(unknown)}")
        suites = [(name, fn) for name, fn in suites if name in wanted]

    print("name,us_per_call,derived")
    records, failures = [], []
    for name, fn in suites:
        print(f"# === {name} ===", flush=True)
        try:
            lines = fn() or []
            records.extend(_parse_line(name, ln) for ln in lines)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"# wrote {len(records)} records to {args.json}", flush=True)
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")


if __name__ == "__main__":
    main()
