# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV lines (benchmarks/common.emit).
from __future__ import annotations

import traceback


def main() -> None:
    from benchmarks import (
        fig2_scaling,
        fig3_per_element,
        fig4_cc,
        fig5_parallelism,
        fig6_rounds,
        moe_dispatch,
        multidev_scaling,
        roofline_table,
        table2_packing,
        table3_splitters,
    )

    suites = [
        ("table2_packing", table2_packing.run),
        ("table3_splitters", table3_splitters.run),
        ("fig2_scaling", fig2_scaling.run),
        ("fig3_per_element", fig3_per_element.run),
        ("fig4_cc", fig4_cc.run),
        ("fig5_parallelism", fig5_parallelism.run),
        ("fig6_rounds", fig6_rounds.run),
        ("moe_dispatch", moe_dispatch.run),
        ("roofline_table", roofline_table.run),
        # reports this process's device count; run standalone for the
        # 8-fake-device scaling table (see module docstring)
        ("multidev_scaling", multidev_scaling.run),
    ]
    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites:
        print(f"# === {name} ===", flush=True)
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")


if __name__ == "__main__":
    main()
