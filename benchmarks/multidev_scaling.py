"""Sharded graph engine scaling: runtime + per-round exchange volume vs
device count, for connected components and random-splitter list ranking.

Run standalone (forces 8 fake CPU host devices; must own the jax import):

    PYTHONPATH=src:. python benchmarks/multidev_scaling.py

or via benchmarks/run.py, where it reports whatever device count that
process already has. CSV columns: name,us_per_call,derived -- derived
holds rounds and the exchange-volume model (KiB sent per device)."""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":  # standalone: claim fake devices pre-jax-import
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8"
    )
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def run(n: int | None = None) -> list[str]:
    import jax

    from benchmarks.common import SCALE, emit, time_fn
    from repro.core import random_splitter_rank, shiloach_vishkin
    from repro.core.list_ranking import select_splitters
    from repro.data.graphs import random_succ
    from repro.distributed.graph import (
        cc_exchange_words_per_round,
        graph_mesh,
        rank_exchange_words,
        sharded_frontier_shiloach_vishkin,
        sharded_random_splitter_rank,
        sharded_shiloach_vishkin,
    )
    from repro.ops.kiss import random_graph

    n = n or int(20_000 * SCALE)
    edges = random_graph(n, 4.0 / n, seed=1)
    succ = random_succ(n, seed=0)
    p = min(512, n)
    spl = select_splitters(n, p, seed=0)

    lines = []
    ndev = jax.device_count()
    counts = [d for d in (1, 2, 4, 8) if d <= ndev]

    # single-device baselines
    t = time_fn(lambda: shiloach_vishkin(edges[:, 0], edges[:, 1], n)[0])
    _, rounds = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    lines.append(emit("cc_single", t * 1e6, f"rounds={int(rounds)};exKiB=0"))
    t = time_fn(lambda: random_splitter_rank(succ, splitters=spl))
    lines.append(emit("rank_single", t * 1e6, "exKiB=0"))

    for d in counts:
        mesh = graph_mesh(d)
        t = time_fn(
            lambda m=mesh: sharded_shiloach_vishkin(
                edges[:, 0], edges[:, 1], n, mesh=m
            )[0]
        )
        _, rounds = sharded_shiloach_vishkin(edges[:, 0], edges[:, 1], n, mesh=mesh)
        ex_kib = cc_exchange_words_per_round(n) * 4 / 1024
        lines.append(
            emit(
                f"cc_sharded_dev{d}",
                t * 1e6,
                f"rounds={int(rounds)};exKiB/round={ex_kib:.1f};"
                f"edges/dev={2 * len(edges) // d}",
            )
        )
        t = time_fn(
            lambda m=mesh: sharded_shiloach_vishkin(
                edges[:, 0], edges[:, 1], n, mesh=m, exchange="sparse"
            )[0]
        )
        _, _, st = sharded_shiloach_vishkin(
            edges[:, 0], edges[:, 1], n, mesh=mesh, exchange="sparse",
            with_stats=True,
        )
        w = cc_exchange_words_per_round(n, stats=st)
        lines.append(
            emit(
                f"cc_sharded_sparse_dev{d}",
                t * 1e6,
                f"capacity={st.capacity};wordsR1={int(w[0])};"
                f"wordsLast={int(w[-1])};denseWords={3 * n}",
            )
        )
        # min_bucket=64 keeps the bucket ladder active at smoke scale
        # too, so the guarded per-device visit counters exercise real
        # compaction in CI, not just the single-level fast path.
        t = time_fn(
            lambda m=mesh: sharded_frontier_shiloach_vishkin(
                edges[:, 0], edges[:, 1], n, mesh=m, min_bucket=64
            )[0]
        )
        _, _, stf = sharded_frontier_shiloach_vishkin(
            edges[:, 0], edges[:, 1], n, mesh=mesh, min_bucket=64,
            with_stats=True,
        )
        # per-DEVICE edge-slot visits vs the dense sharded walk's
        # 2 * ceil(m2/nd) * rounds -- the tentpole's work-compaction win
        dense_per_dev = 2 * (-(-stf.m2 // d)) * stf.rounds
        lines.append(
            emit(
                f"cc_sharded_frontier_dev{d}",
                t * 1e6,
                f"rounds={stf.rounds};edgesTouched/dev={stf.edges_touched};"
                f"denseTouched/dev={dense_per_dev};"
                f"levels={len(stf.levels)};"
                f"wordsLast={int(stf.words_per_round[-1])}",
            )
        )
        t = time_fn(
            lambda m=mesh: sharded_random_splitter_rank(
                succ, splitters=spl, mesh=m
            )
        )
        ex_kib = rank_exchange_words(n, p, d) * 4 / 1024
        lines.append(
            emit(
                f"rank_sharded_dev{d}",
                t * 1e6,
                f"exKiB={ex_kib:.1f};lanes/dev={-(-p // d)}",
            )
        )
    return lines


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
