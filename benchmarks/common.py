"""Shared benchmark helpers."""
from __future__ import annotations

import os
import time

import jax
import numpy as np

# CPU-host benchmarks reproduce the paper's TRENDS (work complexity, packing
# A/B, splitter distributions), not GPU milliseconds. SCALE=1 keeps runs
# minutes-fast; raise REPRO_BENCH_SCALE for larger sweeps.
SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1"))


class TimingResult(float):
    """Median wall seconds, carrying the run's spread.

    A ``float`` subclass (the float value IS the median) so every
    arithmetic call site -- ``t * 1e6``, ``t / n`` -- keeps working
    unchanged; ``p10``/``p90`` ride along for ``emit(..., spread=)``.
    """

    __slots__ = ("p10", "p90")

    def __new__(cls, median: float, p10: float, p90: float):
        self = super().__new__(cls, median)
        self.p10 = float(p10)
        self.p90 = float(p90)
        return self


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> TimingResult:
    """Median wall seconds over `iters` calls (blocking on outputs),
    as a ``TimingResult`` carrying the p10/p90 spread."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    p10, p90 = np.percentile(times, [10, 90])
    return TimingResult(float(np.median(times)), p10, p90)


def emit(
    name: str,
    us_per_call: float,
    derived: str = "",
    spread: tuple[float, float] | None = None,
) -> str:
    """Print one ``name,us_per_call,derived`` CSV line.

    ``spread`` appends the timing spread as ``~p10_us``/``~p90_us``
    counters (values in microseconds, pre-scaled by the caller like
    ``us_per_call`` itself). The ``~`` prefix marks them as wall-time:
    ``benchmarks/run.py --check`` never compares ``~`` keys, so the
    spread can ride in ``derived`` without breaking snapshot pinning.
    """
    if spread is not None:
        frag = f"~p10_us={spread[0]:.1f};~p90_us={spread[1]:.1f}"
        derived = f"{derived};{frag}" if derived else frag
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line
