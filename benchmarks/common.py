"""Shared benchmark helpers."""
from __future__ import annotations

import os
import time

import jax
import numpy as np

# CPU-host benchmarks reproduce the paper's TRENDS (work complexity, packing
# A/B, splitter distributions), not GPU milliseconds. SCALE=1 keeps runs
# minutes-fast; raise REPRO_BENCH_SCALE for larger sweeps.
SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1"))


def time_fn(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds over `iters` calls (blocking on outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line
