"""Euler-tour tree analytics: forest -> tour -> batched computations.

Sweeps the three tree-workload shapes the subsystem targets -- one big
random tree (list ranking dominates), a path (worst-case depth, the
regime where the paper's list-ranking engines matter most), and a
molecule-batch-style forest of many small trees served as ONE padded
tour (the concurrent small-graph-requests scenario) -- and reports wall
time per stage plus deterministic structure counters (trees, arcs,
max depth) that double as regression-guard material for
``run.py --check``. The compute stage runs on BOTH ranking engines;
their counters must agree (the results are bit-identical integers).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, emit, time_fn
from repro.data.graphs import random_tree, random_tree_forest
from repro.trees import (
    euler_tour,
    spanning_forest,
    tour_capacity,
    tree_computations,
)


def _families(n):
    path = np.stack(
        [np.arange(n - 1, dtype=np.int32),
         np.arange(1, n, dtype=np.int32)], axis=1
    )
    return {
        "one-tree": random_tree(n, seed=1),
        "path": path,
        "molecule-batch": random_tree_forest(n, max(2, n // 30), seed=2),
    }


def run(n: int | None = None) -> list[str]:
    n = n or int(200_000 * SCALE)
    lines = []
    for fam, edges in _families(n).items():
        u, v = edges[:, 0], edges[:, 1]
        t_forest = time_fn(
            lambda: spanning_forest(u, v, n).labels, iters=2
        )
        forest = spanning_forest(u, v, n)
        lines.append(
            emit(
                f"tree_ops/forest/{fam}/n={n}",
                t_forest * 1e6,
                f"trees={forest.num_trees};edges={forest.num_edges}",
                spread=(t_forest.p10 * 1e6, t_forest.p90 * 1e6),
            )
        )
        cap = tour_capacity(forest.num_edges)
        t_tour = time_fn(
            lambda: euler_tour(
                forest.edge_u, forest.edge_v, n,
                labels=forest.labels, pad_to=cap,
            ).succ,
            iters=2,
        )
        tour = euler_tour(
            forest.edge_u, forest.edge_v, n,
            labels=forest.labels, pad_to=cap,
        )
        lines.append(
            emit(
                f"tree_ops/tour/{fam}/n={n}",
                t_tour * 1e6,
                f"arcs={tour.num_arcs};capacity={tour.capacity}",
                spread=(t_tour.p10 * 1e6, t_tour.p90 * 1e6),
            )
        )
        for engine in ("wylie", "splitter"):
            t_comp = time_fn(
                lambda: tree_computations(tour, rank_engine=engine).depth,
                iters=2,
            )
            comp = tree_computations(tour, rank_engine=engine)
            max_depth = int(np.max(np.asarray(comp.depth))) if n else 0
            total_size = int(np.sum(np.asarray(comp.subtree_size)))
            lines.append(
                emit(
                    f"tree_ops/compute/{fam}/{engine}/n={n}",
                    t_comp * 1e6,
                    f"max_depth={max_depth};size_sum={total_size};"
                    f"arcs={tour.num_arcs}",
                    spread=(t_comp.p10 * 1e6, t_comp.p90 * 1e6),
                )
            )
    return lines


if __name__ == "__main__":
    run()
