"""PageRank engines: frontier tolerance loop vs dense fixed schedule.

The ADD-monoid proof suite for ``core/operators.py``: PageRank is one
``advance`` + one ``compute`` + the shared ``run_rebuild_loop`` driver
(``core/pagerank.py``), and this sweep pins its work accounting per
graph family. Unlike the MIN-monoid engines an ADD frontier never
compacts -- every contribution is part of the sum -- so both engines
touch all ``m2`` oriented arcs every iteration (``edges_touched ==
m2 * (iterations + 1)``, degree pass included) and the interesting
counter is the ITERATION count: the frontier engine's host tolerance
loop stops as soon as no node moves more than ``tol``, while the dense
engine (the serve path's, one compile, zero per-iteration syncs) runs
the analytic worst-case schedule ``pagerank_iter_bound()`` regardless.

A parity record pins the bit-exactness contract as counters: the dense
fixed schedule cut to the frontier's observed iteration count and the
``serial_pagerank`` NumPy oracle must both match the frontier scores
bit-for-bit (``dense_match=1;oracle_match=1``). All counters are
deterministic and guarded by ``run.py --check``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, emit, time_fn
from repro.core import pagerank, pagerank_iter_bound
from repro.core.serial import serial_pagerank
from repro.ops.kiss import giant_dust_graph, list_graph, random_graph


def _star(n):
    return np.stack(
        [np.zeros(n - 1, np.int32), np.arange(1, n, dtype=np.int32)],
        axis=1,
    )


def _families(n):
    # the frontier engine host-syncs once per iteration and the
    # iteration count is damping-bound (not diameter-bound), so no
    # family needs the BF-style diameter cap -- but giant+dust and
    # chain keep the sssp_frontier caps so the two sweeps stay
    # comparable family for family
    gd = min(n, 1000)
    ch = min(n, 512)
    return {
        "giant+dust": (gd, giant_dust_graph(gd, 0.9, seed=1)),
        "star": (n, _star(n)),
        "random": (n, random_graph(n, 2.0 / max(n - 1, 1), seed=2)),
        "chain": (ch, list_graph(ch, 1, seed=3)),
    }


def _weights(edges, salt=0):
    r = np.random.default_rng(100 + salt)
    return (r.integers(0, 8, size=len(edges)) / 4.0).astype(np.float32)


def run(n: int | None = None) -> list[str]:
    n = n or int(800_000 * SCALE)
    bound = pagerank_iter_bound()
    lines = []
    for fam, (nf, edges) in _families(n).items():
        src, dst = edges[:, 0], edges[:, 1]
        w = _weights(edges)
        t_front = time_fn(
            lambda: pagerank(src, dst, w, nf, engine="frontier")[0],
            iters=2,
        )
        _, _, fstats = pagerank(
            src, dst, w, nf, engine="frontier", with_stats=True
        )
        lines.append(emit(
            f"pagerank/frontier/{fam}/n={nf}",
            t_front * 1e6,
            f"iters={fstats.iterations};"
            f"edges_touched={fstats.edges_touched};m2={fstats.m2};"
            f"iter_bound={bound}",
            spread=(t_front.p10 * 1e6, t_front.p90 * 1e6),
        ))
        t_dense = time_fn(
            lambda: pagerank(src, dst, w, nf, engine="dense")[0], iters=2
        )
        _, _, dstats = pagerank(
            src, dst, w, nf, engine="dense", with_stats=True
        )
        lines.append(emit(
            f"pagerank/dense/{fam}/n={nf}",
            t_dense * 1e6,
            f"iters={dstats.iterations};"
            f"edges_touched={dstats.edges_touched}",
            spread=(t_dense.p10 * 1e6, t_dense.p90 * 1e6),
        ))

    # bit-exact parity pinned as counters (capped: the oracle's
    # np.add.at walk is serial host work, not part of the sweep)
    nf = min(n, 4096)
    edges = random_graph(nf, 2.0 / max(nf - 1, 1), seed=2)
    src, dst = edges[:, 0], edges[:, 1]
    w = _weights(edges, salt=1)
    sc_f, it_f = pagerank(src, dst, w, nf, engine="frontier")
    k = int(it_f)
    sc_d, _ = pagerank(src, dst, w, nf, engine="dense", num_iters=k)
    sc_o = serial_pagerank(
        np.stack([np.asarray(src), np.asarray(dst)], axis=1),
        w, nf, num_iters=k,
    )
    lines.append(emit(
        f"pagerank/parity/random/n={nf}",
        0.0,
        f"iters={k};"
        f"dense_match={int(np.array_equal(np.asarray(sc_f), np.asarray(sc_d)))};"
        f"oracle_match={int(np.array_equal(np.asarray(sc_f), sc_o))}",
    ))
    return lines


if __name__ == "__main__":
    run()
