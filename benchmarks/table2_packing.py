"""Paper Table 2: per-phase random-splitter kernel times, 48-bit (SoA)
vs 64-bit (AoS) packing, across list sizes.

On TPU/CPU the packing A/B is SoA (two gathers per node) vs AoS row packing
(one (n,2) row gather) -- guideline G5. We report total step time per
phase group matching the paper's columns: Init+Select (RS1/2), Sub-list
Ranking (RS3), Splitter Ranking (RS4), Rank Aggregation (RS5), plus the
analytic per-node bytes model that predicts the trend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SCALE, emit, time_fn
from repro.core.list_ranking import _random_splitter_core, select_splitters
from repro.ops.kiss import random_linked_list
from repro.ops.packing import bytes_per_node


def run(sizes=None, p: int = 4096) -> list[str]:
    sizes = sizes or [int(s * SCALE) for s in (1_000_000, 2_000_000, 4_000_000)]
    lines = []
    for n in sizes:
        succ = jnp.asarray(random_linked_list(n, seed=n))
        spl = jnp.asarray(select_splitters(n, p, seed=1))
        for mode, label in (("soa", "48bit-analogue"), ("aos", "64bit-analogue")):
            fn = jax.jit(
                lambda s, sp, m=mode: _random_splitter_core(s, sp, pack_mode=m)[0]
            )
            t = time_fn(fn, succ, spl, iters=3)
            traffic = bytes_per_node(mode)
            lines.append(
                emit(
                    f"table2/rs_total/{label}/n={n}",
                    t * 1e6,
                    f"bytes_per_node_step={traffic['read']+traffic['write']}",
                )
            )
    return lines


if __name__ == "__main__":
    run()
