"""Frontier vs dense Bellman-Ford: edge-relaxation visits per family.

The SSSP analogue of ``cc_frontier``: sweeps graph families where the
frontier advances through a shrinking (or never-large) active set and
reports, per family: wall time for both engines, total relax-slot
visits (``SsspStats.relax_visits`` vs the dense engine's ``m2 *
rounds`` -- every oriented edge every round), the visit-reduction
ratio, and the frontier engine's extra full-list mask gathers
(``mask_visits``; unlike CC the compaction is per-level, see
``core/sssp.py``). Low-diameter families (giant+dust, star, random)
converge in a handful of levels, so the frontier engine relaxes a
small multiple of m2 while dense pays m2 per round; the chain family
(capped: level-synchronous BF is O(diameter) rounds, the paper's
worst case) shows the extreme -- a constant-size advancing front vs a
full dense sweep per round. A batched multi-source line pins the
shared-compile row count. All counters are deterministic and guarded
by ``run.py --check``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, emit, time_fn
from repro.core import bellman_ford, frontier_bellman_ford
from repro.ops.kiss import giant_dust_graph, list_graph, random_graph


def _star(n):
    return np.stack(
        [np.zeros(n - 1, np.int32), np.arange(1, n, dtype=np.int32)],
        axis=1,
    )


def _families(n):
    # level-synchronous BF runs O(weighted-hop-diameter) host-synced
    # levels, so the high-diameter families are capped at an absolute
    # size (their round count IS their size; scaling them up only
    # scales the host loop, not the device work the sweep measures)
    gd = min(n, 1000)
    ch = min(n, 512)
    return {
        "giant+dust": (gd, giant_dust_graph(gd, 0.9, seed=1)),
        "star": (n, _star(n)),
        "random": (n, random_graph(n, 2.0 / max(n - 1, 1), seed=2)),
        "chain": (ch, list_graph(ch, 1, seed=3)),
    }


def _weights(edges, salt=0):
    r = np.random.default_rng(100 + salt)
    return (r.integers(0, 8, size=len(edges)) / 4.0).astype(np.float32)


def run(n: int | None = None) -> list[str]:
    n = n or int(800_000 * SCALE)
    lines = []
    for fam, (nf, edges) in _families(n).items():
        src, dst = edges[:, 0], edges[:, 1]
        w = _weights(edges)
        t_dense = time_fn(
            lambda: bellman_ford(src, dst, w, nf)[0], iters=2
        )
        _, _, _, dstats = bellman_ford(src, dst, w, nf, with_stats=True)
        # min_bucket=64: the default floor (1024) exceeds m2 at smoke
        # scale, which would silently degrade frontier to dense
        t_front = time_fn(
            lambda: frontier_bellman_ford(src, dst, w, nf, min_bucket=64)[0],
            iters=2,
        )
        _, _, _, fstats = frontier_bellman_ford(
            src, dst, w, nf, min_bucket=64, with_stats=True
        )
        ratio = dstats.relax_visits / max(fstats.relax_visits, 1)
        lines.append(emit(
            f"sssp_frontier/dense/{fam}/n={nf}",
            t_dense * 1e6,
            f"rounds={dstats.rounds};relax_visits={dstats.relax_visits};"
            f"m2={dstats.m2}",
            spread=(t_dense.p10 * 1e6, t_dense.p90 * 1e6),
        ))
        lines.append(emit(
            f"sssp_frontier/frontier/{fam}/n={nf}",
            t_front * 1e6,
            f"rounds={fstats.rounds};relax_visits={fstats.relax_visits};"
            f"mask_visits={fstats.mask_visits};"
            f"visit_ratio={ratio:.2f};levels={len(fstats.levels)}",
            spread=(t_front.p10 * 1e6, t_front.p90 * 1e6),
        ))

    # batched multi-source: S rows share one padded compile; visits
    # count buffer slots (row-batched), so they match the solo run
    nf, edges = _families(n)["random"]
    src, dst = edges[:, 0], edges[:, 1]
    w = _weights(edges)
    srcs = np.arange(4, dtype=np.int32) % nf
    t_batch = time_fn(
        lambda: bellman_ford(src, dst, w, nf, sources=srcs)[0], iters=2
    )
    _, _, _, bstats = bellman_ford(
        src, dst, w, nf, sources=srcs, with_stats=True
    )
    lines.append(emit(
        f"sssp_frontier/batched/random/n={nf}/S={len(srcs)}",
        t_batch * 1e6,
        f"rounds={bstats.rounds};relax_visits={bstats.relax_visits};"
        f"num_sources={bstats.num_sources}",
        spread=(t_batch.p10 * 1e6, t_batch.p90 * 1e6),
    ))
    return lines


if __name__ == "__main__":
    run()
