"""Frontier-compacted CC vs dense SV: total edge visits per graph family.

Sweeps the skewed-component families where frontier compaction pays
(one-giant-plus-dust, forest of small components, a single chain) and
reports, per family: wall time for both engines, total edge-slot visits
(``FrontierStats.edges_touched`` vs the dense engine's ``2m * rounds``
-- two hook passes per round, per the paper's Table 4 accounting), and
the visit-reduction ratio. The one-giant-plus-dust family is the
headline: the giant's edges all die within a few rounds of its labels
coalescing, so dense SV re-walks dead work for the whole O(log n) tail
while the frontier engine's buffer collapses geometrically (>= 5x fewer
visits at default scale). Also prints an Afforest pre-pass column
(``sample_rounds=2``) for the same families.
"""
from __future__ import annotations

from benchmarks.common import SCALE, emit, time_fn
from repro.core import frontier_shiloach_vishkin, shiloach_vishkin
from repro.ops.kiss import giant_dust_graph, list_graph


def _families(n):
    return {
        "giant+dust": giant_dust_graph(n, 0.9, seed=1),
        "forest-small": list_graph(n, max(2, n // 64), seed=2),
        "chain": list_graph(n, 1, seed=3),
    }


def run(n: int | None = None) -> list[str]:
    # The visit ratio is asymptotic (dense pays 2m per round for an
    # O(log n) round count; frontier passes stay ~constant per edge), so
    # the default sits in the regime the paper targets.
    n = n or int(800_000 * SCALE)
    lines = []
    for fam, edges in _families(n).items():
        src, dst = edges[:, 0], edges[:, 1]
        t_dense = time_fn(lambda: shiloach_vishkin(src, dst, n)[0], iters=2)
        _, rounds = shiloach_vishkin(src, dst, n)
        t_front = time_fn(
            lambda: frontier_shiloach_vishkin(src, dst, n)[0], iters=2
        )
        _, _, st = frontier_shiloach_vishkin(src, dst, n, with_stats=True)
        dense_visits = 2 * st.m2 * int(rounds)
        ratio = dense_visits / max(st.edges_touched, 1)
        lines.append(
            emit(
                f"cc_frontier/dense/{fam}/n={n}",
                t_dense * 1e6,
                f"rounds={int(rounds)};edges_touched={dense_visits}",
                spread=(t_dense.p10 * 1e6, t_dense.p90 * 1e6),
            )
        )
        lines.append(
            emit(
                f"cc_frontier/frontier/{fam}/n={n}",
                t_front * 1e6,
                f"rounds={st.rounds};edges_touched={st.edges_touched};"
                f"visit_ratio={ratio:.2f};levels={len(st.levels)}",
                spread=(t_front.p10 * 1e6, t_front.p90 * 1e6),
            )
        )
        _, _, sta = frontier_shiloach_vishkin(
            src, dst, n, sample_rounds=2, with_stats=True
        )
        lines.append(
            emit(
                f"cc_frontier/afforest/{fam}/n={n}",
                0.0,
                f"edges_touched={sta.edges_touched};"
                f"giant_frac={sta.largest_component_frac:.2f};"
                f"live_after_sample={sta.live_after_sample}",
            )
        )
    return lines


if __name__ == "__main__":
    run()
