"""Beyond-paper A/B: sorted (coalesced) vs unsorted MoE token dispatch.

Guideline G1 applied at the model level: identical semantics, different
memory pattern. Reports wall time and the one-hot-cumsum overhead the
unsorted baseline pays."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import SCALE, emit, time_fn
from repro.models.transformer import MoEConfig, TransformerConfig
from repro.models.transformer.moe import init_moe_params, moe_ffn_local


def run(tokens: int | None = None) -> list[str]:
    tokens = tokens or int(16384 * SCALE)
    cfg_base = TransformerConfig(
        name="bench", num_layers=1, d_model=512, num_heads=8, num_kv_heads=8,
        head_dim=64, d_ff=2048, vocab_size=32,
        moe=MoEConfig(num_experts=64, top_k=4, d_ff_expert=512),
        dtype="float32", remat=False,
    )
    params = init_moe_params(jax.random.PRNGKey(0), cfg_base, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, tokens, 512), jnp.float32)
    lines = []
    times = {}
    for dispatch in ("sorted_ep", "unsorted"):
        cfg = dataclasses.replace(
            cfg_base, moe=dataclasses.replace(cfg_base.moe, dispatch=dispatch)
        )
        fn = jax.jit(lambda p, x, c=cfg: moe_ffn_local(p, c, x, jax.nn.silu))
        t = time_fn(fn, params, x, iters=3)
        times[dispatch] = t
        lines.append(emit(f"moe_dispatch/{dispatch}/T={tokens}", t * 1e6, ""))
    lines.append(
        emit(
            "moe_dispatch/sorted_speedup",
            times["unsorted"] / times["sorted_ep"],
            "x_vs_unsorted",
        )
    )
    return lines


if __name__ == "__main__":
    run()
