"""Render the 40-cell roofline table from results/dryrun.json (the §Roofline
deliverable's data source). Emits one CSV line per (arch, shape, mesh)."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit


def run(path: str = "results/dryrun.json") -> list[str]:
    if not os.path.exists(path):
        print(f"# {path} missing -- run: python -m repro.launch.dryrun --all")
        return []
    lines = []
    for r in json.load(open(path)):
        tag = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        if r["status"] == "skip":
            lines.append(emit(tag, 0.0, f"SKIP:{r['reason'][:60]}"))
            continue
        if r["status"] != "ok":
            lines.append(emit(tag, 0.0, "ERROR"))
            continue
        roof = r["roofline"]
        dom = roof["bottleneck"]
        step_s = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
        lines.append(
            emit(
                tag,
                step_s * 1e6,
                f"bottleneck={dom};compute_s={roof['compute_s']:.4g};"
                f"memory_s={roof['memory_s']:.4g};"
                f"collective_s={roof['collective_s']:.4g};"
                f"roofline_frac={roof['roofline_fraction']:.3f}",
            )
        )
    return lines


if __name__ == "__main__":
    run()
