"""Wave-batched graph serving vs one-request-at-a-time.

Replays a KISS-deterministic stream of small independent graph requests
(``data/graphs.graph_request_stream`` -- the many-small-molecule-graphs
serving workload) through ``repro.serve.GraphServeEngine`` twice: once
wave-batched (``max_requests=16``) and once with ``max_requests=1``,
which is the same code path serving one request per wave -- the honest
one-request-at-a-time baseline (it still buckets, so the baseline's
compiles are amortized too; the win measured here is batching, not
compile caching).

Emits wall time per REQUEST plus the deterministic batching counters
the serve layer guarantees -- requests/wave, padded-slot waste
(node/edge), and bucket compiles (one set of compiled programs per
(stage, node_cap, edge_cap) bucket) -- which ``run.py --check``
guards against the committed ``BENCH_smoke.json`` in both CI lanes.
Wall-derived numbers (the speedup) are printed as comments only: the
counters in ``derived`` must be deterministic at a given scale.
"""
from __future__ import annotations

from benchmarks.common import SCALE, emit, time_fn
from repro.data.graphs import graph_request_stream
from repro.obs.metrics import derived_fragment
from repro.serve import GraphRequest, GraphServeEngine


def _serve(stream, max_requests: int, **knobs) -> GraphServeEngine:
    eng = GraphServeEngine(max_requests=max_requests, **knobs)
    for i, g in enumerate(stream):
        eng.submit(GraphRequest(uid=i, **g))
    eng.run()
    return eng


def run(num_requests: int | None = None) -> list[str]:
    R = num_requests or max(8, int(1600 * SCALE))
    lines = []
    for kind, family in (
        ("cc", "random"), ("analytics", "tree"), ("pagerank", "random"),
    ):
        stream = graph_request_stream(R, kind=kind, family=family, seed=11)
        t_batch = time_fn(lambda: _serve(stream, 16), iters=2)
        eng = _serve(stream, 16)
        # legacy counters first (pinned bit-identical by --check), then
        # the engine's unified metrics.snapshot() (repro.obs.metrics)
        lines.append(emit(
            f"graph_serve/batched/{kind}/{family}/req={R}",
            t_batch / R * 1e6,
            f"waves={eng.waves};req_per_wave={eng.requests_per_wave:.2f};"
            f"compiles={eng.bucket_compiles};"
            f"node_waste={eng.node_pad_waste:.3f};"
            f"edge_waste={eng.edge_pad_waste:.3f};"
            + derived_fragment(eng.metrics.snapshot()),
            spread=(t_batch.p10 / R * 1e6, t_batch.p90 / R * 1e6),
        ))
        t_solo = time_fn(lambda: _serve(stream, 1), iters=2)
        solo = _serve(stream, 1)
        lines.append(emit(
            f"graph_serve/solo/{kind}/{family}/req={R}",
            t_solo / R * 1e6,
            f"waves={solo.waves};compiles={solo.bucket_compiles}",
            spread=(t_solo.p10 / R * 1e6, t_solo.p90 / R * 1e6),
        ))
        print(
            f"# graph_serve {kind}/{family}: batched "
            f"{t_batch / R * 1e6:.0f} us/req vs solo "
            f"{t_solo / R * 1e6:.0f} us/req "
            f"({t_solo / max(t_batch, 1e-12):.2f}x)",
            flush=True,
        )

    # rank_engine="splitter" lane: served forests vary their tour-head
    # count per wave, and the splitter count is a compiled dimension of
    # the rank core -- tour_splitters' power-of-two capacity pad is
    # what keeps the compile count bucket-bounded. Pinned here as the
    # jit-cache DELTA of _random_splitter_core across the whole serve
    # run (a raw size would count earlier suites' shapes).
    from repro.core.list_ranking import _random_splitter_core

    stream = graph_request_stream(
        R, kind="analytics", family="tree", seed=13
    )
    cache0 = _random_splitter_core._cache_size()
    t_spl = time_fn(
        lambda: _serve(stream, 16, rank_engine="splitter"), iters=2
    )
    eng = _serve(stream, 16, rank_engine="splitter")
    rank_compiles = _random_splitter_core._cache_size() - cache0
    lines.append(emit(
        f"graph_serve/batched/analytics-splitter/tree/req={R}",
        t_spl / R * 1e6,
        f"waves={eng.waves};compiles={eng.bucket_compiles};"
        f"rank_compiles={rank_compiles}",
        spread=(t_spl.p10 / R * 1e6, t_spl.p90 / R * 1e6),
    ))
    return lines


if __name__ == "__main__":
    run()
