"""Paper Figure 5: speedup vs thread blocks -> TPU adaptation: speedup vs
lane count p for the random-splitter walk.

The GPU plot saturates at the SM count; the vectorized analogue saturates
when the lockstep walk's trip count (max sub-list length ~ (n/p) ln p)
stops shrinking relative to per-step overhead. We report time and trip
count per p (the oversubscription story, guideline G7)."""
from __future__ import annotations

from benchmarks.common import SCALE, emit, time_fn
from repro.core import random_splitter_rank
from repro.ops.kiss import random_linked_list


def run(n: int | None = None, ps=(64, 256, 1024, 4096, 16384)) -> list[str]:
    n = n or int(1_000_000 * SCALE)
    succ = random_linked_list(n, seed=0)
    lines = []
    base = None
    for p in ps:
        if p > n:
            continue
        t = time_fn(
            lambda p=p: random_splitter_rank(succ, p, seed=3), iters=2
        )
        _, stats = random_splitter_rank(succ, p, seed=3, with_stats=True)
        base = base or t
        lines.append(
            emit(
                f"fig5/p={p}/n={n}",
                t * 1e6,
                f"speedup_vs_p64={base/t:.2f};walk_steps={stats.walk_steps}",
            )
        )
    return lines


if __name__ == "__main__":
    run()
