"""Paper Figure 4: connected-components runtime per graph family
(lists, trees of degree k, random graphs of density d) vs the serial
union-find baseline -- now with dense-vs-frontier engine columns and an
``edges_touched`` derived metric (edge-slot visits at two hook passes
per round, the Table 4 accounting; see benchmarks/cc_frontier.py for
the dedicated frontier sweep)."""
from __future__ import annotations

import time

from benchmarks.common import SCALE, emit, time_fn
from repro.core import (
    frontier_shiloach_vishkin,
    label_propagation,
    shiloach_vishkin,
)
from repro.core.serial import serial_connected_components
from repro.ops.kiss import list_graph, random_graph, tree_graph


def _families(n):
    return {
        "list": list_graph(n, 4, seed=1),
        "tree-k3": tree_graph(n, 3, seed=2),
        "random-d0.001": random_graph(n, 2e-3 * 100_000 / n, seed=3),
    }


def run(n: int | None = None) -> list[str]:
    n = n or int(200_000 * SCALE)
    lines = []
    for fam, edges in _families(n).items():
        m = len(edges)
        t_sv = time_fn(
            lambda e=edges: shiloach_vishkin(e[:, 0], e[:, 1], n)[0], iters=2
        )
        _, rounds = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
        t_fr = time_fn(
            lambda e=edges: frontier_shiloach_vishkin(e[:, 0], e[:, 1], n)[0],
            iters=2,
        )
        _, _, st = frontier_shiloach_vishkin(
            edges[:, 0], edges[:, 1], n, with_stats=True
        )
        t_lp = time_fn(
            lambda e=edges: label_propagation(e[:, 0], e[:, 1], n)[0], iters=2
        )
        if n <= 200_000:
            t0 = time.perf_counter()
            serial_connected_components(edges, n)
            # host-only numpy union-find: nothing async to block on
            t_ser = time.perf_counter() - t0  # repro-lint: disable=block-timer
            lines.append(emit(f"fig4/serial/{fam}/n={n}", t_ser * 1e6, f"m={m}"))
        dense_touched = 2 * st.m2 * int(rounds)
        lines.append(
            emit(
                f"fig4/sv/{fam}/n={n}",
                t_sv * 1e6,
                f"m={m};rounds={int(rounds)};edges_touched={dense_touched}",
            )
        )
        lines.append(
            emit(
                f"fig4/sv_frontier/{fam}/n={n}",
                t_fr * 1e6,
                f"m={m};rounds={st.rounds};edges_touched={st.edges_touched};"
                f"visit_ratio={dense_touched / max(st.edges_touched, 1):.2f}",
            )
        )
        lines.append(emit(f"fig4/labelprop/{fam}/n={n}", t_lp * 1e6, f"m={m}"))
    return lines


if __name__ == "__main__":
    run()
