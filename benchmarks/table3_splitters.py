"""Paper Table 3: random vs perfect (even) splitters.

Reports, per n: mean sub-list length n/p, Reid-Miller expected extremes
(low ~ n/(2p^2), high ~ (n/p) H_p), observed extremes, walk trip counts,
and the runtime gap random-vs-even (paper: 6-10%)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, emit, time_fn
from repro.core import even_splitters, random_splitter_rank
from repro.ops.kiss import random_linked_list


def run(sizes=None, p: int = 2048) -> list[str]:
    sizes = sizes or [int(s * SCALE) for s in (1_000_000, 2_000_000)]
    lines = []
    for n in sizes:
        succ = random_linked_list(n, seed=n)
        _, stats_r = random_splitter_rank(succ, p, seed=7, with_stats=True)
        t_rand = time_fn(
            lambda: random_splitter_rank(succ, p, seed=7), iters=2
        )
        spl_even = even_splitters(succ, p)
        _, stats_e = random_splitter_rank(
            succ, splitters=spl_even, with_stats=True
        )
        t_even = time_fn(
            lambda: random_splitter_rank(succ, splitters=spl_even), iters=2
        )
        h_p = float(np.log(p) + 0.5772)
        exp_high = n / p * h_p
        exp_low = n / (2 * p * p)
        gap = (t_rand - t_even) / t_even * 100
        lines.append(
            emit(
                f"table3/n={n}/p={p}",
                t_rand * 1e6,
                f"mean={n/p:.1f};exp_low={exp_low:.2f};exp_high={exp_high:.1f};"
                f"obs_low={stats_r.sublist_lengths.min()};"
                f"obs_high={stats_r.sublist_lengths.max()};"
                f"even_low={stats_e.sublist_lengths.min()};"
                f"even_high={stats_e.sublist_lengths.max()};"
                f"runtime_gap_pct={gap:.1f}",
            )
        )
    return lines


if __name__ == "__main__":
    run()
