"""Chaos-smoke: graph serving under a deterministic fault plan.

Replays a KISS-deterministic request stream through
``repro.serve.GraphServeEngine`` with a seeded ``FaultPlan`` (poison +
transient + forced-nonconvergence injections, plus a simulated OOM on
the stream's own first-wave bucket) and emits the containment health
counters -- completed/failed/retried/quarantined/degraded/bisections/
wave_runs. Everything in ``derived`` is deterministic: the plan is
seeded, the stream is seeded, and the containment pipeline
(``serve/waves.py``) is sequential -- so ``run.py --check`` guards the
counters against ``BENCH_smoke.json`` in both CI lanes exactly like
the packing counters. A drift here means the containment semantics
changed: retry budgets, bisection probe order, or degradation
re-packing.

Wall time per request (faulty vs clean run of the same stream) is
printed as a comment only -- the overhead of containment is bisection
probes and degraded re-packs, which the ``wave_runs`` counter already
pins exactly.
"""
from __future__ import annotations

import time

from benchmarks.common import SCALE, emit
from repro.data.graphs import graph_request_stream
from repro.obs.metrics import derived_fragment
from repro.serve import FaultPlan, GraphRequest, GraphServeEngine


def _requests(stream):
    return [GraphRequest(uid=i, **g) for i, g in enumerate(stream)]


def _serve(stream, plan=None) -> GraphServeEngine:
    eng = GraphServeEngine(max_requests=8, fault_plan=plan, max_retries=2)
    for r in _requests(stream):
        eng.submit(r)
    eng.run()
    return eng


def run(num_requests: int | None = None) -> list[str]:
    R = num_requests or max(16, int(800 * SCALE))
    lines = []
    stream = graph_request_stream(R, kind="cc", family="random", seed=29)

    # clean baseline (no plan): containment machinery at zero overhead
    t0 = time.perf_counter()
    clean = _serve(stream)
    # host-driven wave loop: _run_wave materializes results via
    # np.asarray, so the run is synced when it returns
    t_clean = time.perf_counter() - t0  # repro-lint: disable=block-timer
    h = clean.health_records[-1]
    lines.append(emit(
        f"serve_chaos/clean/req={R}",
        t_clean / R * 1e6,
        f"completed={h.completed};failed={h.failed};"
        f"wave_runs={h.wave_runs};waves={clean.waves}",
    ))

    # seeded chaos: poison + transient + forced-nonconvergence uids,
    # plus an OOM on the first wave's own bucket (degradation path)
    plan = FaultPlan.random(
        31, range(R), p_poison=0.08, p_transient=0.12, max_transient=2,
        p_nonconverge=0.04,
    )
    probe = GraphServeEngine(max_requests=8)
    first_cap, _ = probe._wave_caps(_requests(stream)[:8])
    plan = FaultPlan(
        poison_uids=plan.poison_uids,
        transient_uids=plan.transient_uids,
        nonconverge_uids=plan.nonconverge_uids,
        oom_node_caps=frozenset([first_cap]),
    )
    # the gap since the clean run's read is plan setup, not a timed
    # interval; the chaos interval itself is host-synced (see above)
    t0 = time.perf_counter()  # repro-lint: disable=block-timer
    eng = _serve(stream, plan)
    t_chaos = time.perf_counter() - t0  # repro-lint: disable=block-timer
    h = eng.health_records[-1]
    # legacy health counters first (pinned bit-identical by --check),
    # then the engine's unified metrics.snapshot() (repro.obs.metrics)
    lines.append(emit(
        f"serve_chaos/faulty/req={R}",
        t_chaos / R * 1e6,
        f"completed={h.completed};failed={h.failed};"
        f"retried={h.retried};quarantined={h.quarantined};"
        f"degraded={h.degraded};bisections={h.bisections};"
        f"wave_runs={h.wave_runs};"
        + derived_fragment(eng.metrics.snapshot()),
    ))
    print(
        f"# serve_chaos: {h.failed}/{R} quarantined, "
        f"{h.wave_runs - clean.health_records[-1].wave_runs} extra wave "
        f"runs for containment "
        f"({t_chaos / max(t_clean, 1e-12):.2f}x clean wall)",
        flush=True,
    )

    # kind="sssp" waves through the SAME containment machinery: a
    # weighted multi-source stream, clean then with poison + transient
    # + forced-nonconvergence injections (the relax-bound sentinel).
    R2 = max(8, R // 2)
    sstream = graph_request_stream(
        R2, kind="sssp", family="random", seed=37
    )
    t0 = time.perf_counter()  # repro-lint: disable=block-timer
    sclean = _serve(sstream)
    t_sclean = time.perf_counter() - t0  # repro-lint: disable=block-timer
    h = sclean.health_records[-1]
    lines.append(emit(
        f"serve_chaos/sssp_clean/req={R2}",
        t_sclean / R2 * 1e6,
        f"completed={h.completed};failed={h.failed};"
        f"wave_runs={h.wave_runs};waves={sclean.waves}",
    ))
    # higher rates than the cc stream: R2 is half the size, and the
    # seed must light up all three injection paths even at smoke scale
    splan = FaultPlan.random(
        40, range(R2), p_poison=0.2, p_transient=0.2, max_transient=2,
        p_nonconverge=0.12,
    )
    t0 = time.perf_counter()  # repro-lint: disable=block-timer
    seng = _serve(sstream, splan)
    t_schaos = time.perf_counter() - t0  # repro-lint: disable=block-timer
    h = seng.health_records[-1]
    lines.append(emit(
        f"serve_chaos/sssp_faulty/req={R2}",
        t_schaos / R2 * 1e6,
        f"completed={h.completed};failed={h.failed};"
        f"retried={h.retried};quarantined={h.quarantined};"
        f"degraded={h.degraded};bisections={h.bisections};"
        f"wave_runs={h.wave_runs}",
    ))
    print(
        f"# serve_chaos[sssp]: {h.failed}/{R2} quarantined, "
        f"{h.wave_runs - sclean.health_records[-1].wave_runs} extra "
        f"wave runs for containment",
        flush=True,
    )

    # kind="pagerank" waves: the ADD-monoid family through the same
    # containment machinery. The forced-nonconvergence injection here
    # exercises the dense engine's REAL iteration-budget sentinel
    # (max_rounds=0 + the post-run tolerance probe, core/pagerank.py),
    # not a simulated failure -- so the quarantine counters pin that
    # the sentinel fires and is contained like any other poison.
    R3 = max(8, R // 2)
    pstream = graph_request_stream(
        R3, kind="pagerank", family="random", seed=43
    )
    t0 = time.perf_counter()  # repro-lint: disable=block-timer
    pclean = _serve(pstream)
    t_pclean = time.perf_counter() - t0  # repro-lint: disable=block-timer
    h = pclean.health_records[-1]
    lines.append(emit(
        f"serve_chaos/pagerank_clean/req={R3}",
        t_pclean / R3 * 1e6,
        f"completed={h.completed};failed={h.failed};"
        f"wave_runs={h.wave_runs};waves={pclean.waves}",
    ))
    pplan = FaultPlan.random(
        44, range(R3), p_poison=0.2, p_transient=0.2, max_transient=2,
        p_nonconverge=0.12,
    )
    t0 = time.perf_counter()  # repro-lint: disable=block-timer
    peng = _serve(pstream, pplan)
    t_pchaos = time.perf_counter() - t0  # repro-lint: disable=block-timer
    h = peng.health_records[-1]
    lines.append(emit(
        f"serve_chaos/pagerank_faulty/req={R3}",
        t_pchaos / R3 * 1e6,
        f"completed={h.completed};failed={h.failed};"
        f"retried={h.retried};quarantined={h.quarantined};"
        f"degraded={h.degraded};bisections={h.bisections};"
        f"wave_runs={h.wave_runs}",
    ))
    print(
        f"# serve_chaos[pagerank]: {h.failed}/{R3} quarantined, "
        f"{h.wave_runs - pclean.health_records[-1].wave_runs} extra "
        f"wave runs for containment",
        flush=True,
    )
    return lines


if __name__ == "__main__":
    run()
