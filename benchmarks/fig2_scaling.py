"""Paper Figure 2: runtime vs list size for all list-ranking implementations.

Lines: serial traversal (numpy/python, the paper's 'sequential CPU'),
Wylie pointer jumping (O(n log n) work), random splitter (O(n) work,
both packings). The claim reproduced: the O(n)-work method dominates and
scales linearly; Wylie's per-element cost grows with log n."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, emit, time_fn
from repro.core import random_splitter_rank, wylie_rank
from repro.core.serial import serial_list_rank
from repro.ops.kiss import random_linked_list


def run(sizes=None) -> list[str]:
    sizes = sizes or [int(s * SCALE) for s in (250_000, 500_000, 1_000_000, 2_000_000)]
    lines = []
    for n in sizes:
        succ = random_linked_list(n, seed=n)
        p = min(4096, n // 64 or 1)
        if n <= 1_000_000:  # python-loop serial gets slow beyond this
            import time as _t

            t0 = _t.perf_counter()
            serial_list_rank(succ)
            t_serial = _t.perf_counter() - t0
            lines.append(emit(f"fig2/serial/n={n}", t_serial * 1e6, "work=O(n) serial"))
        t_w = time_fn(lambda: wylie_rank(succ, pack_mode="aos"), iters=2)
        lines.append(emit(f"fig2/wylie/n={n}", t_w * 1e6, "work=O(n log n)"))
        for pm in ("soa", "aos"):
            t_rs = time_fn(
                lambda pm=pm: random_splitter_rank(succ, p, seed=3, pack_mode=pm),
                iters=2,
            )
            lines.append(emit(f"fig2/splitter-{pm}/n={n}", t_rs * 1e6, "work=O(n)"))
    return lines


if __name__ == "__main__":
    run()
