"""Paper Figure 3: time PER LIST ELEMENT vs n, and the packing crossover.

Reproduced claims: (a) splitter time/element is ~flat (O(1)/element) while
Wylie grows ~log n; (b) the AoS ('64-bit') layout wins until the per-step
traffic (160n bits vs 96n bits in the paper's accounting) saturates
bandwidth -- on CPU the crossover manifests once n leaves cache; we report
the analytic traffic model alongside measurements."""
from __future__ import annotations

import numpy as np

from benchmarks.common import SCALE, emit, time_fn
from repro.core import random_splitter_rank, wylie_rank
from repro.ops.kiss import random_linked_list
from repro.ops.packing import bytes_per_node


def run(sizes=None) -> list[str]:
    sizes = sizes or [
        int(s * SCALE) for s in (250_000, 500_000, 1_000_000, 2_000_000, 4_000_000)
    ]
    lines = []
    per_elem = {}
    for n in sizes:
        succ = random_linked_list(n, seed=n)
        p = min(4096, max(n // 64, 1))
        t_w = time_fn(lambda: wylie_rank(succ, pack_mode="aos"), iters=2)
        lines.append(
            emit(f"fig3/wylie/n={n}", t_w / n * 1e9, "ns_per_element")
        )
        for pm in ("soa", "aos"):
            t = time_fn(
                lambda pm=pm: random_splitter_rank(succ, p, seed=3, pack_mode=pm),
                iters=2,
            )
            per_elem.setdefault(pm, []).append(t / n)
            traffic = bytes_per_node(pm)
            lines.append(
                emit(
                    f"fig3/splitter-{pm}/n={n}",
                    t / n * 1e9,
                    f"ns_per_element;bytes_per_node={traffic['read']+traffic['write']}",
                )
            )
    # flatness check: max/min ratio of splitter ns/element across sizes
    for pm, ts in per_elem.items():
        ratio = max(ts) / min(ts)
        lines.append(
            emit(f"fig3/flatness/{pm}", ratio, "max_over_min_time_per_element")
        )
    return lines


if __name__ == "__main__":
    run()
