"""DEPRECATED shim: the docs-consistency check moved into repro-lint.

The choice-matrix comparison now lives in the ``choice-set`` lint pass
(``tools/lint/passes/choice_set.py``, docs/lint.md) and runs with the
rest of the invariant checks:

    python -m tools.lint src tests benchmarks

This wrapper keeps the old CLI contract -- same exit codes, same
problem strings -- so existing CI invocations and tests keep working:

    PYTHONPATH=src python tools/check_docs.py

Unlike the original it is fully static (AST-parses the choice-set
constants instead of importing repro), so it no longer needs
PYTHONPATH=src or a jax import to run.
"""
from __future__ import annotations

import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
if str(_ROOT) not in sys.path:  # imported as top-level `check_docs`
    sys.path.insert(0, str(_ROOT))

from tools.lint.passes import choice_set as _cs

DOCS = _ROOT / "docs" / "engines.md"


def documented_choices(text: str) -> dict[str, tuple[str, ...]]:
    """{knob: ordered value tuple} from the choice-matrix table rows."""
    return _cs.documented_choices(text)


def code_choices() -> dict[str, tuple[str, ...]]:
    """The authoritative dispatch sets (statically parsed from the
    files registered in the choice-set pass KNOBS)."""
    return _cs.code_choices(_ROOT)


def check() -> list[str]:
    """Returns a list of human-readable problems (empty = consistent)."""
    doc = documented_choices(DOCS.read_text())
    code = code_choices()
    return [problem for _knob, problem in _cs.compare(doc, code)]


def main() -> int:
    print(
        "note: tools/check_docs.py is a shim over the choice-set lint "
        "pass; prefer `python -m tools.lint` (docs/lint.md)",
        file=sys.stderr,
    )
    problems = check()
    for p in problems:
        print(f"DOCS INCONSISTENT: {p}", file=sys.stderr)
    if not problems:
        print(f"docs/engines.md choice matrix consistent "
              f"({len(code_choices())} knobs)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
