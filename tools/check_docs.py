"""Docs-consistency check: the choice matrix in docs/engines.md must
equal the ``check_choice`` sets in the code, value for value and in the
same order, so the documented matrix cannot rot.

Parses the first (``choice-matrix``) table in docs/engines.md -- one
row per knob, knob name as ```name=`` in the first cell, valid values
as backticked tokens in the second cell -- and compares each row
against the authoritative tuple in the code. Exits non-zero listing
every mismatch. Run from the repo root:

    PYTHONPATH=src python tools/check_docs.py

CI runs this in both jax lanes; ``tests/test_docs.py`` wraps it so the
tier-1 suite catches drift locally too.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

DOCS = Path(__file__).resolve().parent.parent / "docs" / "engines.md"

_ROW = re.compile(r"^\|\s*`(?P<knob>\w+)=`\s*\|(?P<values>[^|]*)\|")
_TOKEN = re.compile(r"`([^`]+)`")


def documented_choices(text: str) -> dict[str, tuple[str, ...]]:
    """{knob: ordered value tuple} from the choice-matrix table rows.

    Only the table following the ``<!-- choice-matrix`` marker counts
    (docs/engines.md has other tables -- numeric knobs, guarantees --
    whose rows are not choice sets); parsing stops at the next
    heading."""
    out = {}
    in_matrix = False
    for line in text.splitlines():
        if "<!-- choice-matrix" in line:
            in_matrix = True
            continue
        if in_matrix and line.startswith("#"):
            break
        if not in_matrix:
            continue
        m = _ROW.match(line.strip())
        if not m or m.group("knob") in out:
            continue
        values = tuple(_TOKEN.findall(m.group("values")))
        if values:
            out[m.group("knob")] = values
    return out


def code_choices() -> dict[str, tuple[str, ...]]:
    """The authoritative dispatch sets, straight from the code."""
    from repro.core import __init__ as _  # noqa: F401  (package import)
    import repro.core as core
    from repro.core.components import HOOK_IMPLS
    from repro.core.list_ranking import KERNEL_IMPLS, PACK_MODES
    from repro.distributed.graph import EXCHANGES
    from repro.serve.engine import OVERFLOW_POLICIES
    from repro.serve.graph import KINDS
    from repro.trees import RANK_ENGINES

    return {
        "engine": tuple(core._CC_ENGINES),
        "kernel_impl": tuple(KERNEL_IMPLS),
        "hook_impl": tuple(HOOK_IMPLS),
        "exchange": tuple(EXCHANGES),
        "rank_engine": tuple(RANK_ENGINES),
        "pack_mode": tuple(PACK_MODES),
        "kind": tuple(KINDS),
        "on_overflow": tuple(OVERFLOW_POLICIES),
    }


def check() -> list[str]:
    """Returns a list of human-readable problems (empty = consistent)."""
    doc = documented_choices(DOCS.read_text())
    code = code_choices()
    problems = []
    for knob, want in sorted(code.items()):
        got = doc.get(knob)
        if got is None:
            problems.append(
                f"{knob}=: no choice-matrix row in docs/engines.md "
                f"(code has {want})"
            )
        elif got != want:
            problems.append(
                f"{knob}=: docs/engines.md says {got}, code says {want}"
            )
    for knob in sorted(set(doc) - set(code)):
        problems.append(
            f"{knob}=: documented in docs/engines.md but unknown to "
            "tools/check_docs.py -- add it to code_choices()"
        )
    return problems


def main() -> int:
    problems = check()
    for p in problems:
        print(f"DOCS INCONSISTENT: {p}", file=sys.stderr)
    if not problems:
        print(f"docs/engines.md choice matrix consistent "
              f"({len(code_choices())} knobs)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
