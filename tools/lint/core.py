"""repro-lint framework: file walking, per-file pass dispatch, pragma
suppression, baseline matching, and human/JSON reporting.

Key objects:

* ``Module`` -- one parsed source file (AST + pragma map).
* ``LintPass`` -- a check; per-module via ``check_module`` and/or
  repo-wide via ``finalize``. Each pass declares which files it applies
  to (``applies_to``), so dispatch is per file.
* ``Project`` -- the parsed module set rooted at the repo root.
* ``run_lint`` / ``lint_source`` -- entry points (CLI and tests).

Suppression layers, innermost first:

1. ``# repro-lint: disable=<pass>[,<pass>...]`` -- trailing on the
   offending line, or on a standalone comment line directly above it
   (``disable=all`` kills every pass for that line).
2. ``# repro-lint: disable-file=<pass>`` anywhere -- whole file.
3. The committed baseline (``tools/lint/baseline.json``) -- grandfathers
   existing findings by (file, pass, source-line text), so line-number
   drift does not invalidate entries. New findings never match.
"""
from __future__ import annotations

import ast
import io
import json
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path

PRAGMA_RE = re.compile(
    r"#\s*repro-lint:\s*(?P<kind>disable(?:-file)?)\s*=\s*"
    r"(?P<passes>[\w, -]+)"
)

SKIP_DIRS = {"__pycache__", ".git", ".venv", "node_modules", ".pytest_cache"}


@dataclass
class Finding:
    """One lint finding, anchored at a repo-relative file:line."""

    file: str  # repo-relative posix path
    line: int
    col: int
    pass_name: str
    code: str
    message: str
    guideline: str = ""
    snippet: str = ""  # stripped source line (baseline key component)

    def key(self) -> tuple[str, str, str]:
        return (self.file, self.pass_name, self.snippet)

    def format(self) -> str:
        g = f" [{self.guideline}]" if self.guideline else ""
        return (
            f"{self.file}:{self.line}:{self.col}: {self.code}"
            f"({self.pass_name}){g} {self.message}"
        )

    def to_json(self) -> dict:
        return {
            "file": self.file,
            "line": self.line,
            "col": self.col,
            "code": self.code,
            "pass": self.pass_name,
            "guideline": self.guideline,
            "message": self.message,
            "snippet": self.snippet,
        }


@dataclass
class Module:
    """One parsed python file plus its pragma map."""

    path: Path
    rel: str  # repo-relative posix path
    text: str
    tree: ast.Module
    lines: list[str]
    # physical line -> set of disabled pass names ("all" disables all)
    pragmas: dict = field(default_factory=dict)
    file_disables: set = field(default_factory=set)

    @classmethod
    def parse(cls, path: Path, rel: str, text: str) -> "Module":
        tree = ast.parse(text, filename=rel)
        mod = cls(
            path=path, rel=rel, text=text, tree=tree,
            lines=text.splitlines(),
        )
        mod._scan_pragmas()
        return mod

    def _scan_pragmas(self) -> None:
        try:
            tokens = list(tokenize.generate_tokens(io.StringIO(self.text).readline))
        except tokenize.TokenError:
            tokens = []
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = PRAGMA_RE.search(tok.string)
            if not m:
                continue
            names = {p.strip() for p in m.group("passes").split(",") if p.strip()}
            if m.group("kind") == "disable-file":
                self.file_disables |= names
                continue
            line = tok.start[0]
            self.pragmas.setdefault(line, set()).update(names)
            # A standalone pragma comment covers the next code line.
            if self.lines[line - 1].lstrip().startswith("#"):
                nxt = line + 1
                while nxt <= len(self.lines) and (
                    not self.lines[nxt - 1].strip()
                    or self.lines[nxt - 1].lstrip().startswith("#")
                ):
                    nxt += 1
                if nxt <= len(self.lines):
                    self.pragmas.setdefault(nxt, set()).update(names)

    def suppressed(self, pass_name: str, line: int) -> bool:
        if pass_name in self.file_disables or "all" in self.file_disables:
            return True
        at = self.pragmas.get(line, ())
        return pass_name in at or "all" in at

    def snippet(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""


@dataclass
class Project:
    """The module set one ``run_lint`` call operates on."""

    root: Path
    modules: list = field(default_factory=list)

    def module(self, rel: str):
        for m in self.modules:
            if m.rel == rel:
                return m
        return None


class LintPass:
    """Base class for a repro-lint pass.

    Subclasses set ``name`` (the pragma token), ``code`` (RLnnn),
    ``guideline`` (which docs/guidelines.md rule it mechanizes) and
    ``description``, then implement ``check_module`` and/or
    ``finalize``. ``applies_to`` scopes the per-file dispatch."""

    name: str = "base"
    code: str = "RL000"
    guideline: str = ""
    description: str = ""

    def applies_to(self, rel: str) -> bool:
        return rel.endswith(".py")

    def check_module(self, module: Module, project: Project):
        return ()

    def finalize(self, project: Project):
        """Repo-wide checks run once after every module pass."""
        return ()

    def finding(
        self, module: Module, node, message: str, *, line=None, col=None
    ) -> Finding:
        ln = line if line is not None else getattr(node, "lineno", 1)
        cl = col if col is not None else getattr(node, "col_offset", 0)
        return Finding(
            file=module.rel,
            line=ln,
            col=cl,
            pass_name=self.name,
            code=self.code,
            message=message,
            guideline=self.guideline,
            snippet=module.snippet(ln),
        )


def _iter_py_files(paths: list[Path]):
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if not any(part in SKIP_DIRS for part in f.parts):
                    yield f


def _parse_error_finding(rel: str, exc: SyntaxError) -> Finding:
    return Finding(
        file=rel,
        line=exc.lineno or 1,
        col=exc.offset or 0,
        pass_name="parse",
        code="RL000",
        message=f"cannot parse: {exc.msg}",
    )


def build_project(paths: list[str | Path], root: str | Path) -> tuple:
    """Parse every .py under ``paths``; returns (Project, parse_findings)."""
    root = Path(root).resolve()
    project = Project(root=root)
    errors: list[Finding] = []
    seen: set = set()
    for f in _iter_py_files([Path(p) for p in paths]):
        f = f.resolve()
        if f in seen:
            continue
        seen.add(f)
        try:
            rel = f.relative_to(root).as_posix()
        except ValueError:
            rel = f.as_posix()
        text = f.read_text()
        try:
            project.modules.append(Module.parse(f, rel, text))
        except SyntaxError as e:
            errors.append(_parse_error_finding(rel, e))
    return project, errors


def run_passes(project: Project, passes) -> list[Finding]:
    """Dispatch passes per file, then repo-wide; apply pragma filters."""
    findings: list[Finding] = []
    for p in passes:
        for mod in project.modules:
            if p.applies_to(mod.rel):
                findings.extend(p.check_module(mod, project))
        findings.extend(p.finalize(project))
    out = []
    for f in findings:
        mod = project.module(f.file)
        if mod is not None and mod.suppressed(f.pass_name, f.line):
            continue
        out.append(f)
    out.sort(key=lambda f: (f.file, f.line, f.col, f.code))
    return out


def run_lint(
    paths: list[str | Path],
    *,
    root: str | Path,
    passes=None,
    select: set | None = None,
) -> list[Finding]:
    """Lint ``paths``: parse, dispatch, pragma-filter. Baseline handling
    is the caller's job (``split_baselined``)."""
    if passes is None:
        from tools.lint.passes import ALL_PASSES

        passes = ALL_PASSES
    if select:
        passes = [p for p in passes if p.name in select]
    project, errors = build_project(paths, root)
    return errors + run_passes(project, passes)


def lint_source(
    text: str,
    *,
    rel: str = "fixture.py",
    passes=None,
    root: str | Path = ".",
    extra_files: dict | None = None,
) -> list[Finding]:
    """Lint an in-memory source string (the test fixture entry point).

    ``extra_files`` maps extra relpaths to source text, for passes whose
    verdict spans files (e.g. choice-set's docs comparison)."""
    if passes is None:
        from tools.lint.passes import ALL_PASSES

        passes = ALL_PASSES
    project = Project(root=Path(root).resolve())
    errors: list[Finding] = []
    all_files = {rel: text, **(extra_files or {})}
    for r, t in all_files.items():
        try:
            project.modules.append(Module.parse(Path(r), r, t))
        except SyntaxError as e:
            errors.append(_parse_error_finding(r, e))
    return errors + run_passes(project, passes)


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------


def load_baseline(path: str | Path) -> list[dict]:
    p = Path(path)
    if not p.exists():
        return []
    data = json.loads(p.read_text())
    return list(data.get("findings", []))


def save_baseline(path: str | Path, findings: list[Finding]) -> None:
    entries = [
        {
            "file": f.file,
            "pass": f.pass_name,
            "line": f.line,
            "snippet": f.snippet,
        }
        for f in findings
    ]
    Path(path).write_text(
        json.dumps({"findings": entries}, indent=2) + "\n"
    )


def split_baselined(
    findings: list[Finding], baseline: list[dict]
) -> tuple[list[Finding], list[Finding], list[dict]]:
    """(new, grandfathered, stale_entries). An entry matches one finding
    with the same (file, pass, snippet) -- line numbers may drift."""
    pool: dict[tuple, int] = {}
    for e in baseline:
        k = (e.get("file"), e.get("pass"), e.get("snippet", ""))
        pool[k] = pool.get(k, 0) + 1
    new, old = [], []
    for f in findings:
        k = f.key()
        if pool.get(k, 0) > 0:
            pool[k] -= 1
            old.append(f)
        else:
            new.append(f)
    stale = []
    for e in baseline:
        k = (e.get("file"), e.get("pass"), e.get("snippet", ""))
        if pool.get(k, 0) > 0:
            pool[k] -= 1
            stale.append(e)
    return new, old, stale
