"""CLI: ``python -m tools.lint src/ tests/ benchmarks/``.

Exit status: 0 clean (or everything baselined/pragma'd), 1 on new
findings or stale baseline entries, 2 on usage errors. ``--json``
emits the machine-readable finding list (CI runs this).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

from tools.lint.core import (
    load_baseline,
    run_lint,
    save_baseline,
    split_baselined,
)
from tools.lint.passes import ALL_PASSES

REPO_ROOT = Path(__file__).resolve().parents[2]
DEFAULT_BASELINE = REPO_ROOT / "tools" / "lint" / "baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description=(
            "repro-lint: AST invariant checks for the PRAM->accelerator "
            "guidelines (docs/lint.md)"
        ),
    )
    ap.add_argument(
        "paths", nargs="*", default=["src", "tests", "benchmarks"],
        help="files/directories to lint (default: src tests benchmarks)",
    )
    ap.add_argument("--json", action="store_true", help="JSON findings")
    ap.add_argument(
        "--baseline", default=str(DEFAULT_BASELINE),
        help="baseline file (default: tools/lint/baseline.json)",
    )
    ap.add_argument(
        "--no-baseline", action="store_true",
        help="report grandfathered findings too",
    )
    ap.add_argument(
        "--write-baseline", action="store_true",
        help="write current findings to the baseline file and exit 0",
    )
    ap.add_argument(
        "--select", default=None,
        help="comma-separated pass names to run (default: all)",
    )
    ap.add_argument(
        "--list-passes", action="store_true", help="list passes and exit"
    )
    args = ap.parse_args(argv)

    if args.list_passes:
        for p in ALL_PASSES:
            print(f"{p.code}  {p.name:22s} [{p.guideline}] {p.description}")
        return 0

    select = (
        {s.strip() for s in args.select.split(",") if s.strip()}
        if args.select
        else None
    )
    if select:
        known = {p.name for p in ALL_PASSES}
        bad = select - known
        if bad:
            print(
                f"unknown pass(es): {sorted(bad)}; known: {sorted(known)}",
                file=sys.stderr,
            )
            return 2

    t0 = time.monotonic()
    findings = run_lint(args.paths, root=REPO_ROOT, select=select)

    if args.write_baseline:
        save_baseline(args.baseline, findings)
        print(
            f"wrote {len(findings)} finding(s) to {args.baseline}",
            file=sys.stderr,
        )
        return 0

    baseline = [] if args.no_baseline else load_baseline(args.baseline)
    new, old, stale = split_baselined(findings, baseline)

    if args.json:
        print(json.dumps([f.to_json() for f in new], indent=2))
    else:
        for f in new:
            print(f.format())
    for e in stale:
        print(
            "stale baseline entry (fixed? remove it): "
            f"{e.get('file')} [{e.get('pass')}] {e.get('snippet', '')!r}",
            file=sys.stderr,
        )
    dt = time.monotonic() - t0
    summary = (
        f"repro-lint: {len(new)} new finding(s), {len(old)} baselined, "
        f"{len(stale)} stale baseline entr(y/ies) in {dt:.2f}s"
    )
    print(summary, file=sys.stderr)
    return 1 if (new or stale) else 0


if __name__ == "__main__":
    sys.exit(main())
