"""repro-lint: AST-based invariant checks for the PRAM->accelerator
guidelines (docs/guidelines.md G1-G5) and the repo's hard conventions
(compat-shim routing, deterministic min-CRCW scatters, choice-set /
docs sync, power-of-two capacity bucketing).

Run from the repo root::

    python -m tools.lint src/ tests/ benchmarks/

The framework is pure-static (stdlib ``ast`` + ``tokenize``; no jax
import), so the whole tree lints in well under a second. See
``docs/lint.md`` for the pass catalog, the pragma / baseline workflow,
and how to add a pass.
"""
from tools.lint.core import (  # noqa: F401
    Finding,
    LintPass,
    Module,
    Project,
    lint_source,
    load_baseline,
    run_lint,
    split_baselined,
)
from tools.lint.passes import ALL_PASSES  # noqa: F401
