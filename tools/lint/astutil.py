"""Shared AST helpers for the repro-lint passes.

Everything here is heuristic in the way linters are: the analyses are
single-pass and name-based (no import resolution, no fixpoint), which
is exactly enough for this repo's straight-line driver loops and
round-body closures, and cheap enough to keep the whole tree under a
second. Passes document their scope rules in docs/lint.md.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

# Attribute reads on a device array that are static Python values, not
# device->host transfers (shapes are compile-time in jax).
STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "sharding"})

# jax.* calls that return plain host values (device discovery etc.),
# not traced arrays.
HOST_JAX_CALLS = frozenset(
    {
        "jax.devices",
        "jax.device_count",
        "jax.local_device_count",
        "jax.local_devices",
        "jax.default_backend",
        "jax.tree_util.tree_structure",
    }
)

DEVICE_PREFIXES = ("jnp.", "jax.", "lax.")


def dotted_name(node: ast.AST) -> str | None:
    """'jax.lax.while_loop' for nested Attribute/Name chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> str | None:
    return dotted_name(node.func)


def _const_str_tuple(node: ast.AST) -> tuple[str, ...] | None:
    """The value of a literal tuple/list of strings, else None."""
    if isinstance(node, (ast.Tuple, ast.List)) and all(
        isinstance(e, ast.Constant) and isinstance(e.value, str)
        for e in node.elts
    ):
        return tuple(e.value for e in node.elts)
    return None


def module_constants(tree: ast.Module) -> dict[str, tuple[tuple[str, ...], int]]:
    """{NAME: (string tuple, lineno)} for module-level literal tuples."""
    out: dict[str, tuple[tuple[str, ...], int]] = {}
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Name):
                val = _const_str_tuple(stmt.value)
                if val is not None:
                    out[tgt.id] = (val, stmt.lineno)
    return out


def _jit_marker(node: ast.AST) -> tuple[bool, tuple[str, ...]]:
    """Is ``node`` (a decorator or call) a jax.jit wrapper? Returns
    (is_jit, static_argnames)."""
    name = dotted_name(node)
    if name in ("jit", "jax.jit"):
        return True, ()
    if isinstance(node, ast.Call):
        fname = dotted_name(node.func)
        inner_is_jit = False
        statics: tuple[str, ...] = ()
        if fname in ("jit", "jax.jit"):
            inner_is_jit = True
        elif fname in ("partial", "functools.partial") and node.args:
            inner_is_jit, statics = _jit_marker(node.args[0])
        if inner_is_jit:
            for kw in node.keywords:
                if kw.arg == "static_argnames":
                    vals = _const_str_tuple(kw.value)
                    if vals is None and isinstance(kw.value, ast.Constant):
                        vals = (kw.value.value,)
                    statics = statics + tuple(vals or ())
            return True, statics
    return False, ()


@dataclass
class FuncInfo:
    """One function (or nested closure) with its lint-relevant context."""

    node: ast.FunctionDef
    parents: list  # enclosing FunctionDef chain, outermost first
    is_jitted: bool = False
    static_argnames: tuple = ()

    @property
    def name(self) -> str:
        return self.node.name

    @property
    def qualnames(self) -> list[str]:
        return [p.name for p in self.parents] + [self.node.name]


def iter_functions(tree: ast.Module):
    """Yield FuncInfo for every (async) function, with parent chains."""

    def walk(node, parents):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                is_jit, statics = False, ()
                for dec in child.decorator_list:
                    j, s = _jit_marker(dec)
                    if j:
                        is_jit, statics = True, s
                yield FuncInfo(child, list(parents), is_jit, statics)
                yield from walk(child, parents + [child])
            else:
                yield from walk(child, parents)

    yield from walk(tree, [])


def module_jitted(tree: ast.Module) -> dict[str, tuple[str, ...]]:
    """{name: static_argnames} for jit-wrapped callables in this module:
    decorated defs plus ``name = jax.jit(fn, ...)`` assignments."""
    out: dict[str, tuple[str, ...]] = {}
    for info in iter_functions(tree):
        if info.is_jitted:
            out[info.name] = info.static_argnames
    for stmt in ast.walk(tree):
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
            tgt = stmt.targets[0]
            if isinstance(tgt, ast.Name) and isinstance(stmt.value, ast.Call):
                is_jit, statics = _jit_marker(stmt.value)
                if is_jit:
                    out[tgt.id] = statics
    return out


@dataclass
class Taint:
    """Names holding device values (or host ints derived from them)."""

    names: set = field(default_factory=set)

    def has(self, name: str) -> bool:
        return name in self.names


def _assign_targets(tgt: ast.AST):
    if isinstance(tgt, ast.Name):
        yield tgt.id
    elif isinstance(tgt, (ast.Tuple, ast.List)):
        for e in tgt.elts:
            yield from _assign_targets(e)


def expr_is_device(
    expr: ast.AST,
    tainted: set,
    jitted: dict,
    skip_calls: frozenset = frozenset(),
) -> bool:
    """Does ``expr`` carry a device value?

    True when it mentions a jnp./jax./lax. call (minus the host-value
    allowlist), a call to a module-jitted function, or a tainted name --
    except under a ``.shape``-style static attribute or inside a call
    from ``skip_calls`` (the recompile-hazard sanitizers)."""

    def visit(node) -> bool:
        if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
            return False  # a.shape / a.ndim reads are static
        if isinstance(node, ast.Call):
            name = call_name(node)
            if name is not None:
                base = name.split(".")[-1]
                if base in skip_calls or name in skip_calls:
                    return False  # sanitized (next_pow2 & friends)
                if name in jitted or base in jitted:
                    return True
                if (
                    name.startswith(DEVICE_PREFIXES)
                    and name not in HOST_JAX_CALLS
                ):
                    return True
            return any(visit(c) for c in ast.iter_child_nodes(node))
        if isinstance(node, ast.Name):
            return node.id in tainted
        return any(visit(c) for c in ast.iter_child_nodes(node))

    return visit(expr)


def function_taint(
    fn: ast.FunctionDef,
    jitted: dict,
    *,
    seed_calls: tuple[str, ...] = (),
    skip_calls: frozenset = frozenset(),
) -> set:
    """One forward pass over ``fn``'s statements collecting names bound
    to device values. ``seed_calls`` optionally restricts taint SOURCES
    to specific builtins (the recompile pass seeds from int()/float()/
    .item() results instead of raw device values)."""
    tainted: set = set()

    def source(expr) -> bool:
        if not seed_calls:
            return expr_is_device(expr, tainted, jitted, skip_calls)

        def visit(node) -> bool:
            if isinstance(node, ast.Call):
                name = call_name(node)
                base = name.split(".")[-1] if name else None
                if base in skip_calls or (name or "") in skip_calls:
                    return False
                if name in seed_calls and node.args:
                    if expr_is_device(node.args[0], tainted, jitted):
                        return True
                if (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item"
                ):
                    return True
            if isinstance(node, ast.Name):
                return node.id in tainted
            return any(visit(c) for c in ast.iter_child_nodes(node))

        return visit(expr)

    for stmt in ast.walk(fn):
        if isinstance(stmt, ast.Assign):
            if source(stmt.value):
                for t in stmt.targets:
                    tainted.update(_assign_targets(t))
        elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
            if stmt.value is not None and source(stmt.value):
                tainted.update(_assign_targets(stmt.target))
    return tainted
