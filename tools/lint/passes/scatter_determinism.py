"""scatter-determinism (RL002): round bodies scatter with min/max only.

The repo's arbitrary-CRCW adaptation (docs/guidelines.md G3,
DESIGN/PAPER section 4) resolves concurrent hook writes with
commutative-idempotent **min-scatters** (``.at[].min`` / ``.at[].max``),
which is what keeps labels, round counts, and recorded spanning forests
bit-identical across the dense / frontier / sharded engines. A
``.at[].set`` or ``.at[].add`` whose index vector can contain
duplicates resolves by execution order instead -- silently
nondeterministic on parallel hardware.

Scope: SV round/hook bodies -- any function whose enclosing-name chain
matches ``sv<digit>`` / ``*round*`` / ``*hook*`` -- plus every file
under ``src/repro/kernels/``. Within scope, ``.at[idx].set/add/...``
with a non-constant index must be min/max, be pragma'd with a
commutation argument (e.g. all winners write the same stamp ``s``), or
be moved out of the round body.
"""
from __future__ import annotations

import ast
import re

from tools.lint import astutil
from tools.lint.core import LintPass, Module, Project

_SCOPE_NAME = re.compile(r"(^|_)(sv\d|round|hook)")
_NONCOMMUTATIVE = {"set", "add", "mul", "or_", "and_", "xor", "subtract"}


def _in_scope(info: astutil.FuncInfo, rel: str) -> bool:
    if "/kernels/" in rel:
        return True
    return any(_SCOPE_NAME.search(n) for n in info.qualnames)


def _at_scatter(node: ast.Call):
    """(array_expr, index_expr, method) for ``X.at[idx].method(...)``."""
    f = node.func
    if not (isinstance(f, ast.Attribute) and isinstance(f.value, ast.Subscript)):
        return None
    sub = f.value
    if not (isinstance(sub.value, ast.Attribute) and sub.value.attr == "at"):
        return None
    return sub.value.value, sub.slice, f.attr


class ScatterDeterminismPass(LintPass):
    name = "scatter-determinism"
    code = "RL002"
    guideline = "G3"
    description = (
        "only commutative-idempotent scatters (.at[].min/.at[].max) in "
        "SV round/hook/kernel bodies"
    )

    def applies_to(self, rel: str) -> bool:
        return rel.endswith(".py") and not rel.startswith("tests/")

    def check_module(self, module: Module, project: Project):
        for info in astutil.iter_functions(module.tree):
            if not _in_scope(info, module.rel):
                continue
            yield from self._check_fn(module, info)

    def _check_fn(self, module, info):
        # Walk only this function's own statements: nested defs get their
        # own FuncInfo visit, so descending into them double-reports.
        stack = list(ast.iter_child_nodes(info.node))
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            stack.extend(ast.iter_child_nodes(node))
            if not isinstance(node, ast.Call):
                continue
            hit = _at_scatter(node)
            if hit is None:
                continue
            _arr, idx, method = hit
            if method not in _NONCOMMUTATIVE:
                continue
            if isinstance(idx, ast.Constant):
                continue  # scalar-constant target: no duplicates possible
            yield self.finding(
                module,
                node,
                f"`.at[].{method}` in round/hook body `{info.name}`: "
                "duplicate index targets resolve by execution order, "
                "breaking the deterministic min-CRCW tie-break; use "
                ".at[].min/.at[].max, or pragma with the reason the "
                "writes commute (same-value stamps, provably unique "
                "indices)",
            )
