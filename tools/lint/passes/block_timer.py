"""block-timer (RL006): benchmarks block on device work before timing.

JAX dispatch is asynchronous: a ``fn(x)`` call returns as soon as the
work is enqueued, so ``t0 = perf_counter(); fn(x); dt = perf_counter()
- t0`` measures dispatch latency, not the kernel -- and un-blocked
work launched BEFORE a timer read smears into the next measurement.
Every benchmark in this repo therefore calls ``jax.block_until_ready``
inside the timed interval (``benchmarks/common.time_fn`` is the
canonical shape).

The pass mechanizes that rule for ``benchmarks/``: within a function,
for every pair of consecutive timer reads (``time.perf_counter`` /
``time.monotonic`` / ``time.time`` and their ``_ns`` variants), if the
interval between them contains any other call but no
``block_until_ready``, the second read is flagged -- whatever ran in
the interval may still be in flight when the clock is read.

Scope notes (single-pass, name-based, like every repro-lint pass):

* known host-only helpers (``print``/``emit``/``append``/``len``/...)
  do not count as work, so the ``emit(...)`` line between two timed
  loops does not force a spurious block;
* nested ``def``/``lambda`` bodies are separate timelines (a closure's
  calls run when IT runs, not between the enclosing reads);
* a timer read inside a loop pairs with itself across iterations
  (lexical order is the proxy), which is exactly the
  ``for _: t0=read(); work; times.append(read()-t0)`` shape time_fn
  uses -- the in-loop block satisfies both the lexical pair and the
  wrap-around one.
"""
from __future__ import annotations

import ast

from tools.lint import astutil
from tools.lint.core import LintPass, Module, Project

TIMER_CALLS = frozenset(
    {
        "time.perf_counter",
        "time.perf_counter_ns",
        "time.monotonic",
        "time.monotonic_ns",
        "time.time",
        "time.time_ns",
        "perf_counter",
        "perf_counter_ns",
        "monotonic",
        "monotonic_ns",
    }
)

# Calls that never launch device work: flagging the interval between
# two timed loops because it printed a result would be pure noise.
HOST_ONLY = frozenset(
    {
        "print",
        "emit",
        "append",
        "extend",
        "len",
        "range",
        "int",
        "float",
        "str",
        "format",
        "median",
        "mean",
        "min",
        "max",
        "sum",
        "sorted",
        "join",
        "flush",
    }
)


def _events(fn: ast.AST):
    """(kind, position, node) for every call lexically inside ``fn``,
    skipping nested function/lambda bodies. kind is 'timer', 'block',
    or 'work'."""
    out = []

    def visit(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
            ):
                continue
            if isinstance(child, ast.Call):
                name = astutil.call_name(child) or ""
                base = name.split(".")[-1]
                pos = (child.lineno, child.col_offset)
                if name in TIMER_CALLS:
                    out.append(("timer", pos, child))
                elif base == "block_until_ready":
                    out.append(("block", pos, child))
                elif base not in HOST_ONLY:
                    out.append(("work", pos, child))
            visit(child)

    visit(fn)
    out.sort(key=lambda e: e[1])
    return out


class BlockTimerPass(LintPass):
    name = "block-timer"
    code = "RL006"
    guideline = "C-bench"
    description = (
        "benchmarks call jax.block_until_ready between consecutive "
        "timer reads that bracket device work"
    )

    def applies_to(self, rel: str) -> bool:
        return rel.startswith("benchmarks/") and rel.endswith(".py")

    def check_module(self, module: Module, project: Project):
        for info in astutil.iter_functions(module.tree):
            events = _events(info.node)
            timers = [e for e in events if e[0] == "timer"]
            for first, second in zip(timers, timers[1:]):
                between = [
                    e for e in events if first[1] < e[1] < second[1]
                ]
                if not any(e[0] == "work" for e in between):
                    continue
                if any(e[0] == "block" for e in between):
                    continue
                yield self.finding(
                    module,
                    second[2],
                    f"timer read in `{info.name}` follows un-blocked "
                    "work (async dispatch: the interval may still be "
                    "executing); call jax.block_until_ready on the "
                    "result inside the timed interval "
                    "(benchmarks/common.time_fn is the pattern)",
                )
