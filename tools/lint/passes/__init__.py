"""The repro-lint pass registry. Order = report/report-code order."""
from tools.lint.passes.host_sync import HostSyncPass
from tools.lint.passes.scatter_determinism import ScatterDeterminismPass
from tools.lint.passes.compat_shim import CompatShimPass
from tools.lint.passes.choice_set import ChoiceSetPass
from tools.lint.passes.recompile_hazard import RecompileHazardPass
from tools.lint.passes.block_timer import BlockTimerPass

ALL_PASSES = (
    HostSyncPass(),
    ScatterDeterminismPass(),
    CompatShimPass(),
    ChoiceSetPass(),
    RecompileHazardPass(),
    BlockTimerPass(),
)

PASS_BY_NAME = {p.name: p for p in ALL_PASSES}
