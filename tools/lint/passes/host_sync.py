"""host-sync (RL001): no device->host round-trips in round-loop code.

The paper's adaptations (and the ROADMAP device-resident-loop item) say
per-level host round-trips dominate small-n wall clock: every
``int()`` / ``bool()`` / ``float()`` / ``.item()`` / ``np.asarray`` on
a traced value blocks on the device stream. This pass flags those
conversions inside **sync-sensitive functions**:

* functions jit-decorated (``@jax.jit`` / ``@partial(jax.jit, ...)``),
* functions calling ``lax.while_loop`` / ``fori_loop`` / ``scan``
  directly (round bodies), and
* host-side **driver** functions that call a module-jitted callable
  (the frontier engines' level loops).

A value is "device-derived" when its expression mentions a
``jnp.``/``jax.``/``lax.`` call, a call to a module-jitted function, or
a name assigned from one (``.shape``/``.ndim``-style static reads are
exempt). Intentional level-loop syncs -- the frontier engines' shrink
decisions, end-of-run stats materialization -- carry
``# repro-lint: disable=host-sync`` pragmas with a reason.
"""
from __future__ import annotations

import ast

from tools.lint import astutil
from tools.lint.core import LintPass, Module, Project

_CONTROL_FLOW = (
    "lax.while_loop",
    "jax.lax.while_loop",
    "lax.fori_loop",
    "jax.lax.fori_loop",
    "lax.scan",
    "jax.lax.scan",
)

_CONVERTERS = ("int", "bool", "float")
_ASARRAY = ("np.asarray", "numpy.asarray", "onp.asarray")


def _calls_any(fn: ast.FunctionDef, names) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            cn = astutil.call_name(node)
            if cn in names:
                return True
    return False


def _calls_jitted(fn: ast.FunctionDef, jitted: dict) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            cn = astutil.call_name(node)
            if cn is not None and cn.split(".")[-1] in jitted:
                return True
    return False


class HostSyncPass(LintPass):
    name = "host-sync"
    code = "RL001"
    guideline = "G3"
    description = (
        "device->host conversions (int/bool/float/.item/np.asarray) in "
        "jitted or round-loop code"
    )

    def applies_to(self, rel: str) -> bool:
        return rel.endswith(".py") and not rel.startswith("tests/")

    def check_module(self, module: Module, project: Project):
        jitted = astutil.module_jitted(module.tree)
        sensitive_roots = []
        for info in astutil.iter_functions(module.tree):
            if info.parents:
                continue  # nested defs are covered via their root
            fn = info.node
            if (
                info.is_jitted
                or _calls_any(fn, _CONTROL_FLOW)
                or _calls_jitted(fn, jitted)
            ):
                sensitive_roots.append(fn)
        for fn in sensitive_roots:
            tainted = astutil.function_taint(fn, jitted)
            yield from self._check_fn(module, fn, tainted, jitted)

    def _check_fn(self, module, fn, tainted, jitted):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            cn = astutil.call_name(node)
            if cn in _CONVERTERS and len(node.args) == 1:
                if astutil.expr_is_device(node.args[0], tainted, jitted):
                    yield self.finding(
                        module,
                        node,
                        f"`{cn}()` on a device value in `{fn.name}` forces "
                        "a device->host sync per call; keep the loop "
                        "device-resident (lax.while_loop carry) or pragma "
                        "as an intentional level-loop sync",
                    )
            elif cn in _ASARRAY and node.args:
                if astutil.expr_is_device(node.args[0], tainted, jitted):
                    yield self.finding(
                        module,
                        node,
                        f"`{cn}()` on a device value in `{fn.name}` "
                        "synchronously copies device->host; move the "
                        "materialization out of the round path or pragma "
                        "as an intentional sync",
                    )
            elif (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "item"
                and not node.args
            ):
                if astutil.expr_is_device(node.func.value, tainted, jitted):
                    yield self.finding(
                        module,
                        node,
                        f"`.item()` on a device value in `{fn.name}` is a "
                        "blocking scalar readback; thread the scalar "
                        "through the loop carry instead",
                    )
