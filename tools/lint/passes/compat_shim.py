"""compat-shim (RL003): JAX drift-prone APIs route through repro.compat.

PR 1's invariant: ``repro/compat.py`` is the ONE import site for every
JAX API that has moved across the supported release range
(``shard_map``'s home and kwarg names, ``make_mesh`` / ``AxisType``,
and ``Mesh`` as the shim's re-export anchor). Any direct import or
attribute use of those names outside compat.py reintroduces the drift
the shim exists to absorb -- the pinned CI lane (jax 0.4.x) and the
latest-jax lane only both stay green because call sites cannot bypass
the shim.

Flagged outside ``src/repro/compat.py``:

* ``from jax.sharding import Mesh`` / ``AxisType``
* ``from jax.experimental.shard_map import ...`` (any name)
* ``from jax import shard_map / make_mesh``
* attribute uses ``jax.shard_map`` / ``jax.make_mesh`` /
  ``jax.sharding.AxisType`` / ``jax.sharding.Mesh``

``PartitionSpec`` / ``NamedSharding`` have stable homes and stay
importable directly.
"""
from __future__ import annotations

import ast

from tools.lint import astutil
from tools.lint.core import LintPass, Module, Project

_SHIM_FILE = "src/repro/compat.py"
_SHARDING_NAMES = {"Mesh", "AxisType"}
_JAX_TOP_NAMES = {"shard_map", "make_mesh"}
_ATTR_USES = {
    "jax.shard_map",
    "jax.make_mesh",
    "jax.sharding.AxisType",
    "jax.sharding.Mesh",
    "jax.experimental.shard_map.shard_map",
}


class CompatShimPass(LintPass):
    name = "compat-shim"
    code = "RL003"
    guideline = "C-compat"
    description = (
        "drift-prone jax APIs (shard_map/Mesh/AxisType/make_mesh) "
        "imported only via repro.compat"
    )

    def check_module(self, module: Module, project: Project):
        if module.rel.endswith(_SHIM_FILE) or module.rel == "repro/compat.py":
            return
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ImportFrom):
                yield from self._check_import_from(module, node)
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "jax.experimental.shard_map":
                        yield self._flag(module, node, alias.name)
            elif isinstance(node, ast.Attribute):
                name = astutil.dotted_name(node)
                if name in _ATTR_USES:
                    yield self._flag(module, node, name)

    def _check_import_from(self, module, node):
        mod = node.module or ""
        for alias in node.names:
            if mod == "jax.sharding" and alias.name in _SHARDING_NAMES:
                yield self._flag(module, node, f"jax.sharding.{alias.name}")
            elif mod == "jax.experimental.shard_map":
                yield self._flag(
                    module, node, f"jax.experimental.shard_map.{alias.name}"
                )
            elif mod == "jax" and alias.name in _JAX_TOP_NAMES:
                yield self._flag(module, node, f"jax.{alias.name}")
            elif mod == "jax.experimental" and alias.name == "shard_map":
                yield self._flag(module, node, "jax.experimental.shard_map")

    def _flag(self, module, node, name):
        short = name.split(".")[-1]
        return self.finding(
            module,
            node,
            f"`{name}` used directly; import `{short}` from "
            "`repro.compat` -- the single API-drift shim site (PR 1 "
            "invariant; keeps jax 0.4.x and latest-jax lanes green)",
        )
