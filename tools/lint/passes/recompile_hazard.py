"""recompile-hazard (RL005): data-dependent shapes go through buckets.

XLA compiles per shape (docs/guidelines.md G5): a compiled-shape
argument derived from a **data-dependent host int** -- ``int()`` /
``float()`` / ``.item()`` of a device value, e.g. a live-frontier count
-- recompiles on every distinct value. The repo's discipline (the
frontier engines' shrink ladder, the serve engines' capacity buckets)
is to quantize such ints onto a static ladder first: ``next_pow2``,
``bucket_size`` (``core/operators.py``), ``pad_to`` / ``_pad_to``,
``tour_capacity``, ``frontier_sparse_capacity``,
``default_sparse_capacity``.

This pass taints names assigned from host-materialized device scalars
and flags tainted expressions reaching a compile-shape sink:

* a ``static_argnames`` kwarg of a module-jitted function,
* shape-carrying kwargs anywhere (``size=``, ``shape=``, ``pad_to=``,
  ``pad_edges_to=``, ``capacity=``, ``num_splitters=``),
* the shape argument of ``jnp.zeros/ones/full/empty/arange``, and
* any argument of a ``pallas_call``.

Routing the value through a quantizer (above) clears the taint.
"""
from __future__ import annotations

import ast

from tools.lint import astutil
from tools.lint.core import LintPass, Module, Project

SANITIZERS = frozenset(
    {
        "next_pow2",
        "bucket_size",
        "pad_to",
        "_pad_to",
        "tour_capacity",
        "frontier_sparse_capacity",
        "default_sparse_capacity",
    }
)

_SHAPE_KWARGS = {
    "size",
    "shape",
    "pad_to",
    "pad_edges_to",
    "capacity",
    "sparse_capacity",
    "num_splitters",
}

_SHAPE_CTORS = {
    "jnp.zeros",
    "jnp.ones",
    "jnp.full",
    "jnp.empty",
    "jnp.arange",
    "jnp.broadcast_to",
}


def _mentions_tainted(expr: ast.AST, tainted: set) -> bool:
    """A tainted name referenced outside any sanitizer call."""

    def visit(node) -> bool:
        if isinstance(node, ast.Call):
            cn = astutil.call_name(node)
            base = cn.split(".")[-1] if cn else None
            if base in SANITIZERS:
                return False
            return any(visit(c) for c in ast.iter_child_nodes(node))
        if isinstance(node, ast.Name):
            return node.id in tainted
        return any(visit(c) for c in ast.iter_child_nodes(node))

    return visit(expr)


class RecompileHazardPass(LintPass):
    name = "recompile-hazard"
    code = "RL005"
    guideline = "G5"
    description = (
        "data-dependent host ints reaching compiled shapes must be "
        "bucketed (next_pow2/pad_to/capacity)"
    )

    def applies_to(self, rel: str) -> bool:
        return rel.endswith(".py") and not rel.startswith("tests/")

    def check_module(self, module: Module, project: Project):
        jitted = astutil.module_jitted(module.tree)
        for info in astutil.iter_functions(module.tree):
            if info.parents:
                continue  # closures share the root function's taint walk
            tainted = astutil.function_taint(
                info.node,
                jitted,
                seed_calls=("int", "float"),
                skip_calls=SANITIZERS,
            )
            if not tainted:
                continue
            yield from self._check_fn(module, info.node, tainted, jitted)

    def _check_fn(self, module, fn, tainted, jitted):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            cn = astutil.call_name(node)
            base = cn.split(".")[-1] if cn else None
            if base in SANITIZERS:
                continue
            statics = jitted.get(base, ()) if base else ()
            is_pallas = base == "pallas_call" or (
                cn and cn.endswith(".pallas_call")
            )
            for kw in node.keywords:
                if kw.arg is None:
                    continue
                hazardous = (
                    kw.arg in statics
                    or kw.arg in _SHAPE_KWARGS
                    or is_pallas
                )
                if hazardous and _mentions_tainted(kw.value, tainted):
                    yield self.finding(
                        module,
                        kw.value,
                        f"`{kw.arg}=` at `{base}(...)` derives from a "
                        "data-dependent host int: every distinct value "
                        "recompiles; quantize via next_pow2/pad_to or a "
                        "capacity bucket first",
                    )
            if cn in _SHAPE_CTORS and node.args:
                if _mentions_tainted(node.args[0], tainted):
                    yield self.finding(
                        module,
                        node.args[0],
                        f"shape of `{cn}` derives from a data-dependent "
                        "host int: every distinct value recompiles; "
                        "quantize via next_pow2/pad_to first",
                    )
            elif is_pallas:
                for arg in node.args:
                    if _mentions_tainted(arg, tainted):
                        yield self.finding(
                            module,
                            arg,
                            "pallas_call argument derives from a "
                            "data-dependent host int: every distinct "
                            "value recompiles; quantize via "
                            "next_pow2/pad_to first",
                        )
