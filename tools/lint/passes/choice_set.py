"""choice-set (RL004): dispatch choice sets are constants synced to docs.

Mechanizes (and absorbs) ``tools/check_docs.py``: every public kwarg
validated by ``check_choice`` must

1. validate against a **module-level constant** (never an inline
   literal tuple -- those drift silently),
2. use a knob name registered in ``KNOBS`` below, and
3. have its registered constant match the ``docs/engines.md``
   choice-matrix row value-for-value and in order.

The constants are all literal string tuples, so the comparison is
fully static (AST-parsed; no jax import). ``tools/check_docs.py``
remains as a deprecation wrapper over the same comparison, keeping its
CLI contract (and ``tests/test_docs.py``) unchanged.

Adding a knob: define the tuple constant next to its engine, register
it in ``KNOBS``, and add the docs/engines.md row -- the pass fails
until all three agree, which is the point.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path

from tools.lint import astutil
from tools.lint.core import LintPass, Module, Project

# knob -> (repo-relative defining file, module-level constant name).
# The analogue of check_docs.code_choices(): new knobs register here.
KNOBS = {
    "engine": ("src/repro/core/__init__.py", "_CC_ENGINES"),
    "kernel_impl": ("src/repro/core/list_ranking.py", "KERNEL_IMPLS"),
    "hook_impl": ("src/repro/core/components.py", "HOOK_IMPLS"),
    "exchange": ("src/repro/distributed/graph.py", "EXCHANGES"),
    "rank_engine": ("src/repro/trees/compute.py", "RANK_ENGINES"),
    "pack_mode": ("src/repro/core/list_ranking.py", "PACK_MODES"),
    "kind": ("src/repro/serve/graph.py", "KINDS"),
    "sssp_engine": ("src/repro/core/sssp.py", "SSSP_ENGINES"),
    "pagerank_engine": ("src/repro/core/pagerank.py", "PAGERANK_ENGINES"),
    "on_overflow": ("src/repro/serve/engine.py", "OVERFLOW_POLICIES"),
    "on_failure": ("src/repro/serve/waves.py", "FAILURE_POLICIES"),
    "trace": ("src/repro/obs/trace.py", "TRACE_MODES"),
    "profile": ("src/repro/obs/trace.py", "PROFILE_MODES"),
}

DOCS_REL = "docs/engines.md"

_ROW = re.compile(r"^\|\s*`(?P<knob>\w+)=`\s*\|(?P<values>[^|]*)\|")
_TOKEN = re.compile(r"`([^`]+)`")

_LITERAL_NODES = (ast.Tuple, ast.List, ast.Set)


def documented_choices_with_lines(text: str) -> dict:
    """{knob: (ordered value tuple, lineno)} from the choice-matrix
    table (the table after the ``<!-- choice-matrix`` marker; parsing
    stops at the next heading -- engines.md has other tables)."""
    out: dict = {}
    in_matrix = False
    for i, line in enumerate(text.splitlines(), start=1):
        if "<!-- choice-matrix" in line:
            in_matrix = True
            continue
        if in_matrix and line.startswith("#"):
            break
        if not in_matrix:
            continue
        m = _ROW.match(line.strip())
        if not m or m.group("knob") in out:
            continue
        values = tuple(_TOKEN.findall(m.group("values")))
        if values:
            out[m.group("knob")] = (values, i)
    return out


def documented_choices(text: str) -> dict:
    """{knob: ordered value tuple} -- the check_docs.py contract."""
    return {k: v for k, (v, _ln) in documented_choices_with_lines(text).items()}


def code_choices(root: str | Path) -> dict:
    """{knob: ordered value tuple} parsed statically from the KNOBS
    registry files. Raises if a registered constant is missing or not a
    literal string tuple (that IS drift)."""
    root = Path(root)
    trees: dict = {}
    out: dict = {}
    for knob, (rel, const) in KNOBS.items():
        if rel not in trees:
            trees[rel] = astutil.module_constants(
                ast.parse((root / rel).read_text(), filename=rel)
            )
        if const not in trees[rel]:
            raise LookupError(
                f"{knob}=: registered constant {const} not found as a "
                f"module-level literal string tuple in {rel}"
            )
        out[knob] = trees[rel][const][0]
    return out


def compare(doc: dict, code: dict) -> list:
    """[(knob, problem string)] -- the exact checks check_docs.py ran."""
    problems = []
    for knob, want in sorted(code.items()):
        got = doc.get(knob)
        if got is None:
            problems.append(
                (
                    knob,
                    f"{knob}=: no choice-matrix row in docs/engines.md "
                    f"(code has {want})",
                )
            )
        elif got != want:
            problems.append(
                (
                    knob,
                    f"{knob}=: docs/engines.md says {got}, code says {want}",
                )
            )
    for knob in sorted(set(doc) - set(code)):
        problems.append(
            (
                knob,
                f"{knob}=: documented in docs/engines.md but not in the "
                "choice-set registry -- add it to "
                "tools/lint/passes/choice_set.py KNOBS",
            )
        )
    return problems


class ChoiceSetPass(LintPass):
    name = "choice-set"
    code = "RL004"
    guideline = "C-docs"
    description = (
        "check_choice sites use registered module-level constants that "
        "match the docs/engines.md matrix"
    )

    def check_module(self, module: Module, project: Project):
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            cn = astutil.call_name(node)
            if cn is None or cn.split(".")[-1] != "check_choice":
                continue
            if len(node.args) < 3:
                continue  # the definition / partial applications
            knob_arg, _value, choices = node.args[:3]
            if not (
                isinstance(knob_arg, ast.Constant)
                and isinstance(knob_arg.value, str)
            ):
                yield self.finding(
                    module,
                    node,
                    "check_choice knob name must be a string literal so "
                    "the choice-set pass can match it to docs/engines.md",
                )
                continue
            knob = knob_arg.value
            if isinstance(choices, _LITERAL_NODES) or (
                isinstance(choices, ast.Constant)
            ):
                yield self.finding(
                    module,
                    node,
                    f"check_choice('{knob}', ...) validates against an "
                    "inline literal; hoist it to a module-level constant "
                    "(inline sets drift out of sync with docs/engines.md)",
                )
            if knob not in KNOBS:
                yield self.finding(
                    module,
                    node,
                    f"check_choice knob '{knob}' is not registered; add "
                    "it to tools/lint/passes/choice_set.py KNOBS and give "
                    "it a docs/engines.md choice-matrix row",
                )

    def finalize(self, project: Project):
        docs_path = project.root / DOCS_REL
        if not docs_path.exists():
            yield self._docs_finding(
                1, f"{DOCS_REL} not found -- the choice matrix must exist"
            )
            return
        text = docs_path.read_text()
        doc_lines = documented_choices_with_lines(text)
        doc = {k: v for k, (v, _ln) in doc_lines.items()}
        try:
            code = code_choices(project.root)
        except (OSError, LookupError) as e:
            yield self._docs_finding(1, str(e))
            return
        for knob, problem in compare(doc, code):
            line = doc_lines.get(knob, ((), 1))[1]
            yield self._docs_finding(line, problem)

    def _docs_finding(self, line, message):
        from tools.lint.core import Finding

        return Finding(
            file=DOCS_REL,
            line=line,
            col=0,
            pass_name=self.name,
            code=self.code,
            message=message,
            guideline=self.guideline,
        )
