"""Repo tooling: ``tools.lint`` (repro-lint) and its thin wrappers."""
