"""Roofline machinery: HLO collective parser (incl. loop trip scaling) and
the analytic perfmodel validated against XLA cost analysis."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import perfmodel as pm
from repro.launch.roofline import collective_bytes, _shape_bytes


def test_shape_bytes_parsing():
    assert _shape_bytes("bf16[128,256]{1,0}") == 128 * 256 * 2
    assert _shape_bytes("(f32[8]{0}, s32[4]{0})") == 32 + 16
    assert _shape_bytes("pred[]") == 1


_SYNTH_HLO = """
%region_body.1 (arg: (s32[], f32[64])) -> (s32[], f32[64]) {
  %ar.1 = f32[64]{0} all-reduce(f32[64]{0} %x), replica_groups={}
}

%region_cond.2 (arg: (s32[], f32[64])) -> pred[] {
  %c.1 = s32[] constant(10)
  %cmp = pred[] compare(s32[] %iter, s32[] %c.1), direction=LT
}

ENTRY %main.3 (p0: f32[64]) -> f32[64] {
  %ag.1 = f32[128]{0} all-gather(f32[64]{0} %p0), dimensions={0}
  %w.1 = (s32[], f32[64]) while((s32[], f32[64]) %t), condition=%region_cond.2, body=%region_body.1
}
"""


def test_collective_parser_scales_loop_bodies():
    stats = collective_bytes(_SYNTH_HLO)
    # all-gather outside loop: 128*4 bytes, factor 1
    assert stats.bytes_by_op["all-gather"] == 128 * 4
    # all-reduce inside a 10-trip while: 64*4 * 2 (ring) * 10
    assert stats.bytes_by_op["all-reduce"] == 64 * 4 * 2 * 10
    assert stats.count_by_op["all-reduce"] == 10


def test_lm_perfmodel_vs_xla_cost_analysis():
    """Analytic forward flops within 40% of XLA's count on an unscanned
    1-layer probe (XLA adds elementwise/softmax ops the 2mnk model skips)."""
    import dataclasses

    from repro.configs import get_arch
    from repro.models.transformer import init_params, loss_fn

    cfg = dataclasses.replace(
        get_arch("phi3-mini-3.8b").smoke_config, num_layers=1, remat=False
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jnp.zeros((2, 64), jnp.int32),
        "labels": jnp.zeros((2, 64), jnp.int32),
    }
    compiled = jax.jit(lambda p: loss_fn(p, cfg, batch)).lower(params).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    xla_flops = float(cost["flops"])
    analytic = pm.lm_prefill_flops(cfg, 2, 64)
    assert 0.6 < analytic / xla_flops < 1.7, (analytic, xla_flops)


def test_perfmodel_moe_counts_active_only():
    from repro.configs import get_arch

    ds = get_arch("deepseek-v3-671b").config
    t = pm.lm_train_flops(ds, 256, 4096)
    # 6*N_active*T dominates; full-N would be ~18x bigger
    assert t < 6 * ds.total_params() * 256 * 4096 * 0.2
    assert t > 6 * ds.active_params() * 256 * 4096 * 0.99


def test_decode_flops_swa_capped():
    from repro.configs import get_arch

    mx = get_arch("mixtral-8x7b").config
    f_short = pm.lm_decode_flops(mx, 1, 4096)
    f_long = pm.lm_decode_flops(mx, 1, 524288)
    # sliding window caps the attention term -> equal flops
    assert f_short == f_long
