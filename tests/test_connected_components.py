"""Shiloach-Vishkin + label propagation vs union-find oracle; the paper's
round bound; graph-family behaviour (Figures 4-6 invariants)."""
import numpy as np

from conftest import given, settings, st  # hypothesis or skip-stubs

from repro.core import (
    label_propagation,
    num_components,
    shiloach_vishkin,
    sv_round_bound,
)
from repro.core.serial import canonicalize_labels, serial_connected_components
from repro.ops.kiss import list_graph, random_graph, tree_graph


def _check(edges: np.ndarray, n: int):
    ref = canonicalize_labels(serial_connected_components(edges, n))
    lab, rounds = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    np.testing.assert_array_equal(canonicalize_labels(np.asarray(lab)), ref)
    assert int(rounds) <= sv_round_bound(n)
    lab2, _ = label_propagation(edges[:, 0], edges[:, 1], n)
    np.testing.assert_array_equal(canonicalize_labels(np.asarray(lab2)), ref)
    return int(rounds)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 120), st.integers(1, 400), st.integers(0, 10_000))
def test_random_edge_lists(n, m, seed):
    r = np.random.default_rng(seed)
    edges = r.integers(0, n, size=(m, 2)).astype(np.int32)
    _check(edges, n)


def test_paper_graph_families():
    rounds = {}
    n = 2000
    rounds["list"] = _check(list_graph(n, 4, seed=1), n)
    rounds["tree"] = _check(tree_graph(n, 3, seed=2), n)
    rounds["random"] = _check(random_graph(n, 0.01, seed=3), n)
    # paper section 4: random graphs converge in fewer rounds than
    # trees/lists (smaller diameter after hooking)
    assert rounds["random"] <= rounds["tree"]
    assert rounds["random"] <= rounds["list"]


def test_singleton_and_empty_edges():
    edges = np.zeros((1, 2), np.int32)  # single self-loop
    lab, _ = shiloach_vishkin(edges[:, 0], edges[:, 1], 5)
    assert num_components(lab) == 5


def test_component_counting():
    edges = np.array([[0, 1], [2, 3], [3, 4]], np.int32)
    lab, _ = shiloach_vishkin(edges[:, 0], edges[:, 1], 6)
    assert num_components(lab) == 3  # {0,1}, {2,3,4}, {5}
