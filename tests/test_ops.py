"""Substrate ops: segment reductions, packing, embedding bag, sorted
dispatch, KISS determinism, neighbor sampler, striding layouts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-stubs

from repro.core.pram import partitioning_indices, striding_indices
from repro.ops import (
    embedding_bag,
    grouped_offsets,
    pack_aos,
    segment_mean,
    segment_softmax,
    segment_sum,
    sort_by_key,
    unpack_aos,
)
from repro.ops.kiss import KissRng
from repro.ops.neighbor_sampler import NeighborSampler, edges_to_csr
from repro.ops.sorted_dispatch import position_in_group, take_grouped


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(1, 20), st.integers(0, 1000))
def test_segment_sum_matches_numpy(n, k, seed):
    r = np.random.default_rng(seed)
    seg = r.integers(0, k, n)
    data = r.normal(size=(n, 3)).astype(np.float32)
    got = np.asarray(segment_sum(jnp.asarray(data), jnp.asarray(seg), k))
    ref = np.zeros((k, 3), np.float32)
    np.add.at(ref, seg, data)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)


def test_segment_softmax_normalizes():
    r = np.random.default_rng(0)
    seg = np.sort(r.integers(0, 10, 100))
    logits = r.normal(size=100).astype(np.float32) * 5
    p = np.asarray(segment_softmax(jnp.asarray(logits), jnp.asarray(seg), 10))
    sums = np.zeros(10)
    np.add.at(sums, seg, p)
    present = np.unique(seg)
    np.testing.assert_allclose(sums[present], 1.0, rtol=1e-5)


def test_segment_mean_empty_segments():
    out = np.asarray(
        segment_mean(jnp.ones((3, 2)), jnp.asarray([0, 0, 2]), 4)
    )
    np.testing.assert_allclose(out[0], 1.0)
    np.testing.assert_allclose(out[1], 0.0)  # empty -> 0, not nan


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 100), st.integers(0, 100))
def test_aos_pack_roundtrip(n, seed):
    r = np.random.default_rng(seed)
    rank = r.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    owner = r.integers(-(2**31), 2**31 - 1, n).astype(np.int32)
    pk = pack_aos(jnp.asarray(rank), jnp.asarray(owner))
    r2, o2 = unpack_aos(pk)
    np.testing.assert_array_equal(np.asarray(r2), rank)
    np.testing.assert_array_equal(np.asarray(o2), owner)


def test_embedding_bag_modes():
    r = np.random.default_rng(1)
    table = r.normal(size=(50, 8)).astype(np.float32)
    idx = r.integers(0, 50, 30)
    bags = np.sort(r.integers(0, 5, 30))
    for mode in ("sum", "mean", "max"):
        got = np.asarray(
            embedding_bag(
                jnp.asarray(table), jnp.asarray(idx), jnp.asarray(bags), 5,
                mode=mode,
            )
        )
        for b in range(5):
            rows = table[idx[bags == b]]
            if len(rows) == 0:
                np.testing.assert_allclose(got[b], 0.0)
                continue
            ref = {"sum": rows.sum(0), "mean": rows.mean(0), "max": rows.max(0)}[mode]
            np.testing.assert_allclose(got[b], ref, rtol=1e-5, atol=1e-5)


def test_embedding_bag_weighted_and_padding():
    table = np.eye(4, dtype=np.float32)
    idx = np.array([0, 1, 2])
    bags = np.array([0, 0, 7])  # 7 >= num_bags -> dropped
    w = np.array([2.0, 3.0, 1.0], np.float32)
    got = np.asarray(
        embedding_bag(
            jnp.asarray(table), jnp.asarray(idx), jnp.asarray(bags), 2,
            weights=jnp.asarray(w),
        )
    )
    np.testing.assert_allclose(got[0], [2, 3, 0, 0])
    np.testing.assert_allclose(got[1], 0)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 200), st.integers(1, 8), st.integers(0, 99))
def test_sorted_dispatch_invariants(n, k, seed):
    r = np.random.default_rng(seed)
    keys = jnp.asarray(r.integers(0, k, n).astype(np.int32))
    sk, perm = sort_by_key(keys)[:2]
    assert (np.diff(np.asarray(sk)) >= 0).all()
    counts, offsets = grouped_offsets(sk, k)
    assert np.asarray(counts).sum() == n
    pos = np.asarray(position_in_group(keys, k))
    # positions are a bijection within each key group
    for g in range(k):
        got = np.sort(pos[np.asarray(keys) == g])
        np.testing.assert_array_equal(got, np.arange(len(got)))


def test_take_grouped_capacity_drop():
    keys = jnp.asarray(np.array([0, 0, 0, 1], np.int32))
    vals = jnp.asarray(np.arange(4, dtype=np.float32)[:, None])
    buf, slot, kept = take_grouped(vals, keys, 2, capacity=2)
    assert np.asarray(kept).tolist() == [True, True, False, True]
    np.testing.assert_allclose(np.asarray(buf)[0, :, 0], [0, 1])
    np.testing.assert_allclose(np.asarray(buf)[1, 0, 0], 3)


def test_kiss_deterministic_and_distinct_streams():
    a = KissRng(42, 4).next_u32()
    b = KissRng(42, 4).next_u32()
    np.testing.assert_array_equal(a, b)
    assert len(np.unique(a)) == 4  # streams decorrelate
    c = KissRng(43, 4).next_u32()
    assert not np.array_equal(a, c)


def test_kiss_uniformity():
    rng = KissRng(0, 1024)
    draws = rng.uniform_ints((50_000,), 100)
    hist = np.bincount(draws, minlength=100)
    assert hist.min() > 300 and hist.max() < 700  # ~500 expected


def test_neighbor_sampler_valid_neighbors():
    edges = np.array([[0, 1], [1, 2], [2, 3], [3, 0]], np.int32)
    indptr, indices = edges_to_csr(edges, 4)
    s = NeighborSampler(indptr, indices, seed=0)
    blk = s.sample_hop(np.array([0, 2]), fanout=5)
    assert blk.src_nodes.shape == (10,)
    adj = {0: {1, 3}, 2: {1, 3}}
    for dst_i, src in zip(blk.dst_index, blk.src_nodes):
        assert src in adj[int(blk.dst_nodes[dst_i])]


def test_neighbor_sampler_isolated_nodes_selfloop():
    edges = np.array([[0, 1]], np.int32)
    indptr, indices = edges_to_csr(edges, 3)
    s = NeighborSampler(indptr, indices, seed=0)
    blk = s.sample_hop(np.array([2]), fanout=3)
    assert (blk.src_nodes == 2).all()


def test_striding_vs_partitioning_cover_all():
    n, p = 64, 8
    s = np.asarray(striding_indices(n, p))
    q = np.asarray(partitioning_indices(n, p))
    np.testing.assert_array_equal(np.sort(s.ravel()), np.arange(n))
    np.testing.assert_array_equal(np.sort(q.ravel()), np.arange(n))
    # striding: lane addresses within a step are CONTIGUOUS (coalesced)
    assert (np.diff(s[0]) == 1).all()
    # partitioning: they are n/p apart (uncoalesced on GPU/TPU)
    assert (np.diff(q[0]) == n // p).all()


def test_sharded_row_gather_meshless():
    from repro.ops.sharded_lookup import sharded_row_gather

    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    idx = jnp.asarray([3, 7, 0])
    out = sharded_row_gather(table, idx, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(table)[[3, 7, 0]])
