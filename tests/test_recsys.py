"""xDeepFM smoke + CIN correctness vs a naive reference + retrieval."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.recsys import recsys_batch
from repro.models.recsys.xdeepfm import (
    _cin,
    forward,
    init_params,
    loss_fn,
    serve_retrieval,
    serve_step,
)


def _setup():
    arch = get_arch("xdeepfm")
    cfg = arch.smoke_config
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = recsys_batch(16, cfg.n_fields, cfg.vocab_per_field, seed=1)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    return cfg, params, batch


def test_train_step_smoke():
    cfg, params, batch = _setup()
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
    assert 0.2 < float(loss) < 2.0  # BCE near log(2) at init


def test_serve_scores_in_unit_interval():
    cfg, params, batch = _setup()
    s = np.asarray(serve_step(params, cfg, batch))
    assert s.shape == (16,)
    assert (s > 0).all() and (s < 1).all()


def test_cin_matches_naive_reference():
    """CIN einsum vs the explicit outer-product definition."""
    cfg, params, _ = _setup()
    r = np.random.default_rng(0)
    x0 = r.normal(size=(3, cfg.n_fields, cfg.embed_dim)).astype(np.float32)
    got = np.asarray(_cin(params, jnp.asarray(x0)))
    xk = x0
    pooled = []
    for w in params["cin"]:
        w = np.asarray(w)
        z = np.einsum("bhd,bmd->bhmd", xk, x0)  # explicit outer product
        xk = np.einsum("bhmd,ohm->bod", z, w)
        pooled.append(xk.sum(-1))
    ref = np.concatenate(pooled, -1)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_retrieval_topk_matches_numpy():
    cfg, params, batch = _setup()
    q = {"sparse_ids": batch["sparse_ids"][:1]}
    scores, (top_vals, top_idx) = serve_retrieval(params, cfg, q, top_k=10)
    s = np.asarray(scores)
    ref_idx = np.argsort(-s)[:10]
    np.testing.assert_allclose(
        np.sort(np.asarray(top_vals)), np.sort(s[ref_idx]), rtol=1e-6
    )


def test_training_reduces_loss():
    """A few Adam steps on a fixed batch should reduce BCE (learnability)."""
    from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

    cfg, params, batch = _setup()
    opt_cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, warmup_steps=1)
    opt = init_opt_state(params, opt_cfg)
    first = None
    step = jax.jit(
        lambda p, o: (jax.value_and_grad(lambda q: loss_fn(q, cfg, batch))(p), o)
    )
    for _ in range(30):
        (loss, grads), _ = step(params, opt)
        params, opt, _m = adamw_update(grads, opt, params, opt_cfg)
        first = first if first is not None else float(loss)
    assert float(loss) < first * 0.9
