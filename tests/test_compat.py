"""compat-layer behaviour + deterministic (hypothesis-free) smoke coverage
of the core graph algorithms and their auto-dispatch wrappers. Runs on the
single-device test process; the 8-device paths live in test_multidev.py."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from conftest import random_succ
from repro import compat
from repro.core import connected_components, list_rank, shiloach_vishkin
from repro.core.serial import (
    canonicalize_labels,
    serial_connected_components,
    serial_list_rank,
)


def test_axis_type_sentinels_exist():
    assert compat.AxisType.Auto is not None
    assert len(compat.auto_axis_types(3)) == 3


def test_make_mesh_accepts_and_survives_axis_types():
    mesh = compat.make_mesh(
        (1, 1), ("data", "model"), axis_types=compat.auto_axis_types(2)
    )
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape == {"data": 1, "model": 1}


def test_make_mesh_explicit_devices_keeps_order():
    devs = jax.devices()[:1]
    mesh = compat.make_mesh((1,), ("graph",), devices=devs)
    assert list(mesh.devices.flat) == devs


def test_shard_map_runs_on_one_device_mesh():
    mesh = compat.make_mesh((1,), ("x",), devices=jax.devices()[:1])
    out = compat.shard_map(
        lambda v: jax.lax.psum(v, "x"),
        mesh=mesh,
        in_specs=P("x"),
        out_specs=P("x"),
        check_vma=False,
    )(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(out), np.arange(4.0))


def test_connected_components_dispatch_matches_serial():
    edges = np.array([[0, 1], [1, 2], [4, 5], [6, 6]], np.int32)
    n = 8
    ref = canonicalize_labels(serial_connected_components(edges, n))
    lab, rounds = connected_components(edges[:, 0], edges[:, 1], n)
    np.testing.assert_array_equal(canonicalize_labels(np.asarray(lab)), ref)
    assert int(rounds) >= 1
    lab2, _ = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(lab2))


def test_list_rank_dispatch_matches_serial():
    for n, p in [(40, 8), (257, 16)]:
        succ = random_succ(n, seed=n)
        ref = serial_list_rank(succ)
        got = np.asarray(list_rank(succ, p, seed=1))
        np.testing.assert_array_equal(got, ref)
