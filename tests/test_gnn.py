"""GNN smoke tests (reduced configs) + equivariance/invariance properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.graphs import full_graph, molecule_batch, sampled_minibatch
from repro.models.gnn import so3


def _smoke_graph(arch, n=40, m=120, d=8, num_graphs=4):
    r = np.random.default_rng(0)
    g = {
        "node_feats": jnp.asarray(r.normal(size=(n, d)), jnp.float32),
        "src": jnp.asarray(r.integers(0, n, m).astype(np.int32)),
        "dst": jnp.asarray(np.sort(r.integers(0, n, m)).astype(np.int32)),
        "graph_ids": jnp.asarray(
            np.sort(r.integers(0, num_graphs, n)).astype(np.int32)
        ),
        "num_graphs": num_graphs,
        "positions": jnp.asarray(r.normal(size=(n, 3)), jnp.float32),
        "species": jnp.asarray(r.integers(0, 5, n).astype(np.int32)),
    }
    kind = arch.label_kind("molecule")
    if kind == "graph_float":
        g["labels"] = jnp.asarray(r.normal(size=(num_graphs,)), jnp.float32)
    elif kind == "graph_int":
        g["labels"] = jnp.asarray(r.integers(0, 2, num_graphs).astype(np.int32))
    else:
        g["labels"] = jnp.asarray(r.integers(0, 3, n).astype(np.int32))
    return g


@pytest.mark.parametrize("name", ["gin-tu", "gat-cora", "egnn", "mace"])
def test_gnn_smoke_train_step(name):
    arch = get_arch(name)
    cfg = arch.smoke_config
    import dataclasses

    if hasattr(cfg, "readout") and arch.label_kind("molecule").startswith("graph"):
        cfg = dataclasses.replace(cfg, readout="graph")
    g = _smoke_graph(arch)
    params = arch.module.init_params(jax.random.PRNGKey(0), cfg)
    loss, grads = jax.value_and_grad(
        lambda p: arch.module.loss_fn(p, cfg, g)
    )(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(grads))


def test_cg_coefficients_equivariant():
    rng = np.random.default_rng(3)
    for (l1, l2, l3) in [(1, 1, 2), (2, 1, 1), (2, 2, 2), (1, 1, 0)]:
        C = so3.clebsch_gordan_real(l1, l2, l3)
        R = so3._rand_rotation(rng)
        D1 = so3.wigner_d_real(l1, R)
        D2 = so3.wigner_d_real(l2, R)
        D3 = so3.wigner_d_real(l3, R)
        lhs = np.einsum("abc,ax,by->xyc", C, D1, D2)
        rhs = np.einsum("abz,cz->abc", C, D3)
        assert np.abs(lhs - rhs).max() < 1e-10


def test_cg_triangle_inequality():
    assert so3.clebsch_gordan_real(0, 0, 1) is None
    assert so3.clebsch_gordan_real(2, 0, 1) is None
    assert so3.clebsch_gordan_real(1, 1, 3) is None


@pytest.mark.parametrize("name", ["egnn", "mace"])
def test_rotation_invariance(name):
    arch = get_arch(name)
    cfg = arch.smoke_config
    g = _smoke_graph(arch)
    params = arch.module.init_params(jax.random.PRNGKey(1), cfg)

    def readout(graph):
        out = arch.module.forward(params, cfg, graph)
        return out[0] if isinstance(out, tuple) else out

    base = np.asarray(readout(g))
    rng = np.random.default_rng(11)
    R = so3._rand_rotation(rng)
    g_rot = dict(g, positions=g["positions"] @ jnp.asarray(R.T, jnp.float32))
    rot = np.asarray(readout(g_rot))
    np.testing.assert_allclose(rot, base, rtol=2e-3, atol=2e-3)


def test_egnn_coordinate_equivariance():
    arch = get_arch("egnn")
    cfg = arch.smoke_config
    g = _smoke_graph(arch)
    params = arch.module.init_params(jax.random.PRNGKey(1), cfg)
    _, x1 = arch.module.forward(params, cfg, g)
    rng = np.random.default_rng(12)
    R = so3._rand_rotation(rng)
    g_rot = dict(g, positions=g["positions"] @ jnp.asarray(R.T, jnp.float32))
    _, x2 = arch.module.forward(params, cfg, g_rot)
    np.testing.assert_allclose(
        np.asarray(x2), np.asarray(x1) @ R.T, rtol=2e-3, atol=2e-3
    )


def test_data_builders_shapes():
    g = full_graph(200, 800, 16, with_positions=True)
    assert g["node_feats"].shape == (200, 16)
    assert (np.diff(g["dst"]) >= 0).all()  # sorted by destination (G1)
    mb = molecule_batch(8, d_feat=4)
    assert mb["graph_ids"].max() == 7
    smp = sampled_minibatch(500, 3000, 8, batch_nodes=16, fanouts=[3, 2])
    assert smp["src"].shape == smp["dst"].shape
    assert (smp["labels"] >= 0).sum() <= 16 * 1  # only seed nodes labeled


def test_gnn_edge_padding_is_harmless():
    """Padding edges with dst == n must not change results (OOB drop)."""
    arch = get_arch("gin-tu")
    cfg = arch.smoke_config
    g = _smoke_graph(arch)
    params = arch.module.init_params(jax.random.PRNGKey(0), cfg)
    base = np.asarray(arch.module.forward(params, cfg, g))
    n = g["node_feats"].shape[0]
    g_pad = dict(
        g,
        src=jnp.concatenate([g["src"], jnp.zeros(7, jnp.int32)]),
        dst=jnp.concatenate([g["dst"], jnp.full(7, n, jnp.int32)]),
    )
    padded = np.asarray(arch.module.forward(params, cfg, g_pad))
    np.testing.assert_allclose(padded, base, rtol=1e-6, atol=1e-6)
