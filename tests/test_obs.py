"""repro.obs: span tracing + metrics registry (docs/observability.md).

Covers the layer's contracts: disabled tracing is the shared no-op
singleton (zero allocation, zero events), spans nest with monotonic
Chrome-trace timestamps, the exported JSON round-trips, the metrics
snapshot of two identical fault-injected serve runs is identical, and
the instrumentation adds NO device->host sync (the RL001 lint pass
over the instrumented tree, plus a traced jitted-CC runtime smoke).
"""
import json
import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from repro.obs import metrics, trace  # noqa: E402
from repro.obs.metrics import Registry, derived_fragment  # noqa: E402
from repro.obs.summarize import format_table, main, summarize  # noqa: E402
from repro.obs.trace import _NULL_SPAN, Tracer  # noqa: E402


# ---------------------------------------------------------------------------
# tracer: disabled path
# ---------------------------------------------------------------------------


def test_disabled_span_is_the_shared_singleton():
    t = Tracer()  # trace="off" default
    s1 = t.span("a", bucket=4)
    s2 = t.span("b")
    assert s1 is _NULL_SPAN and s2 is _NULL_SPAN
    with s1 as sp:
        assert sp.tag(rounds=3) is sp
        assert sp.block_on("value") == "value"
    t.event("instant", uid=1)
    assert t.events == []


def test_disabled_timer_span_still_times_and_blocks():
    t = Tracer()
    x = jnp.arange(8)
    with t.span("step", device=True, timer=True) as sp:
        y = sp.block_on(x * 2)
    assert sp.duration > 0.0
    assert int(y[-1]) == 14
    assert t.events == []  # timed, not recorded


def test_configure_rejects_unknown_modes():
    t = Tracer()
    with pytest.raises(ValueError, match="trace"):
        t.configure(trace="loud")
    with pytest.raises(ValueError, match="profile"):
        t.configure(profile="always")
    t.configure(trace="on", profile="off")
    assert t.enabled


# ---------------------------------------------------------------------------
# tracer: enabled path
# ---------------------------------------------------------------------------


def test_nested_spans_monotonic_and_contained():
    t = Tracer(trace="on")
    with t.span("outer", n=2):
        with t.span("inner", i=0):
            pass
        with t.span("inner", i=1):
            pass
    t.event("marker", uid=9)
    # children record before the parent (close order); the event last
    names = [e["name"] for e in t.events]
    assert names == ["inner", "inner", "outer", "marker"]
    inner0, inner1, outer, marker = t.events
    assert all(e["ts"] >= 0 for e in t.events)
    assert inner0["ts"] <= inner1["ts"] <= marker["ts"]
    # containment: both children inside the parent interval
    for child in (inner0, inner1):
        assert outer["ts"] <= child["ts"]
        assert child["ts"] + child["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    assert outer["args"] == {"n": 2}
    assert inner1["args"] == {"i": 1}
    assert marker["ph"] == "i"


def test_span_records_exception_tag():
    t = Tracer(trace="on")
    with pytest.raises(RuntimeError):
        with t.span("boom"):
            raise RuntimeError("x")
    assert t.events[0]["args"]["exception"] == "RuntimeError"


def test_chrome_export_round_trips(tmp_path):
    t = Tracer(trace="on")
    with t.span("work", k=1):
        t.event("mid")
    path = tmp_path / "trace.json"
    n = t.export_chrome(str(path))
    assert n == 2
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert {e["ph"] for e in evs} == {"X", "i"}
    x = next(e for e in evs if e["ph"] == "X")
    assert x["name"] == "work" and x["dur"] >= 0 and x["args"] == {"k": 1}


def test_summarize_table_and_require(tmp_path, capsys):
    t = Tracer(trace="on")
    for _ in range(3):
        with t.span("serve.wave"):
            pass
    path = tmp_path / "t.json"
    t.export_chrome(str(path))
    rows = summarize(t.events)
    assert rows == [("serve.wave", 3, pytest.approx(rows[0][2]),
                     pytest.approx(rows[0][3]), pytest.approx(rows[0][4]))]
    assert "serve.wave" in format_table(rows)
    assert main([str(path), "--require", "serve.wave"]) == 0
    capsys.readouterr()
    assert main([str(path), "--require", "serve.bisect"]) == 1
    assert "REQUIRE FAIL" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_snapshot_flat_sorted_and_typed():
    r = Registry()
    r.inc("b.count")
    r.inc("b.count", 2)
    r.gauge("a.frac", 0.25)
    r.observe("c.ms", 3.0)
    r.observe("c.ms", 1.0)
    snap = r.snapshot()
    assert list(snap) == sorted(snap)
    assert snap["b.count"] == 3
    assert snap["a.frac"] == 0.25
    assert snap["c.ms.count"] == 2 and snap["c.ms.sum"] == 4.0
    assert snap["c.ms.min"] == 1.0 and snap["c.ms.max"] == 3.0


def test_registry_rejects_kind_aliasing():
    r = Registry()
    r.inc("x")
    with pytest.raises(ValueError, match="already a counter"):
        r.gauge("x", 1.0)


def test_derived_fragment_formats_ints_and_floats():
    frag = derived_fragment({"a.n": 3, "a.frac": 0.5, "b.n": 2.0}, "a.")
    assert frag == "a.frac=0.500;a.n=3"


def test_publish_stats_field_mapping():
    from dataclasses import dataclass

    @dataclass
    class S:
        hit: bool
        rounds: int
        frac: float
        sizes: np.ndarray
        levels: list
        name: str
        missing: None = None

    r = Registry()
    s = S(True, 4, 0.5, np.array([2, 3]), [1, 2, 3], "skipped")
    from repro.obs.metrics import publish_stats

    publish_stats(s, "t", r)
    publish_stats(s, "t", r)  # accumulates
    snap = r.snapshot()
    assert snap == {
        "t.frac": 0.5,       # gauge: last write wins
        "t.hit": 2,
        "t.levels.count": 6,
        "t.rounds": 8,
        "t.sizes.total": 10.0,
    }


# ---------------------------------------------------------------------------
# engine integration: determinism + no new syncs
# ---------------------------------------------------------------------------


def _chaos_engine():
    from repro.data.graphs import graph_request_stream
    from repro.serve import FaultPlan, GraphRequest, GraphServeEngine

    plan = FaultPlan.random(
        7, range(12), p_poison=0.15, p_transient=0.2, max_transient=1,
    )
    eng = GraphServeEngine(max_requests=4, fault_plan=plan, max_retries=1)
    stream = graph_request_stream(12, kind="cc", family="random", seed=3)
    for i, g in enumerate(stream):
        eng.submit(GraphRequest(uid=i, **g))
    eng.run()
    return eng


def test_engine_metrics_snapshot_deterministic_across_runs():
    """Two identical fault-injected serve runs -> identical unified
    snapshots (what lets benchmarks/run.py --check pin them)."""
    s1 = _chaos_engine().metrics.snapshot()
    s2 = _chaos_engine().metrics.snapshot()
    assert s1 == s2
    assert s1  # nonempty
    assert any(k.startswith("serve.health.") for k in s1)
    assert any(k.startswith("serve.graph.wave.") for k in s1)
    assert s1["serve.health.quarantined"] >= 1  # the plan really fired


def test_traced_chaos_run_produces_containment_spans():
    trace.reset()
    trace.configure(trace="on")
    try:
        _chaos_engine()
        names = {e["name"] for e in trace.chrome_trace()["traceEvents"]}
    finally:
        trace.configure(trace="off")
        trace.reset()
    assert {"serve.run", "serve.wave", "serve.wave.pack",
            "serve.wave.engine", "serve.quarantine"} <= names
    assert "serve.bisect.probe" in names or "serve.retry" in names


def test_traced_jitted_cc_stays_correct_and_synced():
    """Tracing on: the instrumented engines produce the same labels,
    and device spans close on already-synced boundaries (no tracer
    leaks, no exceptions under jit)."""
    from repro.core import frontier_shiloach_vishkin, shiloach_vishkin

    src = jnp.asarray(np.array([0, 1, 2, 4], np.int32))
    dst = jnp.asarray(np.array([1, 2, 3, 5], np.int32))
    base_d, _ = shiloach_vishkin(src, dst, 8)
    base_f, _ = frontier_shiloach_vishkin(src, dst, 8)
    trace.reset()
    trace.configure(trace="on")
    try:
        lab_d, _ = shiloach_vishkin(src, dst, 8)
        lab_f, _ = frontier_shiloach_vishkin(src, dst, 8)
        names = {e["name"] for e in trace.chrome_trace()["traceEvents"]}
    finally:
        trace.configure(trace="off")
        trace.reset()
    np.testing.assert_array_equal(np.asarray(lab_d), np.asarray(base_d))
    np.testing.assert_array_equal(np.asarray(lab_f), np.asarray(base_f))
    assert "cc.dense" in names
    assert "cc.frontier" in names and "cc.frontier.level" in names


def test_instrumented_tree_adds_no_host_syncs():
    """RL001 regression: the obs instrumentation must attach only at
    boundaries that already sync -- zero new host-sync findings across
    the instrumented tree."""
    from tools.lint import load_baseline, run_lint, split_baselined
    from tools.lint.passes import PASS_BY_NAME

    findings = run_lint(
        [os.path.join(_ROOT, "src")],
        root=_ROOT,
        passes=[PASS_BY_NAME["host-sync"]],
    )
    baseline = load_baseline(
        os.path.join(_ROOT, "tools", "lint", "baseline.json")
    )
    new, _old, stale = split_baselined(findings, baseline)
    assert [f.format() for f in new] == []
