"""Serving engine correctness + bonus-arch (GCN/SAGE/PNA) smoke tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.transformer import forward, init_params
from repro.serve.engine import Request, ServeEngine


def _engine(num_slots=2, max_len=32):
    cfg = get_arch("qwen3-4b").smoke_config
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params, ServeEngine(params, cfg, num_slots=num_slots,
                                    max_len=max_len)


def test_engine_matches_standalone_greedy_decode():
    """A single request through the engine must equal greedy decoding via
    forward() (teacher-forced argmax chain)."""
    cfg, params, eng = _engine(num_slots=2)
    prompt = [3, 7, 11]
    eng.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=5))
    out = eng.run()[0].output

    # reference: iterative greedy via full forward
    toks = list(prompt)
    for _ in range(5):
        logits = forward(params, cfg, jnp.asarray([toks], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert out == toks[len(prompt):]


def test_engine_batches_independent_requests():
    """Two requests in one wave decode as if each ran alone (slot caches
    are independent)."""
    cfg, params, eng = _engine(num_slots=2)
    eng.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=4))
    eng.submit(Request(uid=1, prompt=[5, 6, 7], max_new_tokens=4))
    outs = {r.uid: r.output for r in eng.run()}

    for uid, prompt in ((0, [1, 2]), (1, [5, 6, 7])):
        cfg2, params2, solo = _engine(num_slots=2)
        solo.submit(Request(uid=9, prompt=list(prompt), max_new_tokens=4))
        assert solo.run()[0].output == outs[uid], uid


def test_engine_multiple_waves_and_eos():
    cfg, params, eng = _engine(num_slots=2)
    for uid in range(5):
        eng.submit(Request(uid=uid, prompt=[uid + 1], max_new_tokens=3))
    done = eng.run()
    assert len(done) == 5 and eng.waves == 3
    assert all(len(r.output) == 3 and r.done for r in done)


def test_overlong_prompt_rejected_at_submit():
    """A prompt that can never emit a token must be rejected loudly at
    submit, not silently returned done=False after an exhausted wave
    loop; the P == max_len boundary still serves (one token)."""
    cfg, params, eng = _engine(num_slots=2, max_len=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(uid=0, prompt=list(range(1, 10)),
                           max_new_tokens=4))
    with pytest.raises(ValueError, match="empty"):
        eng.submit(Request(uid=1, prompt=[], max_new_tokens=4))
    assert eng.queue == []
    eng.submit(Request(uid=2, prompt=list(range(1, 9)), max_new_tokens=4))
    (r,) = eng.run()
    assert r.done and len(r.output) == 1  # rows 0..7 prefill, row 7 predicts


def test_overlong_prompt_truncate_mode():
    """on_overflow='truncate' keeps the last max_len tokens, flags the
    request, and decodes exactly like the pre-truncated prompt."""
    cfg, params, eng = _engine(num_slots=2)
    eng2 = ServeEngine(params, cfg, num_slots=2, max_len=8,
                       on_overflow="truncate")
    long_prompt = list(range(1, 14))
    eng2.submit(Request(uid=0, prompt=list(long_prompt), max_new_tokens=1))
    (r,) = eng2.run()
    assert r.truncated and r.done and r.prompt == long_prompt[-8:]

    ref = ServeEngine(params, cfg, num_slots=2, max_len=8)
    ref.submit(Request(uid=1, prompt=long_prompt[-8:], max_new_tokens=1))
    assert ref.run()[0].output == r.output
    with pytest.raises(ValueError, match="on_overflow"):
        ServeEngine(params, cfg, on_overflow="drop")


def test_zero_and_one_token_budgets():
    """max_new_tokens=0 finishes immediately with NO output (the old
    loop emitted one token before checking); 1 still decodes one."""
    cfg, params, eng = _engine(num_slots=2)
    eng.submit(Request(uid=0, prompt=[3, 7], max_new_tokens=0))
    eng.submit(Request(uid=1, prompt=[3, 7], max_new_tokens=1))
    done = {r.uid: r for r in eng.run()}
    assert done[0].done and done[0].output == []
    assert done[1].done and len(done[1].output) == 1
    assert eng.waves == 1  # the zero-budget request burned no wave slot

    # the 1-token result equals standalone greedy's first step
    logits = forward(params, cfg, jnp.asarray([[3, 7]], jnp.int32))
    assert done[1].output == [int(jnp.argmax(logits[0, -1]))]


def test_cache_fills_to_exactly_max_len():
    """The last KV row is usable: a request can decode until the cache
    holds exactly max_len tokens (max_len - P + 1 outputs), and those
    tokens match a roomier engine's prefix bit-for-bit."""
    M, P = 8, 3
    cfg, params, eng = _engine(num_slots=2, max_len=M)
    prompt = [2, 9, 4]
    eng.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=64))
    (r,) = eng.run()
    assert r.done and len(r.output) == M - P + 1  # was M - P - 1 pre-fix

    big = ServeEngine(params, cfg, num_slots=2, max_len=4 * M)
    big.submit(Request(uid=1, prompt=list(prompt),
                       max_new_tokens=M - P + 1))
    assert big.run()[0].output == r.output


@pytest.mark.parametrize("which", ["gcn", "sage", "pna"])
def test_extra_archs_smoke(which):
    from repro.models.gnn import extra

    r = np.random.default_rng(0)
    n, m, d, k = 50, 200, 16, 5
    graph = {
        "node_feats": jnp.asarray(r.normal(size=(n, d)), jnp.float32),
        "src": jnp.asarray(r.integers(0, n, m).astype(np.int32)),
        "dst": jnp.asarray(np.sort(r.integers(0, n, m)).astype(np.int32)),
        "labels": jnp.asarray(r.integers(0, k, n).astype(np.int32)),
    }
    cfgs = {
        "gcn": (extra.GCNConfig(in_dim=d, num_classes=k), extra.gcn_init,
                extra.gcn_forward, extra.gcn_loss),
        "sage": (extra.SAGEConfig(in_dim=d, num_classes=k), extra.sage_init,
                 extra.sage_forward, extra.sage_loss),
        "pna": (extra.PNAConfig(in_dim=d, num_classes=k), extra.pna_init,
                extra.pna_forward, extra.pna_loss),
    }
    cfg, init, fwd, loss = cfgs[which]
    params = init(jax.random.PRNGKey(0), cfg)
    logits = fwd(params, cfg, graph)
    assert logits.shape == (n, k)
    assert bool(jnp.isfinite(logits).all())
    l, g = jax.value_and_grad(lambda p: loss(p, cfg, graph))(params)
    assert bool(jnp.isfinite(l))
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


def test_extra_archs_learn_planted_labels():
    """GCN fits planted linear labels on a small graph (learnability)."""
    from repro.models.gnn import extra
    from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

    r = np.random.default_rng(1)
    n, m, d, k = 80, 400, 12, 4
    feats = r.normal(size=(n, d)).astype(np.float32)
    w_true = r.normal(size=(d, k)).astype(np.float32)
    graph = {
        "node_feats": jnp.asarray(feats),
        "src": jnp.asarray(r.integers(0, n, m).astype(np.int32)),
        "dst": jnp.asarray(np.sort(r.integers(0, n, m)).astype(np.int32)),
        "labels": jnp.asarray(np.argmax(feats @ w_true, -1).astype(np.int32)),
    }
    cfg = extra.GCNConfig(in_dim=d, num_classes=k, d_hidden=32)
    params = extra.gcn_init(jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig(lr=2e-2, weight_decay=0.0, warmup_steps=2)
    opt = init_opt_state(params, ocfg)
    grad_fn = jax.jit(jax.value_and_grad(lambda q: extra.gcn_loss(q, cfg, graph)))
    for _ in range(60):
        _loss, grads = grad_fn(params)
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
    logits = extra.gcn_forward(params, cfg, graph)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == graph["labels"]))
    assert acc > 0.6, acc
