"""List-ranking correctness: hypothesis property tests vs the serial oracle,
all pack modes, splitter statistics (paper Table 3 invariants)."""
import numpy as np
import pytest

from conftest import given, random_succ, settings, st
from repro.core import (
    even_splitters,
    max_splitters_for_linear_work,
    random_splitter_rank,
    select_splitters,
    wylie_rank,
)
from repro.core.serial import serial_list_rank


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 400), st.integers(0, 10_000))
def test_wylie_matches_serial(n, seed):
    succ = random_succ(n, seed)
    ref = serial_list_rank(succ)
    for pm in ("soa", "aos"):
        got = np.asarray(wylie_rank(succ, pack_mode=pm))
        np.testing.assert_array_equal(got, ref)


@settings(max_examples=25, deadline=None)
@given(
    st.integers(2, 500),
    st.integers(0, 10_000),
    st.sampled_from(["soa", "aos"]),
    st.integers(1, 64),
)
def test_random_splitter_matches_serial(n, seed, pack_mode, p):
    p = min(p, n)
    succ = random_succ(n, seed)
    ref = serial_list_rank(succ)
    got = np.asarray(
        random_splitter_rank(succ, p, seed=seed, pack_mode=pack_mode)
    )
    np.testing.assert_array_equal(got, ref)


def test_explicit_splitters_and_stats():
    n, p = 5000, 64
    succ = random_succ(n, 3)
    ref = serial_list_rank(succ)
    rank, stats = random_splitter_rank(succ, p, seed=1, with_stats=True)
    np.testing.assert_array_equal(np.asarray(rank), ref)
    # every node is owned by exactly one sub-list: lengths partition n
    assert stats.sublist_lengths.sum() == n
    # trip count == longest walk; terminal lanes count one fewer step than
    # their recorded length (the exit increment), hence the +-1 window
    assert abs(stats.walk_steps - int(stats.sublist_lengths.max())) <= 1
    assert stats.expected_mean == pytest.approx(n / p)


def test_even_splitters_have_uniform_sublists():
    n, p = 4096, 32
    succ = random_succ(n, 9)
    spl = even_splitters(succ, p)
    rank, stats = random_splitter_rank(
        succ, splitters=spl, with_stats=True
    )
    np.testing.assert_array_equal(np.asarray(rank), serial_list_rank(succ))
    # paper Table 3: perfect splitters -> equal length sub-lists (n/p +- 1)
    assert stats.sublist_lengths.max() - stats.sublist_lengths.min() <= 1


def test_select_splitters_distinct_and_covering():
    spl = select_splitters(10_000, 128, seed=5)
    assert len(np.unique(spl)) == 128
    assert spl[0] == 0  # head always included


def test_linear_work_bound():
    # paper: p log p <= n keeps the total work O(n)
    for n in (1_000_000, 10_000_000):
        p = max_splitters_for_linear_work(n)
        assert p * np.log2(p) <= n


def test_wylie_packed_equals_soa_large():
    succ = random_succ(20_000, 11)
    a = np.asarray(wylie_rank(succ, pack_mode="soa"))
    b = np.asarray(wylie_rank(succ, pack_mode="aos"))
    np.testing.assert_array_equal(a, b)


def test_kiss_generated_list_is_valid():
    from repro.ops.kiss import random_linked_list

    succ = random_linked_list(1000, seed=7)
    ref = serial_list_rank(succ)  # raises if the chain doesn't cover n
    assert ref.min() == 0 and ref.max() == 999


def test_unknown_kernel_impl_and_pack_mode_raise():
    """Unknown kernel_impl= used to fall through to the XLA path
    silently; now every dispatch string is validated, naming choices."""
    from repro.core import list_rank

    succ = random_succ(64, 3)
    with pytest.raises(ValueError, match="kernel_impl.*'pallas'"):
        random_splitter_rank(succ, 8, kernel_impl="palas")
    with pytest.raises(ValueError, match="kernel_impl.*'xla'"):
        list_rank(succ, 8, kernel_impl="bogus")
    with pytest.raises(ValueError, match="pack_mode.*'aos'"):
        random_splitter_rank(succ, 8, pack_mode="aso")
    with pytest.raises(ValueError, match="pack_mode"):
        wylie_rank(succ, pack_mode="bogus")
    from repro.distributed.graph import sharded_random_splitter_rank

    with pytest.raises(ValueError, match="kernel_impl"):
        sharded_random_splitter_rank(succ, 8, kernel_impl="bogus")


def test_kernel_impl_routes_are_bit_exact():
    succ = random_succ(300, 5)
    ref = np.asarray(random_splitter_rank(succ, 16, seed=1))
    for impl in ("auto", "pallas_interpret"):
        got = np.asarray(
            random_splitter_rank(succ, 16, seed=1, kernel_impl=impl)
        )
        np.testing.assert_array_equal(got, ref, err_msg=impl)
