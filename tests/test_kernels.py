"""Pallas kernels (interpret=True) vs pure-jnp oracles: shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import random_succ
from repro.kernels.edge_hook.ops import edge_hook
from repro.kernels.edge_hook.ref import edge_hook_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.pointer_jump.ops import pointer_jump
from repro.kernels.pointer_jump.ref import pointer_jump_ref
from repro.kernels.segment_sum.ops import segment_sum_sorted
from repro.kernels.splitter_aggregate.ops import splitter_aggregate
from repro.kernels.splitter_aggregate.ref import splitter_aggregate_ref


@pytest.mark.parametrize("p", [8, 57, 256, 1000])
def test_pointer_jump_sweep(p):
    succ = jnp.asarray(random_succ(p, seed=p))
    w = (succ != jnp.arange(p)).astype(jnp.int32)
    iters = int(np.ceil(np.log2(max(p, 2))))
    r1, l1 = pointer_jump(succ, w, impl="pallas_interpret")
    r2, l2 = pointer_jump_ref(succ, w, iters=iters)
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


@pytest.mark.parametrize("mode", ["sv2", "sv3"])
@pytest.mark.parametrize(
    "n,m,block_e", [(64, 300, 128), (500, 2000, 512), (1000, 777, 256)]
)
def test_edge_hook_sweep(mode, n, m, block_e):
    r = np.random.default_rng(n * 31 + m)
    a = jnp.asarray(r.integers(0, n, m).astype(np.int32))
    b = jnp.asarray(r.integers(0, n, m).astype(np.int32))
    # arbitrary label forest + stamps: the kernel contract is phasewise,
    # not whole-algorithm, so any state exercises it
    labels = jnp.asarray(r.integers(0, n, n).astype(np.int32))
    prev = jnp.asarray(r.integers(0, n, n).astype(np.int32))
    stamps = jnp.asarray(r.integers(0, 3, n).astype(np.int32))
    s = jnp.int32(3)
    got_d, got_q = edge_hook(
        a, b, labels, stamps, s, labels_prev=prev, mode=mode,
        impl="pallas_interpret", block_e=block_e,
    )
    ref_d, ref_q = edge_hook_ref(a, b, labels, prev, stamps, s, mode=mode)
    np.testing.assert_array_equal(np.asarray(got_d), np.asarray(ref_d))
    np.testing.assert_array_equal(np.asarray(got_q), np.asarray(ref_q))


def test_edge_hook_empty_edges():
    labels = jnp.arange(10, dtype=jnp.int32)
    stamps = jnp.zeros(10, jnp.int32)
    empty = jnp.zeros((0,), jnp.int32)
    got_d, got_q = edge_hook(
        empty, empty, labels, stamps, jnp.int32(1),
        mode="sv3", impl="pallas_interpret", block_e=64,
    )
    np.testing.assert_array_equal(np.asarray(got_d), np.arange(10))
    np.testing.assert_array_equal(np.asarray(got_q), np.zeros(10))


@pytest.mark.parametrize("n,p,block", [(100, 4, 64), (5000, 64, 512), (4096, 128, 2048)])
def test_splitter_aggregate_sweep(n, p, block):
    r = np.random.default_rng(n)
    packed = jnp.asarray(
        np.stack([r.integers(0, 50, n), r.integers(0, p, n)], -1).astype(np.int32)
    )
    sprank = jnp.asarray(r.integers(0, 10000, p).astype(np.int32))
    got = splitter_aggregate(packed, sprank, impl="pallas", block_n=block)
    ref = splitter_aggregate_ref(packed, sprank)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize(
    "m,d,ns,dtype",
    [
        (100, 4, 13, jnp.float32),
        (3000, 16, 700, jnp.float32),
        (2048, 32, 256, jnp.bfloat16),
        (513, 8, 999, jnp.float32),  # ragged sizes -> padding paths
    ],
)
def test_segment_sum_sweep(m, d, ns, dtype):
    r = np.random.default_rng(m)
    seg = np.sort(r.integers(0, ns, m)).astype(np.int32)
    data = jnp.asarray(r.normal(size=(m, d)), dtype)
    got = segment_sum_sorted(data, jnp.asarray(seg), ns, impl="pallas",
                             block_e=256, block_s=128)
    ref = jax.ops.segment_sum(data.astype(jnp.float32), jnp.asarray(seg), ns)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref), rtol=tol, atol=tol
    )


def test_segment_sum_skewed_degree():
    # one hot segment receiving most rows (power-law dst) crosses many
    # edge blocks -> exercises the multi-step accumulation path
    m, d, ns = 2000, 8, 64
    r = np.random.default_rng(5)
    seg = np.sort(np.minimum(r.integers(0, ns, m), 3)).astype(np.int32)
    data = jnp.asarray(r.normal(size=(m, d)).astype(np.float32))
    got = segment_sum_sorted(data, jnp.asarray(seg), ns, impl="pallas",
                             block_e=128, block_s=32)
    ref = jax.ops.segment_sum(data, jnp.asarray(seg), ns)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 32), (False, None)])
def test_flash_attention_sweep(hq, hkv, causal, window):
    r = np.random.default_rng(hq * 10 + hkv)
    B, S, D = 2, 128, 32
    q = jnp.asarray(r.normal(size=(B, hq, S, D)), jnp.float32)
    k = jnp.asarray(r.normal(size=(B, hkv, S, D)), jnp.float32)
    v = jnp.asarray(r.normal(size=(B, hkv, S, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          impl="pallas", block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    r = np.random.default_rng(9)
    q = jnp.asarray(r.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(r.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(r.normal(size=(1, 2, 128, 64)), jnp.bfloat16)
    got = flash_attention(q, k, v, impl="pallas", block_q=64, block_k=64)
    ref = attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref), rtol=3e-2, atol=3e-2
    )


def test_kernels_used_by_core_random_splitter():
    """RS4/RS5 kernel integration: run the splitter phases through the
    Pallas kernels and compare against the end-to-end core result."""
    from repro.core import random_splitter_rank
    from repro.core.serial import serial_list_rank

    succ = random_succ(3000, 21)
    ref = serial_list_rank(succ)
    rank = np.asarray(random_splitter_rank(succ, 64, seed=2, pack_mode="aos"))
    np.testing.assert_array_equal(rank, ref)
