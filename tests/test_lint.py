"""repro-lint (tools/lint): every pass has a known-bad fixture that it
flags at the right line and a known-good fixture it leaves alone, the
pragma/baseline layers suppress exactly what they claim to, and the
live tree stays clean against the committed baseline (docs/lint.md)."""
import json
import os
import sys

import pytest

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)

from tools.lint import (  # noqa: E402
    lint_source,
    load_baseline,
    run_lint,
    split_baselined,
)
from tools.lint.passes import PASS_BY_NAME  # noqa: E402
from tools.lint.passes import choice_set  # noqa: E402


def _lint(src, pass_name, rel="fixture.py", extra_files=None):
    """Run ONE pass over an in-memory fixture; only fixture findings."""
    findings = lint_source(
        src,
        rel=rel,
        passes=[PASS_BY_NAME[pass_name]],
        root=_ROOT,
        extra_files=extra_files,
    )
    return [f for f in findings if f.file == rel]


# ---------------------------------------------------------------------------
# host-sync (RL001)
# ---------------------------------------------------------------------------

_HOST_SYNC_BAD = """\
import jax
import jax.numpy as jnp

def drive(x):
    s = jax.lax.while_loop(lambda c: c[1], lambda c: c, (x, True))
    live = int(jnp.sum(s[0]))
    frac = jnp.mean(s[0]).item()
    return live, frac
"""

_HOST_SYNC_GOOD = """\
import jax
import jax.numpy as jnp

def drive(x):
    s = jax.lax.while_loop(lambda c: c[1], lambda c: c, (x, True))
    n = int(x.shape[0])
    return n

def helper(y):
    return int(jnp.sum(y))
"""


def test_host_sync_flags_conversions_in_round_loops():
    findings = _lint(_HOST_SYNC_BAD, "host-sync")
    assert [(f.code, f.line) for f in findings] == [("RL001", 6), ("RL001", 7)]


def test_host_sync_ignores_static_shape_reads_and_plain_helpers():
    assert _lint(_HOST_SYNC_GOOD, "host-sync") == []


def test_host_sync_trailing_pragma_suppresses():
    src = _HOST_SYNC_BAD.replace(
        "live = int(jnp.sum(s[0]))",
        "live = int(jnp.sum(s[0]))  # repro-lint: disable=host-sync",
    )
    assert [f.line for f in _lint(src, "host-sync")] == [7]


def test_host_sync_standalone_pragma_covers_next_line():
    src = _HOST_SYNC_BAD.replace(
        "    live = int(jnp.sum(s[0]))",
        "    # repro-lint: disable=host-sync\n    live = int(jnp.sum(s[0]))",
    )
    assert [f.line for f in _lint(src, "host-sync")] == [8]


# ---------------------------------------------------------------------------
# scatter-determinism (RL002)
# ---------------------------------------------------------------------------

_SCATTER_BAD = """\
import jax.numpy as jnp

def sv_round_fns(a, b, n):
    def round_body(D, Q, s):
        idx = jnp.where(D != Q, D, n)
        Q = Q.at[idx].set(s, mode="drop")
        D = D.at[idx].min(Q, mode="drop")
        return D, Q
    return round_body
"""

_SCATTER_GOOD = """\
import jax.numpy as jnp

def round_body(D, idx, vals, n):
    return D.at[idx].min(vals, mode="drop")

def merge_stats(words, s, vals):
    return words.at[s].add(vals)
"""


def test_scatter_flags_set_on_dup_capable_index_once():
    # Exactly ONE finding: round_body is in scope via both its own name
    # and its parent sv_round_fns -- the site must not double-report.
    findings = _lint(_SCATTER_BAD, "scatter-determinism")
    assert [(f.code, f.line) for f in findings] == [("RL002", 6)]


def test_scatter_allows_min_scatters_and_out_of_scope_fns():
    # .at[].min in a round body is the sanctioned min-CRCW form; the
    # .at[].add lives outside any sv/round/hook scope.
    assert _lint(_SCATTER_GOOD, "scatter-determinism") == []


def test_scatter_kernels_dir_is_always_in_scope():
    src = "def pack(buf, idx, v):\n    return buf.at[idx].set(v)\n"
    findings = _lint(src, "scatter-determinism", rel="src/repro/kernels/pack.py")
    assert [(f.code, f.line) for f in findings] == [("RL002", 2)]
    assert _lint(src, "scatter-determinism", rel="src/repro/core/pack.py") == []


# ---------------------------------------------------------------------------
# compat-shim (RL003)
# ---------------------------------------------------------------------------

_COMPAT_BAD = """\
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
"""

_COMPAT_GOOD = """\
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import Mesh, make_mesh, shard_map
"""


def test_compat_flags_direct_imports_of_drifting_apis():
    findings = _lint(_COMPAT_BAD, "compat-shim")
    assert [(f.code, f.line) for f in findings] == [("RL003", 1), ("RL003", 2)]


def test_compat_allows_stable_homes_and_the_shim():
    assert _lint(_COMPAT_GOOD, "compat-shim") == []


def test_compat_shim_file_itself_is_exempt():
    assert _lint(_COMPAT_BAD, "compat-shim", rel="src/repro/compat.py") == []


def test_compat_disable_file_pragma():
    src = "# repro-lint: disable-file=compat-shim\n" + _COMPAT_BAD
    assert _lint(src, "compat-shim") == []


# ---------------------------------------------------------------------------
# choice-set (RL004)
# ---------------------------------------------------------------------------

_CHOICE_BAD = """\
from repro.core.components import check_choice

def rank(pack_mode="aos"):
    check_choice("pack_mode", pack_mode, ("aos", "soa"))
    check_choice("mystery_knob", pack_mode, PACK_MODES)
"""

_CHOICE_GOOD = """\
from repro.core.components import check_choice
from repro.core.list_ranking import WYLIE_PACK_MODES

def rank(pack_mode="aos"):
    check_choice("pack_mode", pack_mode, WYLIE_PACK_MODES)
"""


def test_choice_set_flags_inline_literals_and_unknown_knobs():
    findings = _lint(_CHOICE_BAD, "choice-set")
    assert [(f.code, f.line) for f in findings] == [("RL004", 4), ("RL004", 5)]
    assert "inline literal" in findings[0].message
    assert "not registered" in findings[1].message


def test_choice_set_accepts_module_constants():
    assert _lint(_CHOICE_GOOD, "choice-set") == []


_MATRIX = """\
# Engines

<!-- choice-matrix -->
| knob | valid values |
|------|--------------|
| `engine=` | `auto` `dense` |
| `pack_mode=` | `aos` `soa` |

# Numeric knobs
| `ghost=` | `x` |
"""


def test_documented_choices_parses_only_the_marked_table():
    assert choice_set.documented_choices(_MATRIX) == {
        "engine": ("auto", "dense"),
        "pack_mode": ("aos", "soa"),
    }


def test_compare_reports_mismatch_missing_and_extra_rows():
    doc = choice_set.documented_choices(_MATRIX)
    code = {"engine": ("auto", "dense", "sparse"), "kind": ("cc",)}
    problems = dict(choice_set.compare(doc, code))
    assert "docs/engines.md says" in problems["engine"]
    assert "no choice-matrix row" in problems["kind"]
    assert "not in the choice-set registry" in problems["pack_mode"]


def test_choice_set_registry_matches_live_docs():
    """The pass reproduces check_docs.py: live code vs live docs."""
    doc = choice_set.documented_choices(
        open(os.path.join(_ROOT, "docs", "engines.md")).read()
    )
    code = choice_set.code_choices(_ROOT)
    assert choice_set.compare(doc, code) == []
    assert len(code) == 13


# ---------------------------------------------------------------------------
# recompile-hazard (RL005)
# ---------------------------------------------------------------------------

_RECOMPILE_BAD = """\
import jax.numpy as jnp

def drive(mask):
    live = int(jnp.sum(mask))
    buf = jnp.zeros(live, dtype=jnp.int32)
    return buf
"""

_RECOMPILE_GOOD = """\
import jax.numpy as jnp
from repro.core.frontier import next_pow2

def drive(mask):
    live = int(jnp.sum(mask))
    size = next_pow2(live)
    buf = jnp.zeros(size, dtype=jnp.int32)
    other = jnp.zeros(next_pow2(live))
    return buf, other
"""

_RECOMPILE_STATIC_BAD = """\
import jax
import jax.numpy as jnp
from functools import partial

@partial(jax.jit, static_argnames=("bound",))
def kernel(x, *, bound):
    return x[:bound]

def drive(x):
    b = int(jnp.max(x))
    return kernel(x, bound=b)
"""


def test_recompile_flags_data_dependent_shapes():
    findings = _lint(_RECOMPILE_BAD, "recompile-hazard")
    assert [(f.code, f.line) for f in findings] == [("RL005", 5)]


def test_recompile_cleared_by_pow2_bucketing():
    assert _lint(_RECOMPILE_GOOD, "recompile-hazard") == []


def test_recompile_flags_tainted_static_argnames():
    findings = _lint(_RECOMPILE_STATIC_BAD, "recompile-hazard")
    assert [(f.code, f.line) for f in findings] == [("RL005", 11)]
    assert "bound=" in findings[0].message


# ---------------------------------------------------------------------------
# block-timer (RL006)
# ---------------------------------------------------------------------------

_TIMER_BAD = """\
import time
import jax

def bench(fn, x):
    t0 = time.perf_counter()
    out = fn(x)
    dt = time.perf_counter() - t0
    t1 = time.monotonic()
    fn(out)
    print("warm")
    return time.monotonic() - t1, dt
"""

_TIMER_GOOD = """\
import time
import jax

def bench(fn, x):
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn(x))
    dt = time.perf_counter() - t0
    print("done", dt)
    t1 = time.perf_counter()
    emit("name", dt)
    t2 = time.perf_counter()
    return out, t2 - t1

def helper(fn, x):
    def inner(y):
        return fn(y)
    t0 = time.perf_counter()
    res = fn(x)
    res.block_until_ready()
    return time.perf_counter() - t0
"""


def test_block_timer_flags_unblocked_intervals():
    findings = _lint(_TIMER_BAD, "block-timer", rel="benchmarks/fix.py")
    assert [(f.code, f.line) for f in findings] == [("RL006", 7), ("RL006", 11)]
    assert "block_until_ready" in findings[0].message


def test_block_timer_accepts_blocked_intervals_and_host_helpers():
    # blocked work, host-only calls between reads, nested defs as
    # separate timelines, and the .block_until_ready() method form
    assert _lint(_TIMER_GOOD, "block-timer", rel="benchmarks/fix.py") == []


def test_block_timer_scoped_to_benchmarks_dir():
    assert _lint(_TIMER_BAD, "block-timer", rel="src/repro/core/x.py") == []
    assert _lint(_TIMER_BAD, "block-timer", rel="tests/test_x.py") == []


def test_block_timer_pragma_suppresses():
    src = _TIMER_BAD.replace(
        "    dt = time.perf_counter() - t0",
        "    dt = time.perf_counter() - t0  # repro-lint: disable=block-timer",
    )
    findings = _lint(src, "block-timer", rel="benchmarks/fix.py")
    assert [f.line for f in findings] == [11]


# ---------------------------------------------------------------------------
# baseline mechanics
# ---------------------------------------------------------------------------


def test_baseline_matches_by_snippet_despite_line_drift():
    findings = _lint(_COMPAT_BAD, "compat-shim")
    assert len(findings) == 2
    entries = [
        {"file": f.file, "pass": f.pass_name, "line": f.line + 40,
         "snippet": f.snippet}
        for f in findings
    ]
    new, old, stale = split_baselined(findings, entries)
    assert new == [] and len(old) == 2 and stale == []


def test_baseline_reports_stale_and_unmatched_entries():
    findings = _lint(_COMPAT_BAD, "compat-shim")
    entries = [
        {"file": findings[0].file, "pass": findings[0].pass_name,
         "snippet": findings[0].snippet},
        {"file": "gone.py", "pass": "compat-shim", "snippet": "import x"},
    ]
    new, old, stale = split_baselined(findings, entries)
    assert len(new) == 1 and len(old) == 1
    assert [e["file"] for e in stale] == ["gone.py"]


# ---------------------------------------------------------------------------
# the live tree and the CLI
# ---------------------------------------------------------------------------


def test_live_tree_has_no_new_findings():
    """`python -m tools.lint src tests benchmarks` stays clean: genuine
    violations get FIXED, intentional ones get a reasoned pragma, and
    only grandfathered debt lives in the committed baseline."""
    findings = run_lint(
        [os.path.join(_ROOT, d) for d in ("src", "tests", "benchmarks")],
        root=_ROOT,
    )
    baseline = load_baseline(
        os.path.join(_ROOT, "tools", "lint", "baseline.json")
    )
    new, _old, stale = split_baselined(findings, baseline)
    assert [f.format() for f in new] == []
    assert stale == []


def test_cli_exit_codes_and_json(tmp_path, capsys):
    from tools.lint.__main__ import main

    bad = tmp_path / "bad.py"
    bad.write_text(_COMPAT_BAD)
    assert main([str(bad), "--no-baseline"]) == 1
    assert "RL003" in capsys.readouterr().out

    assert main([str(bad), "--no-baseline", "--json"]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert [e["code"] for e in payload] == ["RL003", "RL003"]

    good = tmp_path / "good.py"
    good.write_text("x = 1\n")
    assert main([str(good)]) == 0
    assert main(["--list-passes"]) == 0


def test_cli_rejects_unknown_pass_selection(capsys):
    from tools.lint.__main__ import main

    assert main(["--select", "no-such-pass"]) == 2
    assert "unknown pass" in capsys.readouterr().err


def test_check_docs_wrapper_delegates_to_choice_set():
    sys.path.insert(0, os.path.join(_ROOT, "tools"))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    assert check_docs.check() == []
    assert check_docs.code_choices() == choice_set.code_choices(_ROOT)
    assert set(check_docs.documented_choices(check_docs.DOCS.read_text())) == (
        set(check_docs.code_choices())
    )
