"""Config registry + dry-run spec construction for all 40 cells (abstract
only; the compile pass is exercised by launch/dryrun.py on the 512-device
mesh -- results in EXPERIMENTS.md)."""
import jax
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, all_cells, get_arch
from repro.launch.mesh import make_test_mesh


def test_registry_has_all_ten_archs():
    assert len(ARCH_NAMES) == 10
    for name in ARCH_NAMES:
        arch = get_arch(name)
        assert arch.name == name
        assert len(arch.shapes()) == 4


def test_forty_cells():
    assert len(all_cells()) == 40


def test_long_500k_skips_match_attention_kind():
    skipped = {
        name
        for name in ARCH_NAMES
        if get_arch(name).skip_reason("long_500k")
        if get_arch(name).family == "lm"
    }
    # all full-attention LMs skip; mixtral (SWA) runs
    assert skipped == {"gemma-2b", "phi3-mini-3.8b", "qwen3-4b", "deepseek-v3-671b"}


@pytest.mark.parametrize("name,shape", all_cells())
def test_build_spec_abstract(name, shape):
    """Every cell must produce a well-formed DryRunSpec (shapes, shardings,
    flop/byte models) on a small test mesh without any compilation."""
    arch = get_arch(name)
    if arch.skip_reason(shape):
        pytest.skip(arch.skip_reason(shape))
    mesh = make_test_mesh((1, 1), ("data", "model"))
    spec = arch.build(shape, mesh)
    n_args = len(jax.tree.leaves(spec.args))
    n_shard = len(jax.tree.leaves(spec.in_shardings, is_leaf=lambda x: x is None))
    assert n_args > 0
    assert spec.model_flops_total > 0
    assert spec.flops_total is None or spec.flops_total >= spec.model_flops_total * 0.5
    assert spec.hbm_bytes_per_device is None or spec.hbm_bytes_per_device > 0


def test_param_spec_divisibility_on_production_shapes():
    """Every sharded param dim must divide by its mesh axis size on the
    16x16 production mesh (checked abstractly via axis sizes)."""
    from repro.configs.lm_family import lm_path_rules
    from repro.models.transformer import init_params

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for name in ("gemma-2b", "phi3-mini-3.8b", "qwen3-4b", "deepseek-v3-671b",
                 "mixtral-8x7b"):
        cfg = get_arch(name).config
        params_abs = jax.eval_shape(
            lambda c=cfg: init_params(jax.random.PRNGKey(0), c)
        )
        specs = lm_path_rules(cfg, FakeMesh()).spec_tree(params_abs)

        def check(leaf, spec):
            for dim, part in zip(leaf.shape, tuple(spec)):
                if part is None:
                    continue
                axes = (part,) if isinstance(part, str) else part
                size = int(np.prod([FakeMesh.shape[a] for a in axes]))
                assert dim % size == 0, (name, leaf.shape, spec)

        jax.tree.map(
            check, params_abs, specs,
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict),
        )
