"""Multi-device checks, executed in fresh subprocesses (the test process is
pinned to 1 CPU device; these need 8 fake devices, and jax locks the device
count at first import). Each function prints MULTIDEV_OK on success.

Run directly: python tests/multidev_scripts.py <name>
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402


def moe_ep():
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import make_mesh
    from repro.models.transformer import MoEConfig, TransformerConfig
    from repro.models.transformer.moe import init_moe_params, moe_ffn, moe_ffn_local

    mesh = make_mesh((2, 4), ("data", "model"))
    for ep_axes, n_exp in [(("model",), 8), (("data", "model"), 8), (("model",), 2)]:
        cfg = TransformerConfig(
            name="t", num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
            head_dim=16, d_ff=64, vocab_size=11,
            moe=MoEConfig(num_experts=n_exp, top_k=2, d_ff_expert=16,
                          num_shared_experts=1, capacity_factor=8.0,
                          ep_axes=ep_axes),
            dtype="float32", remat=False,
        )
        mp = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)
        ref = moe_ffn_local(mp, cfg, x, jax.nn.silu)
        xs = jax.device_put(x, NamedSharding(mesh, P(("data",), None, None)))
        out = jax.jit(
            lambda p, x: moe_ffn(p, cfg, x, jax.nn.silu, mesh=mesh,
                                 dp_axes=("data",))
        )(mp, xs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
    print("MULTIDEV_OK")


def pipeline_pp():
    import jax, jax.numpy as jnp

    from repro.compat import make_mesh
    from repro.distributed.pipeline import pipeline_apply

    mesh = make_mesh((4,), ("pod",))
    num_stages, layers_per_stage, d = 4, 2, 8
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (num_stages, layers_per_stage, d, d)) * 0.3

    def layer_fn(x, lp):
        return jnp.tanh(x @ lp["w"])

    xs = jax.random.normal(jax.random.PRNGKey(1), (6, 5, d))  # 6 microbatches
    out = pipeline_apply(layer_fn, {"w": w}, xs, mesh, stage_axis="pod")

    # sequential reference
    ref = xs
    for s in range(num_stages):
        for l in range(layers_per_stage):
            ref = jnp.tanh(ref @ w[s, l])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)

    # differentiability through the pipeline (shard_map + ppermute transpose)
    def loss(w_):
        return jnp.sum(pipeline_apply(layer_fn, w_, xs, mesh, "pod") ** 2)

    g = jax.grad(lambda w_: loss({"w": w_}))(w)
    assert np.isfinite(np.asarray(g)).all()
    print("MULTIDEV_OK")


def sharded_lookup():
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import make_mesh
    from repro.ops.sharded_lookup import sharded_row_gather

    mesh = make_mesh((2, 4), ("data", "model"))
    table = jnp.asarray(np.random.default_rng(0).normal(size=(64, 8)),
                        jnp.float32)
    idx = jnp.asarray(np.random.default_rng(1).integers(0, 64, (4, 6)),
                      jnp.int32)
    ref = np.asarray(table)[np.asarray(idx)]
    ts = jax.device_put(table, NamedSharding(mesh, P("model", None)))
    xs = jax.device_put(idx, NamedSharding(mesh, P(("data",), None)))
    out = jax.jit(
        lambda t, i: sharded_row_gather(t, i, mesh, "model",
                                        idx_spec=P(("data",), None))
    )(ts, xs)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6)
    print("MULTIDEV_OK")


def gnn_edge_parallel():
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import make_mesh
    from repro.configs import get_arch

    mesh = make_mesh((2, 4), ("data", "model"))
    arch = get_arch("gin-tu")
    cfg = arch.smoke_config
    r = np.random.default_rng(0)
    n, m = 40, 128
    g = {
        "node_feats": jnp.asarray(r.normal(size=(n, cfg.in_dim)), jnp.float32),
        "src": jnp.asarray(r.integers(0, n, m).astype(np.int32)),
        "dst": jnp.asarray(np.sort(r.integers(0, n, m)).astype(np.int32)),
        "graph_ids": jnp.zeros(n, jnp.int32),
        "num_graphs": 1,
        "labels": jnp.asarray(r.integers(0, 3, n).astype(np.int32)),
    }
    import dataclasses
    cfg = dataclasses.replace(cfg, readout="node")
    params = arch.module.init_params(jax.random.PRNGKey(0), cfg)
    ref = float(arch.module.loss_fn(params, cfg, g))
    gs = dict(g)
    gs["src"] = jax.device_put(
        g["src"], NamedSharding(mesh, P(("data", "model"))))
    gs["dst"] = jax.device_put(
        g["dst"], NamedSharding(mesh, P(("data", "model"))))
    got = float(jax.jit(lambda p, gg: arch.module.loss_fn(p, cfg, gg))(params, gs))
    assert abs(got - ref) < 1e-4, (got, ref)
    print("MULTIDEV_OK")


def sharded_cc():
    import jax

    from repro.core import connected_components, shiloach_vishkin
    from repro.distributed.graph import graph_mesh, sharded_shiloach_vishkin
    from repro.ops.kiss import list_graph, random_graph, tree_graph

    assert jax.device_count() == 8, jax.device_count()
    mesh = graph_mesh(8)
    cases = [
        ("list", 500, list_graph(500, 4, seed=1)),
        ("tree", 500, tree_graph(500, 3, seed=2)),
        ("random", 400, random_graph(400, 0.02, seed=3)),
        ("tiny", 5, np.zeros((1, 2), np.int32)),  # shard < edge count
    ]
    r = np.random.default_rng(0)
    cases.append(("dense", 120, r.integers(0, 120, (700, 2)).astype(np.int32)))
    for name, n, edges in cases:
        ref_lab, ref_rounds = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
        lab, rounds = sharded_shiloach_vishkin(
            edges[:, 0], edges[:, 1], n, mesh=mesh
        )
        np.testing.assert_array_equal(
            np.asarray(lab), np.asarray(ref_lab), err_msg=name
        )
        assert int(rounds) == int(ref_rounds), (name, int(rounds), int(ref_rounds))
        # auto-dispatch picks the sharded engine on this 8-device process
        lab2, _ = connected_components(edges[:, 0], edges[:, 1], n)
        np.testing.assert_array_equal(np.asarray(lab2), np.asarray(ref_lab))
    print("MULTIDEV_OK")


def sharded_cc_sparse():
    import jax

    from repro.core import shiloach_vishkin
    from repro.distributed.graph import (
        cc_exchange_words_per_round,
        graph_mesh,
        sharded_shiloach_vishkin,
    )
    from repro.ops.kiss import giant_dust_graph, list_graph, random_graph

    assert jax.device_count() == 8, jax.device_count()
    mesh = graph_mesh(8)
    cases = [
        ("list", 500, list_graph(500, 4, seed=1)),
        ("giant+dust", 600, giant_dust_graph(600, 0.9, seed=2)),
        ("random", 400, random_graph(400, 0.02, seed=3)),
        ("tiny", 5, np.zeros((1, 2), np.int32)),
    ]
    for name, n, edges in cases:
        ref_lab, ref_rounds = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
        lab, rounds, st = sharded_shiloach_vishkin(
            edges[:, 0], edges[:, 1], n, mesh=mesh,
            exchange="sparse", with_stats=True,
        )
        np.testing.assert_array_equal(
            np.asarray(lab), np.asarray(ref_lab), err_msg=name
        )
        assert int(rounds) == int(ref_rounds), (name, int(rounds))
        # measured volumes: late rounds must undercut the dense 3n model
        words = cc_exchange_words_per_round(n, stats=st)
        assert len(words) == int(rounds)
        if int(rounds) > 1 and n >= 400:
            assert int(words[-1]) < 3 * n, (name, words.tolist())
        # once the frontier fits capacity, the exchange stays sparse
        # (5C+3 words/round: a win only when capacity << n -- the tiny
        # case's 64-pair floor exceeds 3n, so its volume check is skipped;
        # the fallback itself triggers on overflow, not on cost)
        if 5 * st.capacity + 3 < 3 * n:
            fits = st.frontier_per_round <= st.capacity
            assert (words[fits] < 3 * n).all(), (name, words.tolist())
        # overflow fallback (capacity too small for ANY round) is bit-exact
        lab2, rounds2 = sharded_shiloach_vishkin(
            edges[:, 0], edges[:, 1], n, mesh=mesh,
            exchange="sparse", sparse_capacity=2,
        )
        np.testing.assert_array_equal(
            np.asarray(lab2), np.asarray(ref_lab), err_msg=f"{name}/overflow"
        )
        assert int(rounds2) == int(ref_rounds)
    print("MULTIDEV_OK")


def sharded_frontier():
    import jax

    from repro.core import (
        connected_components,
        frontier_shiloach_vishkin,
        shiloach_vishkin,
    )
    from repro.distributed.graph import (
        graph_mesh,
        sharded_frontier_shiloach_vishkin,
    )
    from repro.ops.kiss import (
        giant_dust_graph,
        list_graph,
        random_graph,
        tree_graph,
    )

    assert jax.device_count() == 8, jax.device_count()
    mesh = graph_mesh(8)
    r = np.random.default_rng(0)
    cases = [
        ("list", 500, list_graph(500, 4, seed=1)),
        ("giant+dust", 600, giant_dust_graph(600, 0.9, seed=2)),
        ("random", 400, random_graph(400, 0.02, seed=3)),
        ("tree", 500, tree_graph(500, 3, seed=2)),
        ("tiny", 5, np.zeros((1, 2), np.int32)),  # shard < edge count
        ("dense", 120, r.integers(0, 120, (700, 2)).astype(np.int32)),
    ]
    for name, n, edges in cases:
        # the cross-engine guarantee: labels, rounds, AND hook forests
        # bit-identical to the dense walk and the single-device frontier
        ref_lab, ref_rounds, (hu_ref, hv_ref) = shiloach_vishkin(
            edges[:, 0], edges[:, 1], n, record_hooks=True
        )
        lab_f, rounds_f = frontier_shiloach_vishkin(
            edges[:, 0], edges[:, 1], n, min_bucket=16
        )
        np.testing.assert_array_equal(np.asarray(lab_f), np.asarray(ref_lab))
        assert int(rounds_f) == int(ref_rounds), name
        for exchange in ("sparse", "dense"):
            lab, rounds, (hu, hv), st = sharded_frontier_shiloach_vishkin(
                edges[:, 0], edges[:, 1], n, mesh=mesh, min_bucket=16,
                exchange=exchange, record_hooks=True, with_stats=True,
            )
            np.testing.assert_array_equal(
                np.asarray(lab), np.asarray(ref_lab), err_msg=name
            )
            assert int(rounds) == int(ref_rounds), (name, exchange)
            np.testing.assert_array_equal(
                np.asarray(hu), np.asarray(hu_ref),
                err_msg=f"{name}/{exchange}/hook_u",
            )
            np.testing.assert_array_equal(
                np.asarray(hv), np.asarray(hv_ref),
                err_msg=f"{name}/{exchange}/hook_v",
            )
            # buckets only shrink; visit accounting is per device
            sizes = [b for b, _ in st.levels]
            assert sizes == sorted(sizes, reverse=True), (name, exchange)
            assert st.num_devices == 8
        # forced overflow at a tiny explicit capacity stays bit-exact
        # and the stats record the dense-fallback rounds
        lab2, rounds2, st2 = sharded_frontier_shiloach_vishkin(
            edges[:, 0], edges[:, 1], n, mesh=mesh, min_bucket=16,
            sparse_capacity=2, with_stats=True,
        )
        np.testing.assert_array_equal(
            np.asarray(lab2), np.asarray(ref_lab), err_msg=f"{name}/overflow"
        )
        assert int(rounds2) == int(ref_rounds), name
        over = st2.frontier_per_round > 2
        if over.any():
            assert (st2.words_per_round[over] > n).all(), name
        # shard-local hook kernel path (interpret off-TPU)
        lab3, rounds3 = sharded_frontier_shiloach_vishkin(
            edges[:, 0], edges[:, 1], n, mesh=mesh, min_bucket=16,
            hook_impl="pallas_interpret",
        )
        np.testing.assert_array_equal(
            np.asarray(lab3), np.asarray(ref_lab), err_msg=f"{name}/kernel"
        )
        assert int(rounds3) == int(ref_rounds), name
    # the auto rule: an explicit mesh picks the sharded frontier engine
    n, edges = cases[0][1], cases[0][2]
    ref_lab, ref_rounds = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    lab, rounds = connected_components(edges[:, 0], edges[:, 1], n, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref_lab))
    assert int(rounds) == int(ref_rounds)
    print("MULTIDEV_OK")


def sharded_rank_pallas():
    import jax

    from repro.core import random_splitter_rank, select_splitters
    from repro.data.graphs import random_succ
    from repro.distributed.graph import graph_mesh, sharded_random_splitter_rank

    assert jax.device_count() == 8, jax.device_count()
    mesh = graph_mesh(8)
    for n, p, seed in [(1000, 64, 0), (333, 17, 4), (50, 3, 2)]:
        succ = random_succ(n, seed)
        spl = select_splitters(n, p, seed=seed)
        ref = np.asarray(random_splitter_rank(succ, splitters=spl))
        got = np.asarray(
            sharded_random_splitter_rank(
                succ, splitters=spl, mesh=mesh, kernel_impl="pallas_interpret"
            )
        )
        np.testing.assert_array_equal(got, ref, err_msg=f"n={n} p={p}")
    # "auto" resolves to the XLA phases off-TPU: same ranks either way
    succ = random_succ(200, 9)
    np.testing.assert_array_equal(
        np.asarray(
            sharded_random_splitter_rank(succ, 16, seed=1, mesh=mesh,
                                         kernel_impl="auto")
        ),
        np.asarray(random_splitter_rank(succ, 16, seed=1)),
    )
    print("MULTIDEV_OK")


def sharded_rank():
    import jax

    from repro.core import list_rank, random_splitter_rank, select_splitters
    from repro.data.graphs import random_succ
    from repro.distributed.graph import graph_mesh, sharded_random_splitter_rank

    assert jax.device_count() == 8, jax.device_count()
    mesh = graph_mesh(8)
    for n, p, seed in [(1000, 64, 0), (777, 37, 5), (50, 3, 2), (9, 9, 1)]:
        succ = random_succ(n, seed)
        spl = select_splitters(n, p, seed=seed)
        ref = np.asarray(random_splitter_rank(succ, splitters=spl))
        got = np.asarray(
            sharded_random_splitter_rank(succ, splitters=spl, mesh=mesh)
        )
        np.testing.assert_array_equal(got, ref, err_msg=f"n={n} p={p}")
        # default splitter selection must agree too (same KISS streams)
        ref2, st_ref = random_splitter_rank(succ, p, seed=seed, with_stats=True)
        got2, st = sharded_random_splitter_rank(
            succ, p, seed=seed, mesh=mesh, with_stats=True
        )
        np.testing.assert_array_equal(np.asarray(got2), np.asarray(ref2))
        np.testing.assert_array_equal(st.sublist_lengths, st_ref.sublist_lengths)
        assert st.walk_steps == st_ref.walk_steps
    # auto-dispatch smoke (8 visible devices -> sharded engine)
    succ = random_succ(321, 7)
    np.testing.assert_array_equal(
        np.asarray(list_rank(succ, 16, seed=3)),
        np.asarray(random_splitter_rank(succ, 16, seed=3)),
    )
    print("MULTIDEV_OK")


def sharded_trees():
    import jax

    from repro.core import shiloach_vishkin
    from repro.distributed.graph import graph_mesh, sharded_shiloach_vishkin
    from repro.trees import euler_tour, spanning_forest, tree_computations
    from repro.trees.reference import serial_tree_reference
    from repro.ops.kiss import giant_dust_graph, random_graph, tree_graph

    assert jax.device_count() == 8, jax.device_count()
    mesh = graph_mesh(8)
    cases = [
        ("tree", 500, tree_graph(500, 3, seed=1)),
        ("giant+dust", 600, giant_dust_graph(600, 0.9, seed=2)),
        ("random", 400, random_graph(400, 0.02, seed=3)),
    ]
    for name, n, edges in cases:
        # hook recording is neutral AND bit-identical to single-device
        ref_lab, ref_rounds, (hu_ref, hv_ref) = shiloach_vishkin(
            edges[:, 0], edges[:, 1], n, record_hooks=True
        )
        for exchange in ("dense", "sparse"):
            lab, rounds, (hu, hv) = sharded_shiloach_vishkin(
                edges[:, 0], edges[:, 1], n, mesh=mesh,
                exchange=exchange, record_hooks=True,
            )
            np.testing.assert_array_equal(
                np.asarray(lab), np.asarray(ref_lab), err_msg=name
            )
            assert int(rounds) == int(ref_rounds), (name, exchange)
            np.testing.assert_array_equal(
                np.asarray(hu), np.asarray(hu_ref),
                err_msg=f"{name}/{exchange}/hook_u",
            )
            np.testing.assert_array_equal(
                np.asarray(hv), np.asarray(hv_ref),
                err_msg=f"{name}/{exchange}/hook_v",
            )
        # end-to-end: sharded CC forest + sharded splitter ranking
        forest = spanning_forest(edges[:, 0], edges[:, 1], n, mesh=mesh)
        tour = euler_tour(forest.edge_u, forest.edge_v, n,
                          labels=forest.labels)
        comp = tree_computations(tour, rank_engine="splitter", mesh=mesh)
        ref = serial_tree_reference(forest.edge_u, forest.edge_v, n)
        for k, attr in [
            ("parent", "parent"), ("depth", "depth"),
            ("subtree_size", "subtree_size"),
            ("preorder", "preorder"), ("postorder", "postorder"),
        ]:
            np.testing.assert_array_equal(
                np.asarray(getattr(comp, attr)), ref[k],
                err_msg=f"{name}/{k}",
            )
    print("MULTIDEV_OK")


if __name__ == "__main__":
    globals()[sys.argv[1]]()
