"""Sharded frontier-compacted CC: bit-exactness (labels, rounds, hook
forests) vs the dense walk on a 1-device mesh, the frontier-driven
sparse-exchange capacity, the overflow fallback, and the new dispatch
rules. The real multi-device run lives in ``multidev_scripts.py
sharded_frontier`` (8 fake devices need a fresh subprocess)."""
import numpy as np
import pytest

from repro.core import (
    connected_components,
    frontier_shiloach_vishkin,
    shiloach_vishkin,
)
from repro.distributed.graph import (
    EXCHANGES,
    frontier_sparse_capacity,
    graph_mesh,
    sharded_frontier_shiloach_vishkin,
)
from repro.ops.kiss import giant_dust_graph, list_graph, random_graph, tree_graph


def _star(n):
    return np.stack(
        [np.zeros(n - 1, np.int32), np.arange(1, n, dtype=np.int32)], axis=1
    )


def _adversarial_families():
    r = np.random.default_rng(7)
    return {
        "long-chain": (2000, list_graph(2000, 1, seed=1)),
        "star": (1500, _star(1500)),
        "giant+dust": (2000, giant_dust_graph(2000, 0.9, seed=2)),
        "empty": (17, np.zeros((0, 2), np.int32)),
        "all-self-loops": (9, np.stack([np.arange(9)] * 2, axis=1).astype(np.int32)),
        "tree": (1200, tree_graph(1200, 3, seed=3)),
        "random": (800, random_graph(800, 0.01, seed=4)),
        "dense-multigraph": (150, r.integers(0, 150, (3000, 2)).astype(np.int32)),
    }


@pytest.mark.parametrize(
    "family", sorted(_adversarial_families()), ids=lambda f: f
)
def test_bit_exact_vs_dense_and_frontier(family):
    """Labels, round counts, AND hook forests match both the dense walk
    and the single-device frontier engine (the cross-engine guarantee),
    under the default sparse exchange."""
    n, edges = _adversarial_families()[family]
    mesh = graph_mesh(1)
    ref, rounds_ref, (hu_ref, hv_ref) = shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, record_hooks=True
    )
    lab_f, rounds_f = frontier_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, min_bucket=64
    )
    lab, rounds, (hu, hv) = sharded_frontier_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, mesh=mesh, min_bucket=64,
        record_hooks=True,
    )
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(lab_f))
    assert int(rounds) == int(rounds_ref) == int(rounds_f)
    np.testing.assert_array_equal(np.asarray(hu), np.asarray(hu_ref))
    np.testing.assert_array_equal(np.asarray(hv), np.asarray(hv_ref))


def test_dense_exchange_and_hook_kernel_bit_exact():
    n, edges = 1200, tree_graph(1200, 3, seed=3)
    mesh = graph_mesh(1)
    ref, rounds_ref = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    for kwargs in (
        {"exchange": "dense"},
        {"hook_impl": "pallas_interpret"},
    ):
        lab, rounds = sharded_frontier_shiloach_vishkin(
            edges[:, 0], edges[:, 1], n, mesh=mesh, min_bucket=64, **kwargs
        )
        np.testing.assert_array_equal(
            np.asarray(lab), np.asarray(ref), err_msg=str(kwargs)
        )
        assert int(rounds) == int(rounds_ref), kwargs


def test_frontier_driven_capacity_shrinks_with_buckets():
    """The sparse buffer is sized per level from the live frontier: once
    the bucket undercuts the fixed n/8 default, capacity follows it down
    and the measured per-round exchange words drop with the frontier."""
    n = 4000
    edges = list_graph(n, 1, seed=5)
    lab, rounds, st = sharded_frontier_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, mesh=graph_mesh(1), min_bucket=64,
        with_stats=True,
    )
    assert st.exchange == "sparse"
    assert len(st.capacities) == len(st.levels)
    for cap, (bucket, _r) in zip(st.capacities, st.levels):
        assert cap == frontier_sparse_capacity(n, bucket)
        assert cap <= max(64, n // 8)
    # capacities only shrink (the bucket ladder is monotone)
    assert st.capacities == sorted(st.capacities, reverse=True)
    assert min(st.capacities) < n // 8  # the frontier actually drove it
    # measured volumes: the last round's exchange undercuts the dense 3n
    assert int(st.words_per_round[-1]) < 3 * n
    # per-device visit accounting beats the dense sharded walk
    dense = 2 * st.m2 * int(rounds)
    assert st.edges_touched < dense / 2
    sizes = [b for b, _ in st.levels]
    assert sizes == sorted(sizes, reverse=True)


def test_overflow_fallback_bit_exact_and_recorded():
    """Force overflow with a tiny explicit capacity: labels/rounds stay
    bit-exact and the stats record the dense-fallback rounds (words at
    the dense 3n+3 level wherever the frontier exceeded capacity)."""
    n = 2000
    edges = giant_dust_graph(n, 0.9, seed=2)
    ref, rounds_ref = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    lab, rounds, st = sharded_frontier_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, mesh=graph_mesh(1), min_bucket=64,
        sparse_capacity=2, with_stats=True,
    )
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref))
    assert int(rounds) == int(rounds_ref)
    # an explicit capacity is honoured verbatim at every level
    assert st.capacities == [2] * len(st.levels)
    # every round whose frontier exceeded capacity records the dense
    # fallback: at least one of its three exchanges paid the full n
    # words (each phase decides overflow for itself, so a round can mix
    # a dense SV2 merge with a sparse SV3 merge)
    over = st.frontier_per_round > 2
    assert over.any()  # capacity 2 must overflow on this family
    assert (st.words_per_round[over] > n).all()
    # rounds that DID fit capacity stayed fully sparse (5C+3 words)
    if (~over).any():
        np.testing.assert_array_equal(
            st.words_per_round[~over], 5 * 2 + 3
        )


def test_engine_dispatch_sharded_frontier():
    n = 500
    edges = list_graph(n, 3, seed=10)
    mesh = graph_mesh(1)
    ref, rounds_ref = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    # auto + mesh -> sharded_frontier; explicit engine=; bucket knobs
    for kwargs in (
        {"mesh": mesh},
        {"engine": "sharded_frontier"},
        {"engine": "sharded_frontier", "mesh": mesh, "exchange": "dense"},
        {"mesh": mesh, "min_bucket": 64},
        {"mesh": mesh, "hook_impl": "pallas_interpret"},
        {"min_bucket": 64, "exchange": "sparse"},  # composed, default mesh
    ):
        lab, rounds = connected_components(
            edges[:, 0], edges[:, 1], n, **kwargs
        )
        np.testing.assert_array_equal(
            np.asarray(lab), np.asarray(ref), err_msg=str(kwargs)
        )
        assert int(rounds) == int(rounds_ref), kwargs
    # the sampling pre-pass has no sharded counterpart
    with pytest.raises(ValueError, match="single-device"):
        connected_components(
            edges[:, 0], edges[:, 1], n, mesh=mesh, sample_rounds=2
        )
    with pytest.raises(ValueError, match="single-device"):
        connected_components(
            edges[:, 0], edges[:, 1], n, engine="sharded_frontier", seed=1
        )
    # hook_impl pins a kernel hook path the dense sharded engine lacks
    with pytest.raises(ValueError, match="sharded_frontier"):
        connected_components(
            edges[:, 0], edges[:, 1], n, engine="dense", mesh=mesh,
            hook_impl="xla",
        )
    # inside jit, auto + mesh falls back to the traceable dense sharded walk
    import jax

    f = jax.jit(
        lambda s, d: connected_components(s, d, n, mesh=mesh)[0]
    )
    np.testing.assert_array_equal(
        np.asarray(f(edges[:, 0], edges[:, 1])), np.asarray(ref)
    )
    # unknown strings still raise naming the choices
    with pytest.raises(ValueError, match="sharded_frontier"):
        connected_components(edges[:, 0], edges[:, 1], n, engine="bogus")
    with pytest.raises(ValueError, match="'dense', 'sparse'"):
        sharded_frontier_shiloach_vishkin(
            edges[:, 0], edges[:, 1], n, mesh=mesh, exchange="bogus"
        )
    assert EXCHANGES == ("dense", "sparse")


def test_spanning_forest_engine_independent_through_mesh():
    """repro.trees consumes the hook record: the forest extracted via
    the sharded frontier engine is bit-identical to the single-device
    one (record_hooks=True guarantee)."""
    from repro.core import spanning_forest

    n = 800
    edges = random_graph(n, 0.01, seed=4)
    f_ref = spanning_forest(edges[:, 0], edges[:, 1], n, engine="dense")
    f_sf = spanning_forest(edges[:, 0], edges[:, 1], n, mesh=graph_mesh(1))
    np.testing.assert_array_equal(f_sf.labels, f_ref.labels)
    np.testing.assert_array_equal(f_sf.edge_u, f_ref.edge_u)
    np.testing.assert_array_equal(f_sf.edge_v, f_ref.edge_v)
    assert f_sf.rounds == f_ref.rounds
