"""word64 packing (the paper's literal 64-bit union) needs jax x64 mode,
which is process-global -- test in a fresh subprocess."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    import jax.numpy as jnp
    from repro.ops.packing import pack_word64, unpack_word64

    r = np.random.default_rng(0)
    rank = jnp.asarray(r.integers(0, 2**31 - 1, 1000), jnp.int32)
    owner = jnp.asarray(r.integers(0, 2**31 - 1, 1000), jnp.int32)
    w = pack_word64(rank, owner)
    assert w.dtype == jnp.int64
    r2, o2 = unpack_word64(w)
    np.testing.assert_array_equal(np.asarray(r2), np.asarray(rank))
    np.testing.assert_array_equal(np.asarray(o2), np.asarray(owner))
    # one gather of the packed word == two gathers of the halves
    idx = jnp.asarray(r.integers(0, 1000, 256), jnp.int32)
    ra, oa = unpack_word64(jnp.take(w, idx))
    np.testing.assert_array_equal(np.asarray(ra), np.asarray(rank)[np.asarray(idx)])
    np.testing.assert_array_equal(np.asarray(oa), np.asarray(owner)[np.asarray(idx)])
    print("WORD64_OK")
    """
)


@pytest.mark.slow
def test_word64_roundtrip_x64_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]
    )
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
        timeout=300, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "WORD64_OK" in proc.stdout
