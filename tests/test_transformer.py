"""Per-arch smoke tests (reduced configs) + decode/dispatch equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_arch
from repro.models.transformer import (
    forward,
    init_kv_cache,
    init_params,
    loss_fn,
    serve_step,
)
from repro.models.transformer.moe import init_moe_params, moe_ffn_local

LM_ARCHS = [
    "gemma-2b",
    "phi3-mini-3.8b",
    "qwen3-4b",
    "deepseek-v3-671b",
    "mixtral-8x7b",
]


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke_forward_and_train_step(name):
    arch = get_arch(name)
    cfg = arch.smoke_config
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    logits = forward(params, cfg, toks)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    batch = {"tokens": toks, "labels": toks}
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, cfg, batch))(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke_decode_matches_forward(name):
    arch = get_arch(name)
    cfg = arch.smoke_config
    if cfg.moe is not None:  # avoid capacity drops in the equivalence test
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    ref = forward(params, cfg, toks)
    cache = init_kv_cache(cfg, 2, 12)
    step = jax.jit(lambda p, c, t, i: serve_step(p, cfg, c, t, i))
    for i in range(12):
        logits, cache = step(params, cache, toks[:, i : i + 1], jnp.int32(i))
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(ref[:, -1]), rtol=5e-3, atol=5e-3
    )


def test_swa_ring_buffer_cache_is_window_sized():
    cfg = get_arch("mixtral-8x7b").smoke_config
    cache = init_kv_cache(cfg, 2, 100)
    assert cache["moe"]["k"].shape[2] == cfg.sliding_window  # ring, not 100


def test_moe_sorted_vs_unsorted_dispatch_identical():
    cfg = get_arch("mixtral-8x7b").smoke_config
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
    )
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    a = moe_ffn_local(p, cfg, x, jax.nn.silu)
    cfg_u = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch="unsorted")
    )
    b = moe_ffn_local(p, cfg_u, x, jax.nn.silu)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0 some tokens drop; output must stay finite
    and the residual path preserves them (branch-free drop semantics)."""
    cfg = get_arch("deepseek-v3-671b").smoke_config
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1.0)
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab_size)
    logits = forward(params, cfg, toks)
    assert bool(jnp.isfinite(logits).all())


def test_param_count_formulas_match_init():
    for name in LM_ARCHS:
        arch = get_arch(name)
        cfg = arch.smoke_config
        if cfg.mtp_depth:  # formula covers trunk only
            cfg = dataclasses.replace(cfg, mtp_depth=0)
        params = init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(int(x.size) for x in jax.tree.leaves(params))
        # analytic count ignores norms (tiny); allow 2%
        expected = cfg.total_params()
        assert abs(actual - expected) / expected < 0.02, name


def test_full_config_param_counts():
    """Published parameter counts (sanity for the roofline's N)."""
    ds = get_arch("deepseek-v3-671b").config
    assert 6.5e11 < ds.total_params() < 7.0e11
    assert 3.3e10 < ds.active_params() < 4.0e10
    mx = get_arch("mixtral-8x7b").config
    assert 4.4e10 < mx.total_params() < 5.0e10
    g = get_arch("gemma-2b").config
    assert 2.0e9 < g.total_params() < 3.2e9
