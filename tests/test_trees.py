"""repro.trees: spanning-forest extraction from CC hook decisions,
Euler tour construction, and batched tree computations, checked
bit-exactly against a serial NumPy oracle on adversarial tree shapes
and on both list-ranking engines."""
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-stubs

from repro.core import connected_components, num_components
from repro.core.components import shiloach_vishkin
from repro.core.frontier import frontier_shiloach_vishkin
from repro.core.serial import serial_connected_components
from repro.data.graphs import molecule_batch, random_tree, random_tree_forest
from repro.ops.kiss import giant_dust_graph, list_graph, random_graph, tree_graph
from repro.trees import (
    euler_tour,
    spanning_forest,
    tour_capacity,
    tree_analytics,
    tree_computations,
)
from repro.trees.reference import serial_tree_reference

FIELDS = ("parent", "depth", "subtree_size", "preorder", "postorder")


def _path(n):
    return np.stack(
        [np.arange(n - 1, dtype=np.int32),
         np.arange(1, n, dtype=np.int32)], axis=1
    )


def _star(n):
    return np.stack(
        [np.zeros(n - 1, np.int32), np.arange(1, n, dtype=np.int32)], axis=1
    )


def _caterpillar(spine):
    """Spine path + one leg per spine node."""
    su = np.arange(spine - 1, dtype=np.int32)
    legs = np.arange(spine, dtype=np.int32)
    return np.concatenate(
        [np.stack([su, su + 1], axis=1),
         np.stack([legs, legs + spine], axis=1)]
    ).astype(np.int32)


def _assert_matches_reference(u, v, n, *, root=None, pad_to=None,
                              engines=("wylie", "splitter")):
    ref = serial_tree_reference(u, v, n, root=root)
    tour = euler_tour(u, v, n, root=root, pad_to=pad_to)
    for eng in engines:
        comp = tree_computations(tour, rank_engine=eng)
        for k in FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(comp, k)), ref[k],
                err_msg=f"{k} ({eng})",
            )


def _forest_cases():
    r = np.random.default_rng(11)
    return {
        "tree": (400, tree_graph(400, 3, seed=1)),
        "giant+dust": (500, giant_dust_graph(500, 0.9, seed=2)),
        "random": (300, random_graph(300, 0.02, seed=3)),
        "lists": (400, list_graph(400, 7, seed=4)),
        "multigraph": (60, r.integers(0, 60, (500, 2)).astype(np.int32)),
        "empty": (9, np.zeros((0, 2), np.int32)),
    }


@pytest.mark.parametrize("family", sorted(_forest_cases()), ids=lambda f: f)
def test_spanning_forest_valid_and_engine_independent(family):
    n, edges = _forest_cases()[family]
    forest = spanning_forest(edges[:, 0], edges[:, 1], n, engine="dense")
    # exactly n - #components edges, every one a real input edge
    assert forest.num_edges == n - num_components(forest.labels)
    real = {
        (min(int(a), int(b)), max(int(a), int(b)))
        for a, b in edges if a != b
    }
    for a, b in zip(forest.edge_u, forest.edge_v):
        assert (min(int(a), int(b)), max(int(a), int(b))) in real
    # the forest spans the same partition as the input graph
    np.testing.assert_array_equal(
        serial_connected_components(
            np.stack([forest.edge_u, forest.edge_v], axis=1), n
        ),
        serial_connected_components(edges, n),
    )
    # frontier engine records the identical forest (deterministic ties)
    ff = spanning_forest(
        edges[:, 0], edges[:, 1], n, engine="frontier", min_bucket=64
    )
    np.testing.assert_array_equal(ff.edge_u, forest.edge_u)
    np.testing.assert_array_equal(ff.edge_v, forest.edge_v)


@pytest.mark.parametrize("engine", ["dense", "frontier"])
def test_record_hooks_bit_neutral(engine):
    """record_hooks=True leaves labels AND round counts bit-identical."""
    fn = {
        "dense": shiloach_vishkin,
        "frontier": frontier_shiloach_vishkin,
    }[engine]
    for n, edges in _forest_cases().values():
        ref_lab, ref_rounds = fn(edges[:, 0], edges[:, 1], n)
        lab, rounds, _hooks = fn(
            edges[:, 0], edges[:, 1], n, record_hooks=True
        )
        np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref_lab))
        assert int(rounds) == int(ref_rounds)


def test_record_hooks_bit_neutral_sharded():
    from repro.distributed.graph import graph_mesh, sharded_shiloach_vishkin

    mesh = graph_mesh(1)
    n, edges = _forest_cases()["giant+dust"]
    ref_lab, ref_rounds = sharded_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, mesh=mesh
    )
    lab, rounds, (hu, hv) = sharded_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, mesh=mesh, record_hooks=True
    )
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref_lab))
    assert int(rounds) == int(ref_rounds)
    # and the sharded record matches the dense engine's
    _, _, (hu_ref, hv_ref) = shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, record_hooks=True
    )
    np.testing.assert_array_equal(np.asarray(hu), np.asarray(hu_ref))
    np.testing.assert_array_equal(np.asarray(hv), np.asarray(hv_ref))


def test_afforest_prepass_forest_still_spans():
    n = 600
    edges = giant_dust_graph(n, 0.9, seed=6)
    forest = spanning_forest(
        edges[:, 0], edges[:, 1], n, engine="frontier",
        sample_rounds=3, min_bucket=64,
    )
    assert forest.num_edges == n - num_components(forest.labels)
    np.testing.assert_array_equal(
        serial_connected_components(
            np.stack([forest.edge_u, forest.edge_v], axis=1), n
        ),
        serial_connected_components(edges, n),
    )


@pytest.mark.parametrize(
    "shape", ["path", "star", "caterpillar", "random-tree", "kary-tree"]
)
def test_tree_computations_match_serial_reference(shape):
    if shape == "path":
        n, edges = 80, _path(80)
    elif shape == "star":
        n, edges = 64, _star(64)
    elif shape == "caterpillar":
        n, edges = 60, _caterpillar(30)
    elif shape == "random-tree":
        n, edges = 257, random_tree(257, seed=5)
    else:
        e = tree_graph(200, 4, seed=6)
        f = spanning_forest(e[:, 0], e[:, 1], 200)
        n, edges = 200, np.stack([f.edge_u, f.edge_v], axis=1)
    _assert_matches_reference(edges[:, 0], edges[:, 1], n)


def test_multi_tree_forest_and_padding():
    n = 300
    edges = random_tree_forest(n, 12, seed=7)
    u, v = edges[:, 0], edges[:, 1]
    _assert_matches_reference(u, v, n)
    # padded capacity must not change any result
    cap = tour_capacity(len(u))
    assert cap >= 2 * len(u)
    _assert_matches_reference(u, v, n, pad_to=cap)
    with pytest.raises(ValueError, match="pad_to"):
        euler_tour(u, v, n, pad_to=2 * len(u) - 2)


def test_padded_edge_buffer_tour_matches():
    """num_edges= (padded forest-edge buffer, the serve-path compile
    convention) is bit-neutral: the tour skips dead slots and every
    computation matches both the unpadded tour and the serial oracle,
    on both rank engines."""
    from repro.core.components import shiloach_vishkin

    F = 64
    for n, trees, seed in [(40, 5, 0), (60, 3, 1), (7, 7, 2), (30, 1, 3)]:
        edges = random_tree_forest(n, trees, seed=seed)
        u, v = edges[:, 0], edges[:, 1]
        ref = serial_tree_reference(u, v, n)
        up = np.zeros(F, np.int32)
        vp = np.zeros(F, np.int32)
        up[:len(u)], vp[:len(v)] = u, v
        labels, _ = shiloach_vishkin(u, v, n)
        tour = euler_tour(up, vp, n, labels=labels, num_edges=len(u))
        assert tour.num_arcs == 2 * len(u) and tour.capacity == 2 * F
        assert int(np.asarray(tour.valid).sum()) == tour.num_arcs
        for eng in ("wylie", "splitter"):
            comp = tree_computations(tour, rank_engine=eng)
            for k in FIELDS:
                np.testing.assert_array_equal(
                    np.asarray(getattr(comp, k)), ref[k],
                    err_msg=f"{k} ({eng}, n={n})",
                )
        # pad_edges_to through the one-shot pipeline: identical to the
        # unpadded pipeline, field for field
        base = tree_analytics(u, v, n, engine="dense")
        padded = tree_analytics(u, v, n, engine="dense", pad_edges_to=F)
        for k in FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(padded.computations, k)),
                np.asarray(getattr(base.computations, k)), err_msg=k,
            )
    with pytest.raises(ValueError, match="num_edges"):
        euler_tour(np.zeros(4, np.int32), np.zeros(4, np.int32), 5,
                   num_edges=5)
    with pytest.raises(ValueError, match="pad_edges_to"):
        tree_analytics(u, v, n, engine="dense", pad_edges_to=1)


def test_rerooted_single_tree():
    edges = random_tree(90, seed=8)
    _assert_matches_reference(edges[:, 0], edges[:, 1], 90, root=41)
    ref = serial_tree_reference(edges[:, 0], edges[:, 1], 90, root=41)
    assert ref["depth"][41] == 0 and ref["parent"][41] == 41


def test_degenerate_tours():
    # no edges at all: every node a size-1 root
    _assert_matches_reference(
        np.zeros(0, np.int32), np.zeros(0, np.int32), 5
    )
    comp = tree_computations(
        euler_tour(np.zeros(0, np.int32), np.zeros(0, np.int32), 5)
    )
    np.testing.assert_array_equal(np.asarray(comp.parent), np.arange(5))
    np.testing.assert_array_equal(np.asarray(comp.subtree_size), np.ones(5))
    # single edge
    _assert_matches_reference(
        np.array([1], np.int32), np.array([0], np.int32), 2
    )


def test_tree_analytics_end_to_end_molecule_batch():
    g = molecule_batch(8, nodes_per_graph=12, edges_per_graph=20, seed=9)
    n = 8 * 12
    ta = tree_analytics(g["src"], g["dst"], n, pad_to=tour_capacity(n))
    # spanning forest respects molecule boundaries: a component never
    # crosses graph_ids (molecule_batch unions disjoint graphs)
    labels = np.asarray(ta.forest.labels)
    for comp_label in np.unique(labels):
        gids = np.unique(g["graph_ids"][labels == comp_label])
        assert len(gids) == 1
    ref = serial_tree_reference(ta.forest.edge_u, ta.forest.edge_v, n)
    for k in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ta.computations, k)), ref[k], err_msg=k
        )
    # depth/size sanity: parent depths are one less, sizes telescope
    depth = np.asarray(ta.depth)
    parent = np.asarray(ta.parent)
    nonroot = parent != np.arange(n)
    np.testing.assert_array_equal(
        depth[nonroot], depth[parent[nonroot]] + 1
    )


def test_connected_components_record_hooks_via_dispatch():
    edges = list_graph(200, 3, seed=10)
    res = connected_components(
        edges[:, 0], edges[:, 1], 200, record_hooks=True
    )
    labels, rounds, (hu, hv) = res
    ref_lab, ref_rounds = connected_components(edges[:, 0], edges[:, 1], 200)
    np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref_lab))
    assert int(rounds) == int(ref_rounds)
    assert int((np.asarray(hu) < 200).sum()) == 200 - num_components(labels)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 60), st.integers(1, 8), st.integers(0, 10_000))
def test_random_forests_match_reference(n, trees, seed):
    edges = random_tree_forest(n, trees, seed=seed)
    u = edges[:, 0] if len(edges) else np.zeros(0, np.int32)
    v = edges[:, 1] if len(edges) else np.zeros(0, np.int32)
    _assert_matches_reference(u, v, n, engines=("wylie",))


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 50), st.integers(0, 150), st.integers(0, 10_000))
def test_random_graph_forests_are_spanning(n, m, seed):
    r = np.random.default_rng(seed)
    edges = r.integers(0, n, size=(m, 2)).astype(np.int32)
    forest = spanning_forest(edges[:, 0], edges[:, 1], n, engine="dense")
    assert forest.num_edges == n - num_components(forest.labels)
    np.testing.assert_array_equal(
        serial_connected_components(
            np.stack([forest.edge_u, forest.edge_v], axis=1), n
        ),
        serial_connected_components(edges, n),
    )
