"""Training substrate: optimizer, compression, checkpointing, elastic
resharding, straggler watchdog, microbatch accumulation, full loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager
from repro.train.compression import (
    compress_decompress,
    init_error_feedback,
    quantize_int8,
    dequantize_int8,
)
from repro.train.loop import LoopConfig, StragglerWatchdog, make_train_step, train
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def _quadratic_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 4)), jnp.float32)

    def loss_fn(params, batch=None):
        return jnp.mean((params["w"] - target) ** 2)

    params = {"w": jnp.zeros((8, 4))}
    return params, loss_fn, target


def test_adamw_converges_on_quadratic():
    params, loss_fn, target = _quadratic_problem()
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=1)
    opt = init_opt_state(params, cfg)
    for _ in range(200):
        g = jax.grad(loss_fn)(params)
        params, opt, m = adamw_update(g, opt, params, cfg)
    assert float(loss_fn(params)) < 1e-2
    assert float(m["grad_norm"]) < 1.0


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0, warmup_steps=1)
    opt = init_opt_state(params, cfg)
    huge = {"w": jnp.full(3, 1e9)}
    p2, _, m = adamw_update(huge, opt, params, cfg)
    assert float(m["grad_norm"]) > 1e8
    assert np.abs(np.asarray(p2["w"])).max() < 10.0


def test_int8_quantization_roundtrip_error():
    x = jnp.asarray(np.random.default_rng(1).normal(size=1000), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x)).max()
    assert err <= float(s) * 0.51 + 1e-9  # half-ulp of the int8 grid


def test_error_feedback_compression_converges():
    params, loss_fn, target = _quadratic_problem()
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=1)
    opt = init_opt_state(params, cfg)
    ef = init_error_feedback(params)
    for _ in range(300):
        g = jax.grad(loss_fn)(params)
        g, ef = compress_decompress(g, ef)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    assert float(loss_fn(params)) < 1e-2


def test_microbatch_accumulation_matches_full_batch():
    r = np.random.default_rng(2)
    x = jnp.asarray(r.normal(size=(8, 3)), jnp.float32)
    y = jnp.asarray(r.normal(size=(8,)), jnp.float32)

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    params = {"w": jnp.zeros(3)}
    opt_cfg = AdamWConfig(lr=1e-2, weight_decay=0.0, warmup_steps=1)
    batch = {"x": x, "y": y}
    full = make_train_step(loss_fn, opt_cfg, num_microbatches=1)
    micro = make_train_step(loss_fn, opt_cfg, num_microbatches=4)
    opt = init_opt_state(params, opt_cfg)
    p1, _, _, m1 = full(params, opt, None, batch)
    p2, _, _, m2 = micro(params, opt, None, batch)
    # microbatch losses average to the full-batch mean for equal-size chunks
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), rtol=1e-4)


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {
        "params": {"w": jnp.arange(6.0).reshape(2, 3)},
        "opt_state": {"step": jnp.int32(7), "m": {"w": jnp.ones((2, 3))}},
    }
    for step in (10, 20, 30):
        mgr.save(step, state, blocking=True)
    assert mgr.list_steps() == [20, 30]  # keep=2 GC'd step 10
    restored = mgr.restore(30, state)
    np.testing.assert_array_equal(
        restored["params"]["w"], np.asarray(state["params"]["w"])
    )
    assert int(restored["opt_state"]["step"]) == 7


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, {"params": {"w": jnp.zeros((2, 2))}}, blocking=True)
    with pytest.raises(ValueError, match="shape"):
        mgr.restore(1, {"params": {"w": jnp.zeros((3, 3))}})


def test_train_loop_resume_after_preemption(tmp_path):
    """Simulated preemption: run 6 steps with checkpoint_every=3, 'crash',
    restart -- the loop must resume from step 6, not step 0."""
    params, loss_fn, _ = _quadratic_problem()
    data = iter(lambda: {"dummy": jnp.zeros(())}, None)
    loop_cfg = LoopConfig(
        total_steps=6, checkpoint_every=3, checkpoint_dir=str(tmp_path),
        log_every=100,
    )
    opt_cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=1)
    _, out1 = train(dict(params), lambda p, b: loss_fn(p), data, opt_cfg, loop_cfg)
    assert len(out1["history"]) == 6
    # restart: should resume at 6 and do nothing more (total_steps reached)
    loop_cfg2 = LoopConfig(
        total_steps=8, checkpoint_every=3, checkpoint_dir=str(tmp_path),
        log_every=100,
    )
    _, out2 = train(dict(params), lambda p, b: loss_fn(p), data, opt_cfg, loop_cfg2)
    assert out2["history"][0]["step"] == 6  # resumed, not restarted
    assert len(out2["history"]) == 2


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(factor=3.0)
    for i in range(10):
        wd.observe(i, 0.1)
    assert wd.observe(10, 1.0)  # 10x the EMA -> flagged
    assert wd.slow_steps and wd.slow_steps[0][0] == 10
    assert not wd.observe(11, 0.12)


def test_elastic_fit_spec_drops_and_replicates():
    from types import SimpleNamespace

    from jax.sharding import PartitionSpec as P

    from repro.train.elastic import fit_spec

    mesh = SimpleNamespace(axis_names=("data", "model"),
                           shape={"data": 2, "model": 4})
    # axis missing from mesh -> dropped; non-divisible dim -> replicated
    assert fit_spec(P("pod", "model"), (4, 8), mesh) == P(None, "model")
    assert fit_spec(P("model"), (7,), mesh) == P(None)
    assert fit_spec(P(("data", "model")), (16,), mesh) == P(("data", "model"))


def test_elastic_reshard_roundtrip():
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import make_test_mesh
    from repro.train.elastic import reshard_state

    mesh = make_test_mesh((1, 1), ("data", "model"))
    state = {"w": np.arange(16.0).reshape(4, 4)}
    specs = {"w": P("model", None)}
    out = reshard_state(state, specs, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]), state["w"])
