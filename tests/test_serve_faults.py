"""Fault containment (`repro.serve`): quarantine + bisection, bounded
retry, graceful degradation, convergence sentinels, and the
deterministic `FaultPlan` harness. The acceptance bar: one poison in a
K-request wave is isolated in at most ceil(log2 K) + 1 extra wave runs
with the K-1 survivors bit-identical to solo; a forced round-bound hit
raises ConvergenceError instead of returning wrong labels."""
import math

import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-stubs

from repro.core import ConvergenceError
from repro.data.graphs import graph_request_stream
from repro.serve import (
    FaultPlan,
    GraphRequest,
    GraphServeEngine,
    InjectedEngineError,
    SimulatedOOM,
    TransientFault,
    classify_failure,
    is_resource_exhausted,
)

from test_serve_graph import _assert_matches_solo, _requests


def _stream(k, seed=1, kind="cc"):
    return graph_request_stream(k, kind=kind, seed=seed)


# ---------------------------------------------------------------------------
# classification
# ---------------------------------------------------------------------------


def test_failure_classification():
    assert classify_failure(TransientFault("x")) == "transient"
    assert classify_failure(SimulatedOOM("x")) == "resource"
    assert classify_failure(MemoryError("x")) == "resource"
    assert classify_failure(RuntimeError("RESOURCE_EXHAUSTED: oom")) == (
        "resource"
    )
    assert classify_failure(RuntimeError("ran out of memory on hbm")) == (
        "resource"
    )
    assert classify_failure(InjectedEngineError("x")) == "poison"
    assert classify_failure(ValueError("bad")) == "poison"
    assert is_resource_exhausted(SimulatedOOM("x"))
    assert not is_resource_exhausted(InjectedEngineError("x"))


def test_fault_plan_random_is_deterministic():
    uids = range(32)
    a = FaultPlan.random(7, uids, p_poison=0.3, p_transient=0.3)
    b = FaultPlan.random(7, uids, p_poison=0.3, p_transient=0.3)
    assert a.poison_uids == b.poison_uids
    assert a.transient_uids == b.transient_uids
    c = FaultPlan.random(8, uids, p_poison=0.3, p_transient=0.3)
    assert (a.poison_uids, a.transient_uids) != (
        c.poison_uids, c.transient_uids
    )


# ---------------------------------------------------------------------------
# poison bisection (the acceptance bound)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k,poison", [(8, 3), (8, 0), (8, 7), (5, 2)])
def test_poison_bisected_within_log_bound(k, poison):
    """One poison in a K-request wave: isolated, survivors bit-exact vs
    solo, and at most ceil(log2 K) + 1 extra wave runs."""
    stream = _stream(k)
    eng = GraphServeEngine(
        max_requests=k, fault_plan=FaultPlan(poison_uids=frozenset([poison])),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()

    assert len(done) == k  # every request terminates
    by_uid = {r.uid: r for r in done}
    bad = by_uid[poison]
    assert bad.failed and not bad.done and bad.result is None
    assert "InjectedEngineError" in bad.error
    for uid in range(k):
        if uid == poison:
            continue
        assert not by_uid[uid].failed
        _assert_matches_solo(by_uid[uid], stream[uid])

    h = eng.health_records[-1]
    extra = h.wave_runs - 1  # the doomed first wave is the baseline run
    assert extra <= math.ceil(math.log2(k)) + 1, (
        f"bisection used {extra} extra wave runs for K={k}"
    )
    assert h.quarantined == 1 and h.failed == 1 and h.completed == k - 1
    assert h.bisections == 1 and h.retried == 0 and h.degraded == 0


def test_two_poisons_both_isolated():
    """Multi-poison waves recurse: the deferred siblings' re-run hunts
    the second poison; every healthy request still completes."""
    k = 8
    stream = _stream(k, seed=3)
    eng = GraphServeEngine(
        max_requests=k, fault_plan=FaultPlan(poison_uids=frozenset([1, 6])),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    by_uid = {r.uid: r for r in done}
    assert len(done) == k
    assert by_uid[1].failed and by_uid[6].failed
    for uid in set(range(k)) - {1, 6}:
        _assert_matches_solo(by_uid[uid], stream[uid])
    h = eng.health_records[-1]
    assert h.quarantined == 2 and h.bisections >= 2


def test_poison_in_singleton_wave_quarantines_directly():
    stream = _stream(3, seed=5)
    eng = GraphServeEngine(
        max_requests=1, fault_plan=FaultPlan(poison_uids=frozenset([1])),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    h = eng.health_records[-1]
    assert {r.uid for r in done if r.failed} == {1}
    assert h.bisections == 0 and h.wave_runs == 3  # no probes needed


def test_on_failure_raise_restores_fail_fast():
    stream = _stream(4, seed=7)
    eng = GraphServeEngine(
        max_requests=4,
        on_failure="raise",
        fault_plan=FaultPlan(poison_uids=frozenset([2])),
    )
    for r in _requests(stream):
        eng.submit(r)
    with pytest.raises(InjectedEngineError):
        eng.run()
    with pytest.raises(ValueError, match="on_failure"):
        GraphServeEngine(on_failure="ignore")


# ---------------------------------------------------------------------------
# transient retry
# ---------------------------------------------------------------------------


def test_transient_fault_retried_in_place():
    stream = _stream(4, seed=9)
    eng = GraphServeEngine(
        max_requests=4, max_retries=1,
        fault_plan=FaultPlan(transient_uids={2: 1}),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    assert all(not r.failed for r in done)
    for r in done:
        _assert_matches_solo(r, stream[r.uid])
    h = eng.health_records[-1]
    assert h.retried == 1 and h.quarantined == 0 and h.bisections == 0
    assert h.wave_runs == 2  # one failure + one clean re-run


def test_transient_beyond_retry_budget_is_quarantined():
    """A 'transient' that outlives max_retries is treated like poison:
    bisected and quarantined (here: singleton wave, direct)."""
    stream = _stream(1, seed=11)
    eng = GraphServeEngine(
        max_requests=1, max_retries=1,
        fault_plan=FaultPlan(transient_uids={0: 5}),
    )
    eng.submit(_requests(stream)[0])
    done = eng.run()
    assert done[0].failed and "TransientFault" in done[0].error
    assert eng.health_records[-1].retried == 1  # budget, not the 5 failures


# ---------------------------------------------------------------------------
# graceful degradation (simulated OOM)
# ---------------------------------------------------------------------------


def test_oom_degrades_bucket_and_completes_everything():
    """An OOM on the packed bucket permanently caps the budget; the wave
    re-packs into smaller waves and every request completes bit-exact."""
    stream = _stream(8, seed=13)
    probe = GraphServeEngine(max_requests=8)
    reqs = _requests(stream)
    node_cap, edge_cap = probe._wave_caps(reqs)

    eng = GraphServeEngine(
        max_requests=8,
        fault_plan=FaultPlan(oom_node_caps=frozenset([node_cap])),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 8 and all(not r.failed for r in done)
    for r in done:
        _assert_matches_solo(r, stream[r.uid])
    h = eng.health_records[-1]
    assert h.degraded >= 1 and h.quarantined == 0
    # the cap is permanent: the budget stays below the failing bucket
    assert eng._node_budget <= node_cap // 2
    assert all(w.node_cap < node_cap for w in eng.wave_records)


def test_oom_on_singleton_wave_quarantines():
    """A request that exhausts the device ALONE cannot degrade away --
    it fails with the captured OOM."""
    stream = _stream(1, seed=15)
    eng = GraphServeEngine(max_requests=4)
    caps = eng._wave_caps(_requests(stream))
    eng.fault_plan = FaultPlan(oom_node_caps=frozenset([caps[0]]))
    eng.submit(_requests(stream)[0])
    done = eng.run()
    assert done[0].failed and "SimulatedOOM" in done[0].error
    assert eng.health_records[-1].degraded == 0


def test_lm_engine_oom_halves_slots():
    import jax

    from repro.configs import get_arch
    from repro.models.transformer import init_params
    from repro.serve import Request, ServeEngine

    cfg = get_arch("qwen3-4b").smoke_config
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(
        params, cfg, num_slots=4, max_len=32,
        fault_plan=FaultPlan(oom_slots_at=4),
    )
    solo = ServeEngine(params, cfg, num_slots=4, max_len=32)
    for i in range(4):
        eng.submit(Request(uid=i, prompt=[i + 1, i + 2], max_new_tokens=3))
        solo.submit(Request(uid=i, prompt=[i + 1, i + 2], max_new_tokens=3))
    done = eng.run()
    assert eng.num_slots == 2  # permanently narrowed
    assert len(done) == 4 and all(not r.failed for r in done)
    ref = {r.uid: r.output for r in solo.run()}
    assert {r.uid: r.output for r in done} == ref
    assert eng.health_records[-1].degraded == 1


# ---------------------------------------------------------------------------
# convergence sentinels
# ---------------------------------------------------------------------------


def _path_graph(n):
    src = np.arange(n - 1, dtype=np.int32)
    return src, src + 1


def test_shiloach_vishkin_convergence_error():
    from repro.core import shiloach_vishkin

    src, dst = _path_graph(64)
    with pytest.raises(ConvergenceError, match="max_rounds"):
        shiloach_vishkin(src, dst, 64, max_rounds=1)
    labels, rounds = shiloach_vishkin(src, dst, 64)  # default bound: fine
    assert int(rounds) >= 1


def test_frontier_convergence_error():
    from repro.core import frontier_shiloach_vishkin

    src, dst = _path_graph(64)
    with pytest.raises(ConvergenceError, match="round bound"):
        frontier_shiloach_vishkin(src, dst, 64, max_rounds=1)


def test_random_splitter_convergence_error():
    from repro.core import random_splitter_rank
    from repro.data.graphs import random_succ

    succ = random_succ(256, seed=0)
    with pytest.raises(ConvergenceError, match="max_steps"):
        random_splitter_rank(succ, 4, seed=0, max_steps=1)
    # an adequate budget still ranks exactly
    r = random_splitter_rank(succ, 4, seed=0, max_steps=256)
    assert r is not None


def test_sharded_convergence_errors():
    from repro.data.graphs import random_succ
    from repro.distributed.graph import (
        sharded_random_splitter_rank,
        sharded_shiloach_vishkin,
    )

    src, dst = _path_graph(64)
    with pytest.raises(ConvergenceError, match="max_rounds"):
        sharded_shiloach_vishkin(src, dst, 64, max_rounds=1)
    succ = random_succ(128, seed=1)
    with pytest.raises(ConvergenceError, match="max_steps"):
        sharded_random_splitter_rank(succ, 4, max_steps=1)


def test_nonconvergence_injection_fails_only_that_wave():
    """wants_nonconverge forces max_rounds=0 so the REAL core sentinel
    fires; the wave's requests quarantine, later waves are untouched."""
    stream = _stream(6, seed=17)
    eng = GraphServeEngine(
        max_requests=2,
        fault_plan=FaultPlan(nonconverge_uids=frozenset([2])),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    by_uid = {r.uid: r for r in done}
    assert len(done) == 6
    assert by_uid[2].failed and "ConvergenceError" in by_uid[2].error
    for uid in set(range(6)) - {2}:
        assert not by_uid[uid].failed
        _assert_matches_solo(by_uid[uid], stream[uid])


# ---------------------------------------------------------------------------
# satellites: stale results, duplicate uids, malformed submits
# ---------------------------------------------------------------------------


def test_run_returns_only_new_results_graph():
    """Regression: run() must not re-deliver an earlier run's results."""
    stream = _stream(4, seed=19)
    reqs = _requests(stream)
    eng = GraphServeEngine(max_requests=2)
    eng.submit(reqs[0])
    eng.submit(reqs[1])
    first = eng.run()
    assert {r.uid for r in first} == {0, 1}
    eng.submit(reqs[2])
    eng.submit(reqs[3])
    second = eng.run()
    assert {r.uid for r in second} == {2, 3}, "stale results re-delivered"
    assert eng.run() == []  # empty queue -> nothing new


def test_run_returns_only_new_results_lm():
    import jax

    from repro.configs import get_arch
    from repro.models.transformer import init_params
    from repro.serve import Request, ServeEngine

    cfg = get_arch("qwen3-4b").smoke_config
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, num_slots=2, max_len=32)
    eng.submit(Request(uid=0, prompt=[1, 2], max_new_tokens=2))
    assert {r.uid for r in eng.run()} == {0}
    # zero-budget requests register at submit and deliver on the NEXT run
    eng.submit(Request(uid=1, prompt=[3], max_new_tokens=0))
    eng.submit(Request(uid=2, prompt=[4, 5], max_new_tokens=2))
    assert {r.uid for r in eng.run()} == {1, 2}
    assert eng.run() == []


def test_duplicate_uid_rejected_both_engines():
    import jax

    from repro.configs import get_arch
    from repro.models.transformer import init_params
    from repro.serve import Request, ServeEngine

    stream = _stream(2, seed=21)
    g = GraphServeEngine()
    g.submit(GraphRequest(uid=0, **stream[0]))
    with pytest.raises(ValueError, match="in flight"):
        g.submit(GraphRequest(uid=0, **stream[1]))
    g.run()
    g.submit(GraphRequest(uid=0, **stream[1]))  # delivered uid is reusable

    cfg = get_arch("qwen3-4b").smoke_config
    params = init_params(jax.random.PRNGKey(0), cfg)
    lm = ServeEngine(params, cfg, num_slots=2, max_len=32)
    lm.submit(Request(uid=0, prompt=[1], max_new_tokens=1))
    with pytest.raises(ValueError, match="in flight"):
        lm.submit(Request(uid=0, prompt=[2], max_new_tokens=1))
    with pytest.raises(ValueError, match="in flight"):
        lm.submit(Request(uid=0, prompt=[2], max_new_tokens=0))
    lm.run()
    lm.submit(Request(uid=0, prompt=[2], max_new_tokens=1))


def test_malformed_submit_rejected_before_any_wave():
    stream = _stream(2, seed=23)
    plan = FaultPlan(malformed_uids=frozenset([1]))
    eng = GraphServeEngine(fault_plan=plan)
    reqs = _requests(stream)
    for r in reqs:
        if r.uid in plan.malformed_uids:
            plan.malform(r)
            with pytest.raises(ValueError, match="endpoints"):
                eng.submit(r)
        else:
            eng.submit(r)
    done = eng.run()
    assert {r.uid for r in done} == {0}  # the malformed one never entered
    assert eng.health_records[-1].wave_runs == 1


# ---------------------------------------------------------------------------
# the chaos property
# ---------------------------------------------------------------------------


def _chaos_round(num_requests, seed, width, kinds=("analytics",)):
    """Random stream x random FaultPlan: every request terminates
    exactly once (done xor failed) and every non-quarantined result is
    bit-exact vs the solo engines. ``kinds`` draws each request's kind
    (mixing "sssp" in exercises the family-separated wave packing
    under faults)."""
    r = np.random.default_rng(seed)
    stream = []
    for _ in range(num_requests):
        n = int(r.integers(1, 14))
        m = int(r.integers(0, 4 * n))
        g = {
            "src": r.integers(0, n, m).astype(np.int32),
            "dst": r.integers(0, n, m).astype(np.int32),
            "num_nodes": n,
            "kind": kinds[int(r.integers(0, len(kinds)))],
        }
        if g["kind"] in ("sssp", "pagerank"):
            g["weights"] = (r.integers(0, 8, m) / 4.0).astype(np.float32)
        if g["kind"] == "sssp":
            g["sources"] = r.integers(
                0, n, int(r.integers(1, 3))
            ).astype(np.int32)
        stream.append(g)
    plan = FaultPlan.random(
        seed, range(num_requests), p_poison=0.25, p_transient=0.25,
        max_transient=2, p_nonconverge=0.1,
    )
    eng = GraphServeEngine(max_requests=width, max_retries=2,
                           fault_plan=plan)
    for req in _requests(stream):
        eng.submit(req)
    done = eng.run()

    assert sorted(req.uid for req in done) == list(range(num_requests))
    for req in done:
        assert req.done != req.failed, f"uid={req.uid} not exactly-once"
        if req.failed:
            assert req.error and req.result is None
        else:
            _assert_matches_solo(req, stream[req.uid])
    h = eng.health_records[-1]
    assert h.completed + h.failed == num_requests
    assert h.failed == h.quarantined
    # poisons always quarantine; transient-only requests clear within
    # the retry budget (max_retries=2 covers max_transient=2)
    for uid in plan.poison_uids:
        assert next(q for q in done if q.uid == uid).failed


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 7), st.integers(0, 10_000), st.integers(1, 4))
def test_chaos_property_every_request_terminates_once(
    num_requests, seed, width
):
    _chaos_round(num_requests, seed, width)


@pytest.mark.parametrize("seed", [0, 101, 202])
def test_chaos_deterministic_seeds(seed):
    """The hypothesis property above skips without hypothesis; this
    pins three deterministic chaos rounds so the containment paths run
    in every environment (CI chaos-smoke)."""
    _chaos_round(6, seed, 3)


# ---------------------------------------------------------------------------
# kind="sssp" fault containment
# ---------------------------------------------------------------------------


def test_sssp_poison_bisected_within_log_bound():
    """One poison in a K-request sssp wave: same acceptance bound as
    the cc-chain kinds, survivors' dist/pred bit-exact vs solo."""
    k, poison = 8, 3
    stream = _stream(k, seed=41, kind="sssp")
    eng = GraphServeEngine(
        max_requests=k, fault_plan=FaultPlan(poison_uids=frozenset([poison])),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    assert len(done) == k
    by_uid = {r.uid: r for r in done}
    assert by_uid[poison].failed and "InjectedEngineError" in (
        by_uid[poison].error
    )
    for uid in set(range(k)) - {poison}:
        assert not by_uid[uid].failed
        _assert_matches_solo(by_uid[uid], stream[uid])
    h = eng.health_records[-1]
    assert h.wave_runs - 1 <= math.ceil(math.log2(k)) + 1
    assert h.quarantined == 1 and h.completed == k - 1
    assert all(w.stage == "sssp" for w in eng.wave_records)


def test_sssp_transient_fault_retried_in_place():
    stream = _stream(4, seed=43, kind="sssp")
    eng = GraphServeEngine(
        max_requests=4, max_retries=1,
        fault_plan=FaultPlan(transient_uids={2: 1}),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    assert all(not r.failed for r in done)
    for r in done:
        _assert_matches_solo(r, stream[r.uid])
    h = eng.health_records[-1]
    assert h.retried == 1 and h.quarantined == 0 and h.wave_runs == 2


def test_sssp_nonconvergence_fires_relax_bound_sentinel():
    """wants_nonconverge forces max_rounds=0 so the REAL relax-loop
    bound in core.sssp fires (not a fake error): the wave quarantines
    with ConvergenceError, other sssp waves stay bit-exact."""
    stream = _stream(6, seed=45, kind="sssp")
    eng = GraphServeEngine(
        max_requests=2,
        fault_plan=FaultPlan(nonconverge_uids=frozenset([2])),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    by_uid = {r.uid: r for r in done}
    assert len(done) == 6
    assert by_uid[2].failed and "ConvergenceError" in by_uid[2].error
    assert "max_rounds" in by_uid[2].error  # the core sentinel's text
    for uid in set(range(6)) - {2}:
        assert not by_uid[uid].failed
        _assert_matches_solo(by_uid[uid], stream[uid])


def test_sssp_oom_degrades_bucket_and_completes_everything():
    stream = _stream(8, seed=47, kind="sssp")
    probe = GraphServeEngine(max_requests=8)
    node_cap, _ = probe._wave_caps(_requests(stream))
    eng = GraphServeEngine(
        max_requests=8,
        fault_plan=FaultPlan(oom_node_caps=frozenset([node_cap])),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 8 and all(not r.failed for r in done)
    for r in done:
        _assert_matches_solo(r, stream[r.uid])
    assert eng.health_records[-1].degraded >= 1
    assert all(w.node_cap < node_cap for w in eng.wave_records)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 7), st.integers(0, 10_000), st.integers(1, 4))
def test_chaos_property_mixed_kinds_with_sssp(num_requests, seed, width):
    """The chaos property over mixed analytics/sssp streams: faults +
    family-separated packing never break exactly-once or bit-exact."""
    _chaos_round(num_requests, seed, width, kinds=("analytics", "sssp"))


@pytest.mark.parametrize("seed", [7, 303])
def test_chaos_deterministic_seeds_sssp(seed):
    """Deterministic mixed-kind chaos rounds (run even without
    hypothesis), so the sssp containment paths are CI chaos-smoke."""
    _chaos_round(6, seed, 3, kinds=("analytics", "sssp"))


# ---------------------------------------------------------------------------
# kind="pagerank" fault containment
# ---------------------------------------------------------------------------


def test_pagerank_poison_bisected_within_log_bound():
    """One poison in a K-request pagerank wave: same acceptance bound
    as the other families, survivors' scores bit-exact vs solo."""
    k, poison = 8, 5
    stream = _stream(k, seed=51, kind="pagerank")
    eng = GraphServeEngine(
        max_requests=k, fault_plan=FaultPlan(poison_uids=frozenset([poison])),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    assert len(done) == k
    by_uid = {r.uid: r for r in done}
    assert by_uid[poison].failed and "InjectedEngineError" in (
        by_uid[poison].error
    )
    for uid in set(range(k)) - {poison}:
        assert not by_uid[uid].failed
        _assert_matches_solo(by_uid[uid], stream[uid])
    h = eng.health_records[-1]
    assert h.wave_runs - 1 <= math.ceil(math.log2(k)) + 1
    assert h.quarantined == 1 and h.completed == k - 1
    assert all(w.stage == "pagerank" for w in eng.wave_records)


def test_pagerank_transient_fault_retried_in_place():
    stream = _stream(4, seed=53, kind="pagerank")
    eng = GraphServeEngine(
        max_requests=4, max_retries=1,
        fault_plan=FaultPlan(transient_uids={1: 1}),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    assert all(not r.failed for r in done)
    for r in done:
        _assert_matches_solo(r, stream[r.uid])
    h = eng.health_records[-1]
    assert h.retried == 1 and h.quarantined == 0 and h.wave_runs == 2


def test_pagerank_nonconvergence_fires_iteration_budget_sentinel():
    """wants_nonconverge caps the dense engine's iteration budget to 0
    so the REAL post-run tolerance probe in core.pagerank raises (not
    a fake error): the wave quarantines with ConvergenceError, other
    pagerank waves stay bit-exact."""
    stream = _stream(6, seed=55, kind="pagerank")
    eng = GraphServeEngine(
        max_requests=2,
        fault_plan=FaultPlan(nonconverge_uids=frozenset([2])),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    by_uid = {r.uid: r for r in done}
    assert len(done) == 6
    assert by_uid[2].failed and "ConvergenceError" in by_uid[2].error
    assert "max_rounds" in by_uid[2].error  # the core sentinel's text
    for uid in set(range(6)) - {2}:
        assert not by_uid[uid].failed
        _assert_matches_solo(by_uid[uid], stream[uid])


def test_pagerank_oom_degrades_bucket_and_completes_everything():
    stream = _stream(8, seed=57, kind="pagerank")
    probe = GraphServeEngine(max_requests=8)
    node_cap, _ = probe._wave_caps(_requests(stream))
    eng = GraphServeEngine(
        max_requests=8,
        fault_plan=FaultPlan(oom_node_caps=frozenset([node_cap])),
    )
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 8 and all(not r.failed for r in done)
    for r in done:
        _assert_matches_solo(r, stream[r.uid])
    assert eng.health_records[-1].degraded >= 1
    assert all(w.node_cap < node_cap for w in eng.wave_records)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 7), st.integers(0, 10_000), st.integers(1, 4))
def test_chaos_property_all_three_families(num_requests, seed, width):
    """The chaos property over all three packing families interleaved:
    faults + family-boundary wave closes never break exactly-once or
    bit-exactness."""
    _chaos_round(
        num_requests, seed, width, kinds=("analytics", "sssp", "pagerank")
    )


@pytest.mark.parametrize("seed", [13, 404])
def test_chaos_deterministic_seeds_pagerank(seed):
    """Deterministic three-family chaos rounds (run even without
    hypothesis): CI chaos-smoke for the pagerank containment paths."""
    _chaos_round(6, seed, 3, kinds=("analytics", "sssp", "pagerank"))
