"""The docs tree must exist, be linked, and stay consistent with the
code: tools/check_docs.py compares the docs/engines.md choice matrix
against the check_choice sets (CI also runs it standalone)."""
import os
import sys

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _tools():
    sys.path.insert(0, os.path.join(_ROOT, "tools"))
    try:
        import check_docs
    finally:
        sys.path.pop(0)
    return check_docs


def test_engines_matrix_matches_code():
    check_docs = _tools()
    assert check_docs.check() == []


def test_choice_matrix_parser_sees_all_knobs():
    """A silently-unparsed table (markdown drift) must fail loudly, not
    pass vacuously."""
    check_docs = _tools()
    doc = check_docs.documented_choices(check_docs.DOCS.read_text())
    assert set(doc) >= set(check_docs.code_choices())


@pytest.mark.parametrize(
    "page", ["guidelines.md", "engines.md", "benchmarks.md"]
)
def test_docs_pages_exist_and_linked_from_readme(page):
    path = os.path.join(_ROOT, "docs", page)
    assert os.path.exists(path), page
    with open(os.path.join(_ROOT, "README.md")) as f:
        readme = f.read()
    assert f"docs/{page}" in readme, f"README does not link docs/{page}"


def test_guidelines_pointers_name_real_files():
    """Every `src/...py:line`-style pointer in docs/guidelines.md must
    reference a file that exists (line numbers may drift; files not)."""
    import re

    with open(os.path.join(_ROOT, "docs", "guidelines.md")) as f:
        text = f.read()
    paths = set(re.findall(r"`(src/[\w/]+\.py)(?::\d+)?`", text))
    assert paths, "no code pointers found in guidelines.md"
    for p in paths:
        assert os.path.exists(os.path.join(_ROOT, p)), p
