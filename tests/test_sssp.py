"""SSSP on the frontier machinery (`repro.core.sssp`): distances AND
parent trees must be bit-exact vs the serial Dijkstra / Bellman-Ford
oracles across the adversarial families, batched multi-source must be
bit-exact vs solo runs, unit-weight reachability must agree with CC,
and the serve path must treat kind="sssp" waves like any other
(batched == solo, validated loudly)."""
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-stubs
from test_frontier import _adversarial_families
from test_serve_graph import _assert_matches_solo, _requests

from repro.core import (
    SSSP_ENGINES,
    bellman_ford,
    connected_components,
    frontier_bellman_ford,
    shortest_paths,
    sssp_round_bound,
)
from repro.core.components import ConvergenceError
from repro.core.serial import serial_bellman_ford, serial_dijkstra
from repro.data.graphs import graph_request_stream
from repro.serve import GraphRequest, GraphServeEngine


def _eighth_weights(edges, salt=0):
    """Deterministic weights in {0, 0.25, ..., 1.75}: zero-weight edges
    included on purpose (adversarial tie-breaks)."""
    r = np.random.default_rng(1000 + salt + len(edges))
    return (r.integers(0, 8, size=len(edges)) / 4.0).astype(np.float32)


def _assert_vs_oracles(edges, weights, n, source=0, **engine_kwargs):
    """Both engines == both serial oracles, distances AND parents."""
    od, op = serial_dijkstra(edges, weights, n, source)
    od2, op2 = serial_bellman_ford(edges, weights, n, source)
    np.testing.assert_array_equal(od, od2)
    np.testing.assert_array_equal(op, op2)
    src, dst = edges[:, 0], edges[:, 1]
    for engine in ("frontier", "dense"):
        kw = dict(engine_kwargs)
        if engine == "dense":
            kw.pop("min_bucket", None)
        d, p, rounds = shortest_paths(
            src, dst, weights, n, sources=source, engine=engine, **kw
        )
        np.testing.assert_array_equal(
            np.asarray(d), od, err_msg=f"dist {engine}"
        )
        np.testing.assert_array_equal(
            np.asarray(p), op, err_msg=f"parent {engine}"
        )
        assert int(rounds) <= sssp_round_bound(n)
    return od, op


@pytest.mark.parametrize(
    "family", sorted(_adversarial_families()), ids=lambda f: f
)
def test_bit_exact_vs_serial_oracles(family):
    """test_frontier's adversarial families, weighted with zero-weight
    edges included: frontier == dense == serial Dijkstra == serial BF,
    bit-for-bit on distances and parent trees."""
    n, edges = _adversarial_families()[family]
    _assert_vs_oracles(
        edges, _eighth_weights(edges), n, min_bucket=64
    )


def test_unit_weights_and_degenerate_graphs():
    """weights=None (BFS), the empty graph (all unreachable -> +inf /
    -1), a single-node graph, and all-self-loops (self-relaxes never
    parent)."""
    n, edges = _adversarial_families()["random"]
    _assert_vs_oracles(edges, None, n, min_bucket=64)
    # empty: everything but the source is unreachable
    d, p = _assert_vs_oracles(np.zeros((0, 2), np.int32), None, 17)
    assert d[0] == 0.0 and np.isinf(d[1:]).all()
    assert p[0] == 0 and (p[1:] == -1).all()
    # single node, no edges
    d, p = _assert_vs_oracles(np.zeros((0, 2), np.int32), None, 1)
    assert d.tolist() == [0.0] and p.tolist() == [0]
    # single node, self-loop edge
    loop = np.zeros((1, 2), np.int32)
    d, p = _assert_vs_oracles(loop, np.array([0.5], np.float32), 1)
    assert d.tolist() == [0.0] and p.tolist() == [0]
    # all-self-loops: like the empty graph
    n, edges = _adversarial_families()["all-self-loops"]
    d, p = _assert_vs_oracles(edges, _eighth_weights(edges), n)
    assert np.isinf(d[1:]).all() and (p[1:] == -1).all()


def test_zero_weight_component_min_parent_rule():
    """An all-zero-weight clique: every node is at distance 0 and every
    non-source node's parent is the MINIMUM optimal neighbor (the
    deterministic min-CRCW tie-break)."""
    n = 5
    a, b = np.triu_indices(n, k=1)
    edges = np.stack([a, b], axis=1).astype(np.int32)
    w = np.zeros(len(edges), np.float32)
    d, p = _assert_vs_oracles(edges, w, n, min_bucket=16)
    assert (d == 0.0).all()
    # every node except source 0 ties on ALL in-edges; min u wins
    assert p.tolist() == [0, 0, 0, 0, 0]
    # and from source 2 the same rule gives min-id parents again
    d2, p2, _ = shortest_paths(
        edges[:, 0], edges[:, 1], w, n, sources=2, engine="frontier"
    )
    od2, op2 = serial_dijkstra(edges, w, n, 2)
    np.testing.assert_array_equal(np.asarray(d2), od2)
    np.testing.assert_array_equal(np.asarray(p2), op2)
    assert np.asarray(p2).tolist() == [1, 0, 2, 0, 0]


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 24), st.integers(0, 60), st.integers(0, 10_000))
def test_batched_multi_source_equals_solo_property(n, m, seed):
    """Hypothesis: batched multi-source rows == per-source solo runs
    (duplicate sources allowed), on both engines."""
    r = np.random.default_rng(seed)
    edges = r.integers(0, n, size=(m, 2)).astype(np.int32)
    weights = None if seed % 3 == 0 else _eighth_weights(edges, salt=seed)
    S = int(r.integers(1, 4))
    srcs = r.integers(0, n, size=S).astype(np.int32)  # dups allowed
    for engine in ("frontier", "dense"):
        bd, bp, _ = shortest_paths(
            edges[:, 0], edges[:, 1], weights, n, sources=srcs,
            engine=engine,
        )
        bd, bp = np.asarray(bd), np.asarray(bp)
        assert bd.shape == (S, n) and bp.shape == (S, n)
        for i, s in enumerate(srcs):
            sd, sp, _ = shortest_paths(
                edges[:, 0], edges[:, 1], weights, n, sources=int(s),
                engine=engine,
            )
            np.testing.assert_array_equal(bd[i], np.asarray(sd))
            np.testing.assert_array_equal(bp[i], np.asarray(sp))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 30), st.integers(0, 80), st.integers(0, 10_000))
def test_unit_weight_reachability_equals_cc_property(n, m, seed):
    """Hypothesis: unit-weight SSSP reachability == the CC
    same-component predicate -- dist[v] is finite iff v shares the
    source's component."""
    r = np.random.default_rng(seed)
    edges = r.integers(0, n, size=(m, 2)).astype(np.int32)
    source = int(r.integers(0, n))
    d, p, _ = shortest_paths(
        edges[:, 0], edges[:, 1], None, n, sources=source, engine="frontier"
    )
    lab, _ = connected_components(edges[:, 0], edges[:, 1], n)
    lab = np.asarray(lab)
    np.testing.assert_array_equal(
        np.isfinite(np.asarray(d)), lab == lab[source]
    )
    # parent sentinels agree with reachability too
    np.testing.assert_array_equal(np.asarray(p) == -1, lab != lab[source])


def test_engine_dispatch_and_validation():
    """Unknown engines name the choice set; min_bucket only fits the
    frontier engine; the frontier level loop refuses to trace; auto
    under jit falls back to the dense walk."""
    import jax

    n, edges = 40, np.array([[0, 1], [1, 2]], np.int32)
    with pytest.raises(ValueError, match="'auto', 'frontier', 'dense'"):
        shortest_paths(edges[:, 0], edges[:, 1], None, n, engine="fastest")
    with pytest.raises(TypeError, match="num_nodes"):
        shortest_paths(edges[:, 0], edges[:, 1])
    with pytest.raises(ValueError, match="min_bucket"):
        shortest_paths(
            edges[:, 0], edges[:, 1], None, n, engine="dense", min_bucket=8
        )
    with pytest.raises(ValueError, match="negative"):
        shortest_paths(
            edges[:, 0], edges[:, 1], np.array([1.0, -0.5]), n
        )
    with pytest.raises(ValueError, match="NaN"):
        shortest_paths(
            edges[:, 0], edges[:, 1], np.array([1.0, np.nan]), n
        )
    with pytest.raises(ValueError, match="sources"):
        shortest_paths(edges[:, 0], edges[:, 1], None, n, sources=n)
    assert SSSP_ENGINES == ("auto", "frontier", "dense")

    ref, _, _ = bellman_ford(edges[:, 0], edges[:, 1], None, n)

    @jax.jit
    def traced(s, d):
        dist, parent, _ = shortest_paths(s, d, None, n)  # auto -> dense
        return dist, parent

    td, tp = traced(edges[:, 0], edges[:, 1])
    np.testing.assert_array_equal(np.asarray(td), np.asarray(ref))

    @jax.jit
    def traced_frontier(s, d):
        return shortest_paths(s, d, None, n, engine="frontier")[0]

    with pytest.raises(ValueError, match="host-driven"):
        traced_frontier(edges[:, 0], edges[:, 1])


def test_convergence_sentinel_fires_on_round_bound():
    """max_rounds below the fixpoint raises ConvergenceError on both
    engines (host calls); the default bound always converges."""
    from repro.ops.kiss import list_graph

    n = 64
    edges = list_graph(n, 1, seed=21)
    for engine in ("frontier", "dense"):
        with pytest.raises(ConvergenceError, match="max_rounds"):
            shortest_paths(
                edges[:, 0], edges[:, 1], None, n, engine=engine,
                max_rounds=0,
            )
        with pytest.raises(ConvergenceError):
            shortest_paths(
                edges[:, 0], edges[:, 1], None, n, engine=engine,
                max_rounds=2,
            )
        d, _, rounds = shortest_paths(
            edges[:, 0], edges[:, 1], None, n, engine=engine
        )
        assert int(rounds) <= sssp_round_bound(n)
        assert np.isfinite(np.asarray(d)).all()


def test_frontier_stats_beat_dense_on_chains():
    """The work accounting the benchmark pins: on a long chain the
    frontier engine's relax visits stay far below the dense engine's
    m2 * rounds (only the advancing front relaxes), at the cost of one
    full-list mask gather per level."""
    from repro.ops.kiss import list_graph

    n = 1024
    edges = list_graph(n, 1, seed=22)
    w = _eighth_weights(edges)
    fd, fp, fr, fstats = frontier_bellman_ford(
        edges[:, 0], edges[:, 1], w, n, min_bucket=64, with_stats=True
    )
    dd, dp, dr, dstats = bellman_ford(
        edges[:, 0], edges[:, 1], w, n, with_stats=True
    )
    np.testing.assert_array_equal(np.asarray(fd), np.asarray(dd))
    np.testing.assert_array_equal(np.asarray(fp), np.asarray(dp))
    assert fstats.relax_visits < dstats.relax_visits / 2
    # one mask gather per level PLUS the terminal empty-frontier check
    assert fstats.mask_visits == fstats.m2 * (len(fstats.levels) + 1)
    assert dstats.mask_visits == 0 and dstats.m2 == fstats.m2
    assert fstats.num_sources == dstats.num_sources == 1


# ---------------------------------------------------------------- serve


def test_serve_sssp_batched_bit_exact_vs_solo():
    """kind="sssp" waves: packed multi-request multi-source results ==
    solo shortest_paths calls, on the dense (default) and pinned
    frontier serve engines."""
    stream = graph_request_stream(7, kind="sssp", seed=31)
    for eng_kw in ({}, {"engine": "frontier", "min_bucket": 32}):
        eng = GraphServeEngine(max_requests=3, **eng_kw)
        reqs = _requests(stream)
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert len(done) == len(stream)
        for r in done:
            _assert_matches_solo(r, stream[r.uid])
        assert all(w.stage == "sssp" for w in eng.wave_records)
        assert all(w.src_cap >= 1 for w in eng.wave_records)


def test_serve_sssp_family_separated_from_cc_chain():
    """A mixed queue packs sssp requests only with other sssp requests
    (different device programs), preserving FIFO completion order."""
    stream = (
        graph_request_stream(2, kind="cc", seed=32)
        + graph_request_stream(2, kind="sssp", seed=33)
        + graph_request_stream(2, kind="analytics", family="tree", seed=34)
    )
    eng = GraphServeEngine(max_requests=16)
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    assert [r.uid for r in done] == list(range(len(stream)))
    assert [w.stage for w in eng.wave_records] == [
        "cc", "sssp", "analytics",
    ]
    for r in done:
        _assert_matches_solo(r, stream[r.uid])


def test_serve_sssp_submit_validation():
    eng = GraphServeEngine(max_sources=2)
    e = np.array([0, 1], np.int32), np.array([1, 2], np.int32)

    def req(uid, **kw):
        return GraphRequest(
            uid=uid, src=e[0], dst=e[1], num_nodes=3, kind="sssp", **kw
        )

    with pytest.raises(ValueError, match="finite"):
        eng.submit(req(0, weights=np.array([1.0, -2.0])))
    with pytest.raises(ValueError, match="finite"):
        eng.submit(req(1, weights=np.array([1.0, np.nan])))
    with pytest.raises(ValueError, match="length"):
        eng.submit(req(2, weights=np.array([1.0])))
    with pytest.raises(ValueError, match="sources outside"):
        eng.submit(req(3, sources=np.array([3])))
    with pytest.raises(ValueError, match="max_sources"):
        eng.submit(req(4, sources=np.array([0, 1, 2])))
    with pytest.raises(ValueError, match="sssp/pagerank kinds"):
        eng.submit(GraphRequest(
            uid=5, src=e[0], dst=e[1], num_nodes=3, kind="cc",
            weights=np.array([1.0, 1.0]),
        ))
    assert eng.queue == []  # nothing slipped through
    sharded = GraphServeEngine(engine="sharded_frontier")
    with pytest.raises(ValueError, match="single-device"):
        sharded.submit(req(6))
    hooked = GraphServeEngine(hook_impl="xla")
    with pytest.raises(ValueError, match="hook_impl"):
        hooked.submit(req(7))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 5), st.integers(0, 10_000), st.integers(1, 4))
def test_serve_sssp_random_streams_property(num_requests, seed, width):
    """Hypothesis: sssp serving is bit-exact vs solo on random streams,
    including empty-edge and single-node requests."""
    r = np.random.default_rng(seed)
    stream = []
    for _ in range(num_requests):
        n = int(r.integers(1, 12))
        m = int(r.integers(0, 3 * n))
        stream.append({
            "src": r.integers(0, n, m).astype(np.int32),
            "dst": r.integers(0, n, m).astype(np.int32),
            "num_nodes": n,
            "kind": "sssp",
            "weights": (r.integers(0, 8, m) / 4.0).astype(np.float32),
            "sources": r.integers(0, n, int(r.integers(1, 3))).astype(
                np.int32
            ),
        })
    eng = GraphServeEngine(max_requests=width)
    reqs = _requests(stream)
    for q in reqs:
        eng.submit(q)
    done = eng.run()
    assert len(done) == len(stream)
    for q in done:
        _assert_matches_solo(q, stream[q.uid])
