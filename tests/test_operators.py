"""The advance/filter/compute operator layer (`repro.core.operators`):
unit contracts for the monoid scatters, the filter primitives, and the
two host drivers -- plus hypothesis equivalence properties pinning the
operator-composed engines (frontier CC / frontier SSSP / PageRank) to
their dense counterparts and serial oracles bit-for-bit, across the
adversarial families (empty frontier, duplicate edges, self-loops,
single-node graphs)."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-stubs

from repro.core import ConvergenceError
from repro.core.operators import (
    ADD,
    MIN,
    advance,
    bucket_size,
    compact_frontier,
    compact_weighted,
    compute,
    next_pow2,
    run_bucket_ladder,
    run_rebuild_loop,
)


# ---------------------------------------------------------------------------
# filter primitives
# ---------------------------------------------------------------------------


def test_next_pow2():
    assert [next_pow2(x) for x in (-3, 0, 1, 2, 3, 4, 5, 1023, 1024)] == [
        1, 1, 1, 2, 4, 4, 8, 1024, 1024,
    ]


def test_bucket_size_floor_and_cap():
    assert bucket_size(3, min_bucket=16) == 16
    assert bucket_size(100, min_bucket=16) == 128
    assert bucket_size(100, min_bucket=16, cap=64) == 64
    assert bucket_size(0, min_bucket=8) == 8


def test_compact_frontier_gathers_in_slot_order_and_pads_inert():
    a = np.array([5, 6, 7, 8, 9], np.int32)
    b = np.array([1, 2, 3, 4, 5], np.int32)
    mask = np.array([True, False, True, True, False])
    ca, cb = compact_frontier(a, b, mask, size=8)
    np.testing.assert_array_equal(np.asarray(ca)[:3], [5, 7, 8])
    np.testing.assert_array_equal(np.asarray(cb)[:3], [1, 3, 4])
    np.testing.assert_array_equal(np.asarray(ca)[3:], 0)  # inert pads
    np.testing.assert_array_equal(np.asarray(cb)[3:], 0)


def test_compact_weighted_pads_zero_weight():
    a = np.array([1, 2, 3], np.int32)
    b = np.array([4, 5, 6], np.int32)
    w = np.array([0.5, 1.5, 2.5], np.float32)
    ca, cb, cw = compact_weighted(
        a, b, w, np.array([False, True, False]), size=4
    )
    np.testing.assert_array_equal(np.asarray(ca), [2, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(cb), [5, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(cw), [1.5, 0.0, 0.0, 0.0])


# ---------------------------------------------------------------------------
# advance: the monoid scatter contracts
# ---------------------------------------------------------------------------


def test_advance_min_matches_numpy_and_is_idempotent():
    r = np.random.default_rng(0)
    n, m = 13, 40
    tgt = r.random(n).astype(np.float32)
    idx = r.integers(0, n, m).astype(np.int32)
    val = r.random(m).astype(np.float32)
    ref = tgt.copy()
    np.minimum.at(ref, idx, val)
    out = np.asarray(advance(jnp.asarray(tgt), idx, val, monoid=MIN))
    np.testing.assert_array_equal(out, ref)
    # idempotent: scattering twice changes nothing
    np.testing.assert_array_equal(
        np.asarray(advance(jnp.asarray(out), idx, val, monoid=MIN)), ref
    )
    # identity pads are inert
    np.testing.assert_array_equal(
        np.asarray(advance(
            jnp.asarray(tgt), idx, np.full(m, MIN.identity, np.float32),
            monoid=MIN,
        )),
        tgt,
    )


def test_advance_min_batched_rows():
    """The ``...`` scatter form covers (S, n) batched rows (SSSP's
    multi-source distance array) identically per row."""
    r = np.random.default_rng(1)
    S, n, m = 3, 9, 20
    tgt = r.random((S, n)).astype(np.float32)
    idx = r.integers(0, n, m).astype(np.int32)
    val = r.random((S, m)).astype(np.float32)
    out = np.asarray(advance(jnp.asarray(tgt), idx, val, monoid=MIN))
    for s in range(S):
        ref = tgt[s].copy()
        np.minimum.at(ref, idx, val[s])
        np.testing.assert_array_equal(out[s], ref)


def test_advance_add_matches_numpy_bitwise():
    """The ADD determinism contract: scatter-add folds collisions in
    edge-slot order on this backend, exactly ``np.add.at`` -- the
    property the PageRank serial oracle is built on."""
    r = np.random.default_rng(2)
    n, m = 11, 64
    tgt = r.random(n).astype(np.float32)
    idx = r.integers(0, n, m).astype(np.int32)
    val = r.random(m).astype(np.float32)
    ref = tgt.copy()
    np.add.at(ref, idx, val)
    np.testing.assert_array_equal(
        np.asarray(advance(jnp.asarray(tgt), idx, val, monoid=ADD)), ref
    )
    # identity pads are inert (the weight-0 pad-edge rule)
    np.testing.assert_array_equal(
        np.asarray(advance(
            jnp.asarray(tgt), idx, np.full(m, ADD.identity, np.float32),
            monoid=ADD,
        )),
        tgt,
    )


def test_compute_is_elementwise_map():
    x = np.arange(5, dtype=np.float32)
    np.testing.assert_array_equal(
        np.asarray(compute(lambda a, b: a + b, x, x)), 2 * x
    )


# ---------------------------------------------------------------------------
# host drivers
# ---------------------------------------------------------------------------


def test_bucket_ladder_shrinks_monotonically_then_converges():
    """Scripted live counts: the ladder shrinks to next_pow2(live),
    never re-expands, passes the half-bucket watermark while a shrink
    is possible, and runs to convergence once it can't shrink."""
    lives = iter([100, 20, 20])
    calls, shrinks = [], []

    def run_level(bucket, shrink_at):
        calls.append((bucket, shrink_at))
        return (len(calls) >= 4, False)  # converge on the 4th level

    run_bucket_ladder(
        bucket=256, min_bucket=16,
        run_level=run_level,
        live_count=lambda: next(lives),
        compact=lambda new: shrinks.append(new),
        on_shrink=lambda new: shrinks.append(-new),
    )
    # 256 -> 128 -> 32, then live=20 gives next_pow2=32 == bucket: the
    # ladder stops shrinking and runs the last level to convergence.
    assert calls == [(256, 128), (128, 64), (32, 16), (32, None)]
    assert shrinks == [-128, 128, -32, 32]  # on_shrink before compact


def test_bucket_ladder_min_bucket_never_shrinks():
    calls = []

    def run_level(bucket, shrink_at):
        calls.append((bucket, shrink_at))
        return (True, False)

    run_bucket_ladder(
        bucket=16, min_bucket=16,
        run_level=run_level,
        live_count=lambda: pytest.fail("no sync needed at min_bucket"),
        compact=lambda new: pytest.fail("nothing to compact"),
    )
    assert calls == [(16, None)]


def test_bucket_ladder_nonconvergence_sentinel():
    with pytest.raises(ConvergenceError, match="before convergence"):
        run_bucket_ladder(
            bucket=16, min_bucket=16,
            run_level=lambda bucket, shrink_at: (False, True),  # bound hit
            live_count=lambda: 1,
            compact=lambda new: None,
        )

    class EngineBound(ConvergenceError):
        pass

    def raise_mine():
        raise EngineBound("engine text")

    with pytest.raises(EngineBound, match="engine text"):
        run_bucket_ladder(
            bucket=16, min_bucket=16,
            run_level=lambda bucket, shrink_at: (False, True),
            live_count=lambda: 1,
            compact=lambda new: None,
            on_nonconverged=raise_mine,
        )


def test_rebuild_loop_runs_until_dry_and_counts():
    lives = iter([3, 2, 1, 0])
    seen = []
    rounds = run_rebuild_loop(
        bound=10, live_count=lambda: next(lives),
        run_level=lambda live: seen.append(live),
    )
    assert rounds == 3 and seen == [3, 2, 1]
    assert run_rebuild_loop(
        bound=0, live_count=lambda: 0,
        run_level=lambda live: pytest.fail("dry loop must not run"),
    ) == 0


def test_rebuild_loop_bound_sentinel():
    with pytest.raises(ConvergenceError, match="round bound"):
        run_rebuild_loop(
            bound=2, live_count=lambda: 5, run_level=lambda live: None,
        )

    def raise_mine(live, rounds):
        assert (live, rounds) == (5, 2)
        raise ConvergenceError("engine bound text")

    with pytest.raises(ConvergenceError, match="engine bound text"):
        run_rebuild_loop(
            bound=2, live_count=lambda: 5, run_level=lambda live: None,
            on_bound=raise_mine,
        )


# ---------------------------------------------------------------------------
# equivalence properties: operator-composed engines vs dense + oracles
# ---------------------------------------------------------------------------


def _random_graph(seed, max_n=12, max_m_factor=3):
    """Adversarial family: duplicate edges, self-loops, empty edge
    lists, and single-node graphs all occur."""
    r = np.random.default_rng(seed)
    n = int(r.integers(1, max_n + 1))
    m = int(r.integers(0, max_m_factor * n))
    src = r.integers(0, n, m).astype(np.int32)
    dst = r.integers(0, n, m).astype(np.int32)
    return src, dst, n, r


def _check_cc_equivalence(seed):
    """The operator-composed frontier CC == dense SV: labels, rounds,
    and the recorded hook forest, bit-for-bit."""
    from repro.core import frontier_shiloach_vishkin, shiloach_vishkin
    from repro.core.serial import canonicalize_labels, serial_connected_components

    src, dst, n, _ = _random_graph(seed)
    lab_d, rounds_d, (hu_d, hv_d) = shiloach_vishkin(
        src, dst, n, record_hooks=True
    )
    lab_f, rounds_f, (hu_f, hv_f) = frontier_shiloach_vishkin(
        src, dst, n, min_bucket=4, record_hooks=True
    )
    np.testing.assert_array_equal(np.asarray(lab_f), np.asarray(lab_d))
    assert int(rounds_f) == int(rounds_d)
    np.testing.assert_array_equal(np.asarray(hu_f), np.asarray(hu_d))
    np.testing.assert_array_equal(np.asarray(hv_f), np.asarray(hv_d))
    # and both partition like the union-find oracle
    np.testing.assert_array_equal(
        canonicalize_labels(np.asarray(lab_d)),
        serial_connected_components(
            np.stack([src, dst], axis=1).astype(np.int64), n
        ),
    )


def _check_sssp_equivalence(seed):
    """The operator-composed frontier Bellman-Ford == dense BF ==
    both serial oracles, bit-for-bit in dist and parents."""
    from repro.core import bellman_ford, frontier_bellman_ford
    from repro.core.serial import serial_bellman_ford, serial_dijkstra

    src, dst, n, r = _random_graph(seed)
    w = (r.integers(0, 8, len(src)) / 4.0).astype(np.float32)
    source = int(r.integers(0, n))
    dist_d, par_d, _ = bellman_ford(src, dst, w, n, sources=[source])
    dist_f, par_f, _ = frontier_bellman_ford(
        src, dst, w, n, sources=[source], min_bucket=4
    )
    np.testing.assert_array_equal(np.asarray(dist_f), np.asarray(dist_d))
    np.testing.assert_array_equal(np.asarray(par_f), np.asarray(par_d))
    edges = np.stack([src, dst], axis=1).astype(np.int64)
    for oracle in (serial_bellman_ford, serial_dijkstra):
        dist_s, par_s = oracle(edges, w, n, source)
        np.testing.assert_array_equal(np.asarray(dist_d)[0], dist_s)
        np.testing.assert_array_equal(np.asarray(par_d)[0], par_s)


def _check_pagerank_equivalence(seed):
    """The two PageRank engines and the NumPy oracle agree bit-for-bit
    at the same iteration count: the host tolerance loop's trajectory
    IS the fixed dense schedule's prefix IS the serial op sequence."""
    from repro.core.pagerank import pagerank
    from repro.core.serial import serial_pagerank

    src, dst, n, r = _random_graph(seed)
    w = (r.integers(0, 8, len(src)) / 4.0).astype(np.float32)
    scores_f, iters = pagerank(src, dst, w, n, engine="frontier")
    k = int(iters)
    scores_d, iters_d = pagerank(src, dst, w, n, engine="dense", num_iters=k)
    assert int(iters_d) == k
    np.testing.assert_array_equal(
        np.asarray(scores_d), np.asarray(scores_f)
    )
    oracle = serial_pagerank(
        np.stack([src, dst], axis=1).astype(np.int64), w, n, num_iters=k
    )
    np.testing.assert_array_equal(np.asarray(scores_f), oracle)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_frontier_cc_matches_dense_bit_exact(seed):
    _check_cc_equivalence(seed)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_frontier_sssp_matches_dense_and_oracles_bit_exact(seed):
    _check_sssp_equivalence(seed)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_pagerank_engines_match_oracle_bit_exact(seed):
    _check_pagerank_equivalence(seed)


@pytest.mark.parametrize("seed", [0, 7, 123])
def test_equivalence_deterministic_seeds(seed):
    """Three pinned seeds per engine family (run even without
    hypothesis) so the operator-composition equivalences are always
    exercised in CI."""
    _check_cc_equivalence(seed)
    _check_sssp_equivalence(seed)
    _check_pagerank_equivalence(seed)


def test_pagerank_deterministic_edge_cases():
    """Single-node, empty-edge, duplicate-edge, and all-zero-weight
    graphs: engines still agree with the oracle bit-for-bit (runs even
    without hypothesis)."""
    from repro.core.pagerank import pagerank
    from repro.core.serial import serial_pagerank

    cases = [
        (np.zeros(0, np.int32), np.zeros(0, np.int32), None, 1),
        (np.zeros(0, np.int32), np.zeros(0, np.int32), None, 5),
        (np.array([0, 0, 0], np.int32), np.array([1, 1, 1], np.int32),
         None, 3),  # duplicate edges fold in slot order
        (np.array([0, 1], np.int32), np.array([1, 2], np.int32),
         np.array([0.0, 0.0], np.float32), 3),  # dangling by zero weight
        (np.array([0, 1, 2, 0], np.int32), np.array([1, 2, 0, 0], np.int32),
         np.array([0.5, 1.5, 0.25, 1.0], np.float32), 4),  # self-loop
    ]
    for src, dst, w, n in cases:
        scores_f, iters = pagerank(src, dst, w, n, engine="frontier")
        k = int(iters)
        scores_d, _ = pagerank(src, dst, w, n, engine="dense", num_iters=k)
        oracle = serial_pagerank(
            np.stack([src, dst], axis=1).astype(np.int64), w, n,
            num_iters=k,
        )
        np.testing.assert_array_equal(np.asarray(scores_f), oracle)
        np.testing.assert_array_equal(np.asarray(scores_d), oracle)


def test_pagerank_validation_and_sentinels():
    from repro.core.pagerank import pagerank, pagerank_iter_bound

    src = np.array([0, 1], np.int32)
    dst = np.array([1, 2], np.int32)
    with pytest.raises(TypeError, match="num_nodes"):
        pagerank(src, dst)
    with pytest.raises(ValueError, match="pagerank_engine"):
        pagerank(src, dst, None, 3, engine="fastest")
    with pytest.raises(ValueError, match="finite"):
        pagerank(src, dst, np.array([1.0, np.inf], np.float32), 3)
    with pytest.raises(ValueError, match=">= 0"):
        pagerank(src, dst, np.array([1.0, -1.0], np.float32), 3)
    with pytest.raises(ValueError, match="teleport"):
        pagerank(src, dst, None, 3, teleport=np.ones(2, np.float32))
    with pytest.raises(ValueError, match="damping"):
        pagerank_iter_bound(damping=1.0)
    with pytest.raises(ValueError, match="num_iters"):
        pagerank(src, dst, None, 3, engine="frontier", num_iters=5)
    # the convergence sentinels: both engines raise the REAL error
    with pytest.raises(ConvergenceError, match="iteration bound"):
        pagerank(src, dst, None, 3, engine="frontier", max_rounds=1)
    with pytest.raises(ConvergenceError, match="iteration budget"):
        pagerank(src, dst, None, 3, engine="dense", max_rounds=0)
    # stats: every iteration walks all 2m arcs, plus the degree pass
    scores, iters, stats = pagerank(src, dst, None, 3, with_stats=True)
    assert stats.m2 == 4 and stats.iterations == int(iters)
    assert stats.edges_touched == 4 * (int(iters) + 1)
    assert len(stats.levels) == int(iters)


def test_pagerank_auto_traces_to_dense():
    """engine="auto" under jit runs the traceable dense engine; the
    frontier engine rejects tracing loudly."""
    import jax

    from repro.core.pagerank import pagerank

    src = np.array([0, 1], np.int32)
    dst = np.array([1, 2], np.int32)

    @jax.jit
    def traced(s, d):
        return pagerank(s, d, None, 3, num_iters=7)

    scores = np.asarray(traced(src, dst)[0])
    solo, _ = pagerank(src, dst, None, 3, engine="dense", num_iters=7)
    np.testing.assert_array_equal(scores, np.asarray(solo))

    @jax.jit
    def traced_frontier(s, d):
        return pagerank(s, d, None, 3, engine="frontier")

    with pytest.raises(ValueError, match="host-driven"):
        traced_frontier(src, dst)
