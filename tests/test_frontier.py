"""Frontier-compacted CC engine: bit-exactness vs the dense sv_run loop
across adversarial graph families, work accounting, the Afforest-style
sampling pre-pass, and edge dedup."""
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-stubs

from repro.core import (
    connected_components,
    dedup_edges,
    frontier_shiloach_vishkin,
    num_components,
    shiloach_vishkin,
)
from repro.core.serial import canonicalize_labels, serial_connected_components
from repro.ops.kiss import giant_dust_graph, list_graph, random_graph, tree_graph


def _star(n):
    return np.stack(
        [np.zeros(n - 1, np.int32), np.arange(1, n, dtype=np.int32)], axis=1
    )


def _adversarial_families():
    r = np.random.default_rng(7)
    return {
        "long-chain": (2000, list_graph(2000, 1, seed=1)),
        "star": (1500, _star(1500)),
        "giant+dust": (2000, giant_dust_graph(2000, 0.9, seed=2)),
        "empty": (17, np.zeros((0, 2), np.int32)),
        "all-self-loops": (9, np.stack([np.arange(9)] * 2, axis=1).astype(np.int32)),
        "tree": (1200, tree_graph(1200, 3, seed=3)),
        "random": (800, random_graph(800, 0.01, seed=4)),
        "dense-multigraph": (150, r.integers(0, 150, (3000, 2)).astype(np.int32)),
    }


@pytest.mark.parametrize(
    "family", sorted(_adversarial_families()), ids=lambda f: f
)
def test_bit_exact_vs_dense(family):
    n, edges = _adversarial_families()[family]
    ref, rounds_ref = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    lab, rounds = frontier_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, min_bucket=64
    )
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref))
    assert int(rounds) == int(rounds_ref)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 100), st.integers(0, 300), st.integers(0, 10_000))
def test_random_edge_lists_bit_exact(n, m, seed):
    r = np.random.default_rng(seed)
    edges = r.integers(0, n, size=(m, 2)).astype(np.int32)
    ref, rounds_ref = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    lab, rounds = frontier_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, min_bucket=16
    )
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref))
    assert int(rounds) == int(rounds_ref)


def test_edges_touched_below_dense_on_chains():
    n = 4000
    edges = list_graph(n, 1, seed=5)
    _, rounds = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    _, _, stats = frontier_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, min_bucket=64, with_stats=True
    )
    dense = 2 * stats.m2 * int(rounds)
    assert stats.edges_touched < dense / 2
    sizes = [size for size, _ in stats.levels]
    assert sizes == sorted(sizes, reverse=True)  # buckets only shrink
    assert stats.rounds == int(rounds)


def test_afforest_prepass_partition_correct():
    for n, edges in [
        (2000, giant_dust_graph(2000, 0.9, seed=6)),
        (800, random_graph(800, 0.02, seed=7)),
        (1200, tree_graph(1200, 3, seed=8)),
    ]:
        ref = canonicalize_labels(serial_connected_components(edges, n))
        lab, _rounds, stats = frontier_shiloach_vishkin(
            edges[:, 0], edges[:, 1], n,
            sample_rounds=3, min_bucket=64, with_stats=True,
        )
        np.testing.assert_array_equal(
            canonicalize_labels(np.asarray(lab)), ref
        )
        assert stats.sample_rounds == 3
        assert 0.0 <= stats.largest_component_frac <= 1.0
        # the pre-pass resolves edges before full SV sees them
        assert stats.live_after_sample < stats.m2


def test_hook_kernel_path_bit_exact():
    n = 600
    edges = tree_graph(n, 3, seed=9)
    ref, rounds_ref = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    lab, rounds = frontier_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n,
        min_bucket=64, hook_impl="pallas_interpret",
    )
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref))
    assert int(rounds) == int(rounds_ref)


def test_dedup_edges():
    src = np.array([0, 1, 1, 2, 3, 3, 3], np.int32)
    dst = np.array([1, 0, 1, 3, 2, 2, 3], np.int32)  # dups + self-loops
    a, b = dedup_edges(src, dst)
    assert a.tolist() == [0, 2] and b.tolist() == [1, 3]
    # dedup changes neither labels nor rounds
    for dedup in (True, False):
        lab, rounds = shiloach_vishkin(src, dst, 5, dedup=dedup)
        assert num_components(lab) == 3  # {0,1}, {2,3}, {4}
        assert int(rounds) == 2


def test_dedup_edges_degenerate_inputs():
    # empty edge list
    a, b = dedup_edges(np.zeros(0, np.int32), np.zeros(0, np.int32))
    assert a.shape == (0,) and b.shape == (0,)
    assert a.dtype == np.int32 and b.dtype == np.int32
    lab, rounds = shiloach_vishkin(a, b, 4)
    assert num_components(lab) == 4 and int(rounds) == 1
    # all self-loops collapse to an empty walk
    loops = np.arange(7, dtype=np.int32)
    a, b = dedup_edges(loops, loops)
    assert a.shape == (0,)
    # n=1 single node, self-loop input
    a, b = dedup_edges(np.zeros(1, np.int32), np.zeros(1, np.int32))
    assert a.shape == (0,)
    lab, rounds = shiloach_vishkin(a, b, 1)
    assert num_components(lab) == 1 and int(rounds) == 1
    # orientation + duplicates collapse to one canonical edge
    a, b = dedup_edges(
        np.array([2, 1, 1, 2], np.int32), np.array([1, 2, 2, 1], np.int32)
    )
    assert a.tolist() == [1] and b.tolist() == [2]


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(0, 120), st.integers(0, 10_000))
def test_dedup_never_changes_labels_or_rounds(n, m, seed):
    r = np.random.default_rng(seed)
    edges = r.integers(0, n, size=(m, 2)).astype(np.int32)
    lab_raw, rounds_raw = shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, dedup=False
    )
    lab_dd, rounds_dd = shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, dedup=True
    )
    np.testing.assert_array_equal(np.asarray(lab_dd), np.asarray(lab_raw))
    assert int(rounds_dd) == int(rounds_raw)
    # the frontier engine agrees under dedup too
    lab_f, rounds_f = frontier_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, min_bucket=16
    )
    np.testing.assert_array_equal(np.asarray(lab_f), np.asarray(lab_raw))
    assert int(rounds_f) == int(rounds_raw)


def test_all_self_loops_single_round():
    e = np.stack([np.arange(6)] * 2, axis=1).astype(np.int32)
    lab, rounds = frontier_shiloach_vishkin(e[:, 0], e[:, 1], 6)
    assert num_components(lab) == 6
    assert int(rounds) == 1  # dedup leaves an empty walk: one no-op round


def test_connected_components_engine_dispatch():
    n = 500
    edges = list_graph(n, 3, seed=10)
    ref, rounds_ref = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    for kwargs in (
        {},  # auto: single visible device -> frontier engine
        {"engine": "frontier"},
        {"engine": "dense"},
        {"engine": "frontier", "sample_rounds": 2},
    ):
        lab, rounds = connected_components(edges[:, 0], edges[:, 1], n, **kwargs)
        if kwargs.get("sample_rounds"):
            np.testing.assert_array_equal(
                canonicalize_labels(np.asarray(lab)),
                canonicalize_labels(np.asarray(ref)),
            )
        else:
            np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref))
            assert int(rounds) == int(rounds_ref)
    with pytest.raises(ValueError):
        connected_components(edges[:, 0], edges[:, 1], n, engine="bogus")
    # an explicit mesh contradicts the single-device frontier engine
    from repro.distributed.graph import graph_mesh

    with pytest.raises(ValueError, match="single-device"):
        connected_components(
            edges[:, 0], edges[:, 1], n, engine="frontier", mesh=graph_mesh(1)
        )
    # engine="dense" + mesh routes to the sharded engine (the dense walk)
    lab, rounds = connected_components(
        edges[:, 0], edges[:, 1], n, engine="dense", mesh=graph_mesh(1)
    )
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref))
    assert int(rounds) == int(rounds_ref)


def test_unknown_dispatch_strings_name_choices():
    """Unknown engine=/kernel_impl=/hook_impl= strings raise loudly,
    naming the valid set (they used to fall through silently)."""
    edges = list_graph(60, 2, seed=11)
    with pytest.raises(ValueError, match="'auto', 'frontier', 'dense'"):
        connected_components(edges[:, 0], edges[:, 1], 60, engine="bogus")
    with pytest.raises(ValueError, match="hook_impl.*'xla'"):
        shiloach_vishkin(edges[:, 0], edges[:, 1], 60, hook_impl="bogus")
    with pytest.raises(ValueError, match="hook_impl.*'pallas'"):
        frontier_shiloach_vishkin(
            edges[:, 0], edges[:, 1], 60, hook_impl="pallas_typo"
        )
    from repro.distributed.graph import sharded_shiloach_vishkin

    with pytest.raises(ValueError, match="exchange.*'dense', 'sparse'"):
        sharded_shiloach_vishkin(
            edges[:, 0], edges[:, 1], 60, exchange="sparse_typo"
        )


def test_auto_sampling_policy_on_dense_graphs():
    """ROADMAP decision: engine='auto' enables the Afforest pre-pass on
    edge-heavy graphs (m/n >= AUTO_SAMPLE_DENSITY); labels remain a
    correct partition, and sample_rounds=0 opts out bit-exactly."""
    from repro.core import AUTO_SAMPLE_DENSITY

    n = 300
    m = int(AUTO_SAMPLE_DENSITY * n) + 10
    r = np.random.default_rng(12)
    edges = r.integers(0, n, size=(m, 2)).astype(np.int32)
    ref, rounds_ref = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    # auto: pre-pass on -> partition-correct labels
    lab, _rounds = connected_components(edges[:, 0], edges[:, 1], n)
    np.testing.assert_array_equal(
        canonicalize_labels(np.asarray(lab)),
        canonicalize_labels(np.asarray(ref)),
    )
    # explicit sample_rounds=0 overrides the policy: bit-exact vs dense
    lab0, rounds0 = connected_components(
        edges[:, 0], edges[:, 1], n, sample_rounds=0
    )
    np.testing.assert_array_equal(np.asarray(lab0), np.asarray(ref))
    assert int(rounds0) == int(rounds_ref)
    # explicit engine= pins exact dense representatives too
    for engine in ("frontier", "dense"):
        labe, roundse = connected_components(
            edges[:, 0], edges[:, 1], n, engine=engine
        )
        np.testing.assert_array_equal(np.asarray(labe), np.asarray(ref))
        assert int(roundse) == int(rounds_ref)
    # sparse graphs stay below the threshold: bit-exact on auto
    sparse = list_graph(n, 3, seed=13)
    ref_s, rounds_s = shiloach_vishkin(sparse[:, 0], sparse[:, 1], n)
    lab_s, rounds_sa = connected_components(sparse[:, 0], sparse[:, 1], n)
    np.testing.assert_array_equal(np.asarray(lab_s), np.asarray(ref_s))
    assert int(rounds_sa) == int(rounds_s)
