"""Frontier-compacted CC engine: bit-exactness vs the dense sv_run loop
across adversarial graph families, work accounting, the Afforest-style
sampling pre-pass, and edge dedup."""
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-stubs

from repro.core import (
    connected_components,
    dedup_edges,
    frontier_shiloach_vishkin,
    num_components,
    shiloach_vishkin,
)
from repro.core.serial import canonicalize_labels, serial_connected_components
from repro.ops.kiss import giant_dust_graph, list_graph, random_graph, tree_graph


def _star(n):
    return np.stack(
        [np.zeros(n - 1, np.int32), np.arange(1, n, dtype=np.int32)], axis=1
    )


def _adversarial_families():
    r = np.random.default_rng(7)
    return {
        "long-chain": (2000, list_graph(2000, 1, seed=1)),
        "star": (1500, _star(1500)),
        "giant+dust": (2000, giant_dust_graph(2000, 0.9, seed=2)),
        "empty": (17, np.zeros((0, 2), np.int32)),
        "all-self-loops": (9, np.stack([np.arange(9)] * 2, axis=1).astype(np.int32)),
        "tree": (1200, tree_graph(1200, 3, seed=3)),
        "random": (800, random_graph(800, 0.01, seed=4)),
        "dense-multigraph": (150, r.integers(0, 150, (3000, 2)).astype(np.int32)),
    }


@pytest.mark.parametrize(
    "family", sorted(_adversarial_families()), ids=lambda f: f
)
def test_bit_exact_vs_dense(family):
    n, edges = _adversarial_families()[family]
    ref, rounds_ref = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    lab, rounds = frontier_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, min_bucket=64
    )
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref))
    assert int(rounds) == int(rounds_ref)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 100), st.integers(0, 300), st.integers(0, 10_000))
def test_random_edge_lists_bit_exact(n, m, seed):
    r = np.random.default_rng(seed)
    edges = r.integers(0, n, size=(m, 2)).astype(np.int32)
    ref, rounds_ref = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    lab, rounds = frontier_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, min_bucket=16
    )
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref))
    assert int(rounds) == int(rounds_ref)


def test_edges_touched_below_dense_on_chains():
    n = 4000
    edges = list_graph(n, 1, seed=5)
    _, rounds = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    _, _, stats = frontier_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n, min_bucket=64, with_stats=True
    )
    dense = 2 * stats.m2 * int(rounds)
    assert stats.edges_touched < dense / 2
    sizes = [size for size, _ in stats.levels]
    assert sizes == sorted(sizes, reverse=True)  # buckets only shrink
    assert stats.rounds == int(rounds)


def test_afforest_prepass_partition_correct():
    for n, edges in [
        (2000, giant_dust_graph(2000, 0.9, seed=6)),
        (800, random_graph(800, 0.02, seed=7)),
        (1200, tree_graph(1200, 3, seed=8)),
    ]:
        ref = canonicalize_labels(serial_connected_components(edges, n))
        lab, _rounds, stats = frontier_shiloach_vishkin(
            edges[:, 0], edges[:, 1], n,
            sample_rounds=3, min_bucket=64, with_stats=True,
        )
        np.testing.assert_array_equal(
            canonicalize_labels(np.asarray(lab)), ref
        )
        assert stats.sample_rounds == 3
        assert 0.0 <= stats.largest_component_frac <= 1.0
        # the pre-pass resolves edges before full SV sees them
        assert stats.live_after_sample < stats.m2


def test_hook_kernel_path_bit_exact():
    n = 600
    edges = tree_graph(n, 3, seed=9)
    ref, rounds_ref = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    lab, rounds = frontier_shiloach_vishkin(
        edges[:, 0], edges[:, 1], n,
        min_bucket=64, hook_impl="pallas_interpret",
    )
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref))
    assert int(rounds) == int(rounds_ref)


def test_dedup_edges():
    src = np.array([0, 1, 1, 2, 3, 3, 3], np.int32)
    dst = np.array([1, 0, 1, 3, 2, 2, 3], np.int32)  # dups + self-loops
    a, b = dedup_edges(src, dst)
    assert a.tolist() == [0, 2] and b.tolist() == [1, 3]
    # dedup changes neither labels nor rounds
    for dedup in (True, False):
        lab, rounds = shiloach_vishkin(src, dst, 5, dedup=dedup)
        assert num_components(lab) == 3  # {0,1}, {2,3}, {4}
        assert int(rounds) == 2


def test_all_self_loops_single_round():
    e = np.stack([np.arange(6)] * 2, axis=1).astype(np.int32)
    lab, rounds = frontier_shiloach_vishkin(e[:, 0], e[:, 1], 6)
    assert num_components(lab) == 6
    assert int(rounds) == 1  # dedup leaves an empty walk: one no-op round


def test_connected_components_engine_dispatch():
    n = 500
    edges = list_graph(n, 3, seed=10)
    ref, rounds_ref = shiloach_vishkin(edges[:, 0], edges[:, 1], n)
    for kwargs in (
        {},  # auto: single visible device -> frontier engine
        {"engine": "frontier"},
        {"engine": "dense"},
        {"engine": "frontier", "sample_rounds": 2},
    ):
        lab, rounds = connected_components(edges[:, 0], edges[:, 1], n, **kwargs)
        if kwargs.get("sample_rounds"):
            np.testing.assert_array_equal(
                canonicalize_labels(np.asarray(lab)),
                canonicalize_labels(np.asarray(ref)),
            )
        else:
            np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref))
            assert int(rounds) == int(rounds_ref)
    with pytest.raises(ValueError):
        connected_components(edges[:, 0], edges[:, 1], n, engine="bogus")
    # an explicit mesh contradicts the single-device frontier engine
    from repro.distributed.graph import graph_mesh

    with pytest.raises(ValueError, match="single-device"):
        connected_components(
            edges[:, 0], edges[:, 1], n, engine="frontier", mesh=graph_mesh(1)
        )
    # engine="dense" + mesh routes to the sharded engine (the dense walk)
    lab, rounds = connected_components(
        edges[:, 0], edges[:, 1], n, engine="dense", mesh=graph_mesh(1)
    )
    np.testing.assert_array_equal(np.asarray(lab), np.asarray(ref))
    assert int(rounds) == int(rounds_ref)
