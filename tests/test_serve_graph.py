"""Wave-batched graph serving (`repro.serve.graph`): packed-batch
results must be bit-exact vs issuing each request alone with the same
engine knobs, compiles must be bucket-bounded, and admission must
reject impossible requests loudly."""
import numpy as np
import pytest

from conftest import given, settings, st  # hypothesis or skip-stubs

from repro.core import (
    connected_components,
    num_components,
    serve_graphs,
    spanning_forest,
    tree_analytics,
)
from repro.data.graphs import graph_request_stream
from repro.serve import GraphRequest, GraphServeEngine

FIELDS = ("parent", "depth", "subtree_size", "preorder", "postorder")


def _requests(stream):
    return [GraphRequest(uid=i, **g) for i, g in enumerate(stream)]


def _assert_matches_solo(req, g, *, engine="dense", mesh=None,
                         pagerank_iters=None):
    """Batched result == the same engine run on the request alone."""
    res = req.result
    assert req.done and res is not None
    if g["kind"] == "pagerank":
        # the serve path always runs the dense fixed-iteration engine;
        # its default count is pagerank_iter_bound at default knobs.
        from repro.core.pagerank import pagerank, pagerank_iter_bound

        iters = (
            pagerank_iters if pagerank_iters is not None
            else pagerank_iter_bound()
        )
        scores, _ = pagerank(
            g["src"], g["dst"], g.get("weights"), g["num_nodes"],
            engine="dense", num_iters=iters,
        )
        np.testing.assert_array_equal(res.scores, np.asarray(scores))
        assert res.labels is None and res.dist is None
        assert res.edge_u is None and res.parent is None
        return
    if g["kind"] == "sssp":
        # sssp engines are bit-exact across engines, so "dense" pins
        # the solo baseline regardless of what the wave ran.
        from repro.core import shortest_paths

        sources = g.get("sources")
        if sources is None:
            sources = np.zeros(1, np.int32)
        dist, pred, _ = shortest_paths(
            g["src"], g["dst"], g.get("weights"), g["num_nodes"],
            sources=np.atleast_1d(np.asarray(sources, np.int32)),
            engine="dense",
        )
        np.testing.assert_array_equal(res.dist, np.asarray(dist))
        np.testing.assert_array_equal(res.pred, np.asarray(pred))
        np.testing.assert_array_equal(
            res.sources, np.atleast_1d(np.asarray(sources, np.int32))
        )
        assert res.labels is None and res.edge_u is None
        return
    lab, _ = connected_components(
        g["src"], g["dst"], g["num_nodes"], engine=engine, mesh=mesh,
        dedup=False,
    )
    np.testing.assert_array_equal(res.labels, np.asarray(lab))
    assert res.num_components == num_components(lab)
    # stage promotion must not leak wave-mate-dependent extra fields
    if g["kind"] == "cc":
        assert res.edge_u is None and res.parent is None
    if g["kind"] == "forest":
        assert res.parent is None
    if g["kind"] in ("forest", "analytics"):
        forest = spanning_forest(
            g["src"], g["dst"], g["num_nodes"], engine=engine, mesh=mesh,
            dedup=False,
        )
        np.testing.assert_array_equal(res.edge_u, forest.edge_u)
        np.testing.assert_array_equal(res.edge_v, forest.edge_v)
    if g["kind"] == "analytics":
        ta = tree_analytics(
            g["src"], g["dst"], g["num_nodes"], engine=engine, mesh=mesh,
            dedup=False,
        )
        for k in FIELDS:
            np.testing.assert_array_equal(
                getattr(res, k), np.asarray(getattr(ta.computations, k)),
                err_msg=f"{k} uid={req.uid}",
            )


def test_batched_bit_exact_vs_solo_mixed_kinds():
    """Mixed cc/forest/analytics waves (stage promotion) over random
    graphs, trees, an empty-edge request, and a single-node request."""
    stream = (
        graph_request_stream(4, kind="cc", seed=1)
        + graph_request_stream(3, kind="forest", family="tree", seed=2)
        + graph_request_stream(4, kind="analytics", family="tree", seed=3)
    )
    z = np.zeros(0, np.int32)
    stream.append({"src": z, "dst": z, "num_nodes": 6, "kind": "analytics"})
    stream.append({"src": z, "dst": z, "num_nodes": 1, "kind": "cc"})
    np.random.default_rng(0).shuffle(stream)  # interleave kinds per wave

    eng = GraphServeEngine(max_requests=5)
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    assert len(done) == len(stream) and eng.waves == 3
    for r in done:
        _assert_matches_solo(r, stream[r.uid])


def test_bucket_compiles_bounded_and_reused():
    """Same-bucket waves reuse compiled programs: the bucket counter
    stays at 1 across many waves, and (when jax exposes jit cache
    sizes) the dense CC kernel really compiled once."""
    from repro.core.components import _sv_dense

    stream = graph_request_stream(12, kind="cc", seed=5)
    eng = GraphServeEngine(max_requests=3)
    cache0 = getattr(_sv_dense, "_cache_size", lambda: None)()
    for r in _requests(stream):
        eng.submit(r)
    eng.run()
    assert eng.waves == 4
    assert eng.bucket_compiles == len(
        {(w.stage, w.node_cap, w.edge_cap) for w in eng.wave_records}
    )
    assert sum(w.new_bucket for w in eng.wave_records) == eng.bucket_compiles
    caps = {(w.node_cap, w.edge_cap) for w in eng.wave_records}
    if cache0 is not None and len(caps) == eng.bucket_compiles:
        added = _sv_dense._cache_size() - cache0
        assert added <= eng.bucket_compiles, (
            "dense CC compiled more than once per bucket"
        )
    # deterministic accounting invariants
    assert eng.requests_per_wave == pytest.approx(3.0)
    assert 0.0 <= eng.node_pad_waste < 1.0
    assert 0.0 <= eng.edge_pad_waste < 1.0


def test_solo_wave_engine_is_identity_baseline():
    """max_requests=1 (the benchmark baseline) serves each request in
    its own wave and still matches direct engine calls."""
    stream = graph_request_stream(4, kind="analytics", family="tree", seed=7)
    eng = GraphServeEngine(max_requests=1)
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    assert eng.waves == len(stream)
    assert all(w.requests == 1 for w in eng.wave_records)
    for r in done:
        _assert_matches_solo(r, stream[r.uid])


def test_serve_graphs_core_dispatch():
    """repro.core.serve_graphs honours engine= like the other entry
    points (explicit frontier engine, still bit-exact)."""
    stream = graph_request_stream(5, kind="forest", seed=9)
    done = serve_graphs(
        _requests(stream), max_requests=4, engine="frontier", min_bucket=32
    )
    for r in done:
        _assert_matches_solo(r, stream[r.uid], engine="frontier")


def test_serve_graphs_mesh_path():
    """An explicit mesh routes every wave through the sharded engines,
    bit-exact vs solo sharded calls."""
    from repro.distributed.graph import graph_mesh

    mesh = graph_mesh(1)
    stream = graph_request_stream(4, kind="analytics", family="tree", seed=13)
    done = serve_graphs(_requests(stream), max_requests=4, mesh=mesh)
    for r in done:
        _assert_matches_solo(r, stream[r.uid], engine="auto", mesh=mesh)


def test_submit_validation():
    eng = GraphServeEngine(max_nodes=64, max_edges=64)
    z = np.zeros(0, np.int32)
    with pytest.raises(ValueError, match="kind"):
        eng.submit(GraphRequest(uid=0, src=z, dst=z, num_nodes=3,
                                kind="labels"))
    with pytest.raises(ValueError, match="num_nodes"):
        eng.submit(GraphRequest(uid=1, src=z, dst=z, num_nodes=0))
    with pytest.raises(ValueError, match="budget"):
        eng.submit(GraphRequest(uid=2, src=z, dst=z, num_nodes=65))
    with pytest.raises(ValueError, match="budget"):
        eng.submit(GraphRequest(
            uid=3, src=np.zeros(65, np.int32), dst=np.zeros(65, np.int32),
            num_nodes=4,
        ))
    with pytest.raises(ValueError, match="endpoints"):
        eng.submit(GraphRequest(
            uid=4, src=np.array([0], np.int32), dst=np.array([5], np.int32),
            num_nodes=4,
        ))
    with pytest.raises(ValueError, match="endpoints"):
        eng.submit(GraphRequest(
            uid=6, src=np.array([0], np.int32), dst=np.array([-1], np.int32),
            num_nodes=4,
        ))
    with pytest.raises(ValueError, match="mismatch"):
        eng.submit(GraphRequest(
            uid=5, src=np.array([0], np.int32), dst=z, num_nodes=4,
        ))
    assert eng.queue == []  # nothing slipped through
    with pytest.raises(ValueError, match="sample_rounds"):
        GraphServeEngine(sample_rounds=2)
    with pytest.raises(ValueError, match="engine"):
        GraphServeEngine(engine="fastest")


def test_pagerank_batched_bit_exact_vs_solo_and_oracle():
    """kind="pagerank" waves: every unpacked scores slice equals the
    solo dense run AND the serial NumPy oracle bit-for-bit."""
    from repro.core.serial import serial_pagerank

    stream = graph_request_stream(6, kind="pagerank", seed=31)
    eng = GraphServeEngine(max_requests=3)
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 6 and eng.waves == 2
    assert all(w.stage == "pagerank" for w in eng.wave_records)
    assert all(w.rounds == eng.pagerank_iters for w in eng.wave_records)
    for r in done:
        g = stream[r.uid]
        _assert_matches_solo(r, g, pagerank_iters=eng.pagerank_iters)
        oracle = serial_pagerank(
            np.stack([g["src"], g["dst"]], axis=1), g["weights"],
            g["num_nodes"], num_iters=eng.pagerank_iters,
        )
        np.testing.assert_array_equal(r.result.scores, oracle)


def test_three_way_family_boundary_fifo_stable():
    """The _family packing boundary over all three families: a wave
    closes AT the boundary in FIFO order (no reordering past it --
    later same-family requests are NOT pulled forward), each wave is
    family-pure, and stage promotion never crosses a family."""
    stream = (
        graph_request_stream(1, kind="cc", seed=61)
        + graph_request_stream(1, kind="analytics", family="tree", seed=62)
        + graph_request_stream(2, kind="sssp", seed=63)
        + graph_request_stream(2, kind="pagerank", seed=64)
        + graph_request_stream(1, kind="cc", seed=65)
        + graph_request_stream(1, kind="pagerank", seed=66)
    )
    eng = GraphServeEngine(max_requests=16)
    for r in _requests(stream):
        eng.submit(r)
    done = eng.run()
    assert len(done) == len(stream)
    # cc+analytics promote WITHIN the cc-chain family; every family
    # switch closes the wave, and the trailing cc / pagerank requests
    # are served in arrival order, not merged backwards.
    assert [w.stage for w in eng.wave_records] == [
        "analytics", "sssp", "pagerank", "cc", "pagerank"
    ]
    assert [w.requests for w in eng.wave_records] == [2, 2, 2, 1, 1]
    # completion order is FIFO (family boundaries never reorder)
    assert [r.uid for r in done] == list(range(len(stream)))
    for r in done:
        _assert_matches_solo(r, stream[r.uid],
                             pagerank_iters=eng.pagerank_iters)
    # no cross-family field leaks: the cc member of the promoted wave
    # got labels only; sssp rows got no scores; pagerank no labels.
    by_uid = {r.uid: r for r in done}
    assert by_uid[0].result.scores is None
    assert by_uid[0].result.dist is None
    assert by_uid[2].result.scores is None
    assert by_uid[4].result.labels is None


def test_pagerank_submit_validation():
    z = np.zeros(0, np.int32)
    eng = GraphServeEngine()
    with pytest.raises(ValueError, match="sssp-only"):
        eng.submit(GraphRequest(
            uid=0, src=z, dst=z, num_nodes=3, kind="pagerank",
            sources=np.zeros(1, np.int32),
        ))
    with pytest.raises(ValueError, match="finite"):
        eng.submit(GraphRequest(
            uid=1, src=np.array([0], np.int32), dst=np.array([1], np.int32),
            num_nodes=3, kind="pagerank",
            weights=np.array([-1.0], np.float32),
        ))
    with pytest.raises(ValueError, match="only consumed"):
        eng.submit(GraphRequest(
            uid=2, src=z, dst=z, num_nodes=3, kind="cc",
            weights=np.zeros(0, np.float32),
        ))
    assert eng.queue == []
    with pytest.raises(ValueError, match="pagerank_iters"):
        GraphServeEngine(pagerank_iters=0)
    with pytest.raises(ValueError, match="damping"):
        GraphServeEngine(damping=1.5)
    # engine knobs that cannot reach the dense pagerank engine reject
    # at submit, like the sssp path does
    knobbed = GraphServeEngine(engine="frontier", min_bucket=32)
    with pytest.raises(ValueError, match="not pagerank engine knobs"):
        knobbed.submit(GraphRequest(
            uid=3, src=z, dst=z, num_nodes=3, kind="pagerank",
        ))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(0, 10_000), st.integers(1, 4))
def test_mixed_family_streams_bit_exact_property(num_requests, seed, width):
    """Hypothesis over ALL THREE packing families interleaved: the
    family-boundary wave closes keep every request bit-exact vs its
    solo engine, including empty-edge and single-node requests."""
    r = np.random.default_rng(seed)
    kinds = ("cc", "analytics", "sssp", "pagerank")
    stream = []
    for _ in range(num_requests):
        n = int(r.integers(1, 14))
        m = int(r.integers(0, 4 * n))
        g = {
            "src": r.integers(0, n, m).astype(np.int32),
            "dst": r.integers(0, n, m).astype(np.int32),
            "num_nodes": n,
            "kind": kinds[int(r.integers(0, len(kinds)))],
        }
        if g["kind"] in ("sssp", "pagerank"):
            g["weights"] = (r.integers(0, 8, m) / 4.0).astype(np.float32)
        if g["kind"] == "sssp":
            g["sources"] = r.integers(
                0, n, int(r.integers(1, 3))
            ).astype(np.int32)
        stream.append(g)
    done = serve_graphs(_requests(stream), max_requests=width)
    assert sorted(req.uid for req in done) == list(range(num_requests))
    for req in done:
        _assert_matches_solo(req, stream[req.uid])


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 6), st.integers(0, 10_000), st.integers(1, 4))
def test_random_streams_bit_exact_property(num_requests, seed, width):
    """Hypothesis: packed-batch serving is bit-exact vs per-request
    calls on random streams, including empty-edge and single-node
    requests."""
    r = np.random.default_rng(seed)
    stream = []
    for _ in range(num_requests):
        n = int(r.integers(1, 14))
        m = int(r.integers(0, 4 * n))
        stream.append({
            "src": r.integers(0, n, m).astype(np.int32),
            "dst": r.integers(0, n, m).astype(np.int32),
            "num_nodes": n,
            "kind": "analytics",
        })
    done = serve_graphs(_requests(stream), max_requests=width)
    for req in done:
        _assert_matches_solo(req, stream[req.uid])
