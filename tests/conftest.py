import os

# Tests run single-device CPU. The 512-device dry-run sets its own XLA_FLAGS
# inside launch/dryrun.py; never set it globally here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

from repro.data.graphs import random_succ  # noqa: F401  (re-export for tests)

# Optional hypothesis: property tests skip individually (instead of the
# whole module erroring at collection) when it is not installed.
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
except ImportError:

    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()  # type: ignore[assignment]

    def settings(*a, **k):  # type: ignore[no-redef]
        return lambda f: f

    def given(*a, **k):  # type: ignore[no-redef]
        return pytest.mark.skip(reason="property test needs hypothesis")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _bound_jit_code_growth():
    # The CPU backend keeps every compiled executable's JIT code pages
    # alive for the life of the process; once the suite accumulates
    # enough distinct compilations, LLVM segfaults inside
    # backend_compile (deterministically, at whichever test crosses the
    # threshold). Dropping the caches at each module boundary bounds the
    # accumulation — modules rarely share compiled shapes, so the extra
    # recompiles are cheap.
    yield
    import jax

    jax.clear_caches()
