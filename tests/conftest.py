import os

# Tests run single-device CPU. The 512-device dry-run sets its own XLA_FLAGS
# inside launch/dryrun.py; never set it globally here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_succ(n: int, seed: int = 0) -> np.ndarray:
    """Random linked-list succ[] with head 0 (plain numpy, no KISS)."""
    r = np.random.default_rng(seed)
    order = np.concatenate([[0], 1 + r.permutation(n - 1)]) if n > 1 else np.zeros(1, np.int64)
    succ = np.empty(n, dtype=np.int32)
    succ[order[:-1]] = order[1:]
    succ[order[-1]] = order[-1]
    return succ
