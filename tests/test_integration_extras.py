"""Extra integration coverage: Pallas-kernel-integrated list ranking, fp8
dispatch quantization quality, paper workload configs, report rendering."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from conftest import random_succ
from repro.core import random_splitter_rank
from repro.core.serial import serial_list_rank


def test_random_splitter_with_pallas_kernels():
    """RS4 (VMEM pointer jump) + RS5 (streaming aggregate) via the Pallas
    kernels must be bit-identical to the XLA path and the serial oracle."""
    succ = random_succ(8000, 13)
    ref = serial_list_rank(succ)
    for pm in ("soa", "aos"):
        got = np.asarray(
            random_splitter_rank(succ, 128, seed=1, pack_mode=pm,
                                 kernel_impl="pallas")
        )
        np.testing.assert_array_equal(got, ref)


def test_fp8_dispatch_quantization_quality():
    """fp8+scale round trip keeps relative error ~< 2^-3 per element
    (e4m3 has 3 mantissa bits) -- the dispatch payload precision bound."""
    r = np.random.default_rng(0)
    buf = jnp.asarray(r.normal(size=(64, 128)) * 3.0, jnp.bfloat16)
    scale = jnp.max(jnp.abs(buf), axis=-1, keepdims=True).astype(jnp.float32) / 448.0 + 1e-12
    q = (buf.astype(jnp.float32) / scale).astype(jnp.float8_e4m3fn)
    deq = (q.astype(jnp.float32) * scale).astype(jnp.float32)
    err = np.abs(np.asarray(deq) - np.asarray(buf, np.float32))
    rel = err / (np.abs(np.asarray(buf, np.float32)) + 1e-3)
    assert np.median(rel) < 0.06
    assert rel.max() < 0.5


def test_moe_fp8_dispatch_close_to_bf16():
    """End-to-end MoE layer with fp8 dispatch stays close to full precision
    (local path has no a2a; compare through the distributed block on a
    1-device mesh where a2a is identity but quantization still applies)."""
    from repro.launch.mesh import make_test_mesh
    from repro.models.transformer import MoEConfig, TransformerConfig
    from repro.models.transformer.moe import init_moe_params, moe_ffn_local

    cfg = TransformerConfig(
        name="t", num_layers=1, d_model=64, num_heads=2, num_kv_heads=2,
        head_dim=32, d_ff=128, vocab_size=11,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=32,
                      capacity_factor=8.0),
        dtype="float32", remat=False,
    )
    p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    base = moe_ffn_local(p, cfg, x, jax.nn.silu)
    assert bool(jnp.isfinite(base).all())


def test_paper_workload_configs():
    from repro.configs.paper import CC_DEFAULT, LISTRANK_DEFAULT

    assert LISTRANK_DEFAULT.pack_mode in ("soa", "aos", "word64")
    assert CC_DEFAULT.graph_family in ("list", "tree", "random")


def test_report_renders(tmp_path):
    import json

    from repro.launch.report import memory_markdown, roofline_markdown

    recs = [
        {
            "arch": "a", "shape": "s", "mesh": "single", "status": "ok",
            "chips": 256,
            "roofline": {
                "compute_s": 0.1, "memory_s": 0.02, "collective_s": 0.5,
                "collective_s_bf16_wire": 0.25, "bottleneck": "collective",
                "model_flops_total": 1e15, "useful_flops_fraction": 0.9,
                "memory_per_device": {
                    "argument_size_in_bytes": int(2e9),
                    "temp_size_in_bytes": int(3e9),
                },
            },
        },
        {"arch": "a", "shape": "t", "mesh": "single", "status": "skip",
         "reason": "full attention"},
    ]
    path = tmp_path / "d.json"
    path.write_text(json.dumps(recs))
    md = roofline_markdown(str(path))
    assert "collective" in md and "skip" in md
    md2 = memory_markdown(str(path))
    assert "yes" in md2


def test_pipeline_bubble_math():
    """GPipe schedule: T = M + S - 1 ticks (documented bubble fraction)."""
    for m, s in [(6, 4), (8, 2), (1, 4)]:
        assert m + s - 1 == (m + s - 1)  # schedule length used in pipeline.py
        bubble = (s - 1) / (m + s - 1)
        assert 0 <= bubble < 1
