"""End-to-end behaviour: the paper's pipeline (generate -> rank -> verify),
LM training convergence on the smoke config, and serving round trips."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import num_components, random_splitter_rank, shiloach_vishkin
from repro.core.serial import canonicalize_labels, serial_connected_components, serial_list_rank
from repro.data.lm import lm_batch
from repro.ops.kiss import random_forest, random_linked_list
from repro.train.loop import LoopConfig, train
from repro.train.optimizer import AdamWConfig


def test_paper_pipeline_end_to_end():
    """KISS input generation -> random-splitter ranking (AoS packing,
    Pallas-backed phases) -> serial verification; then graph CC."""
    n = 50_000
    succ = random_linked_list(n, seed=1)
    rank = np.asarray(random_splitter_rank(succ, 512, seed=2, pack_mode="aos"))
    np.testing.assert_array_equal(rank, serial_list_rank(succ))

    edges = random_forest(5_000, num_components=25, seed=3)
    labels, rounds = shiloach_vishkin(edges[:, 0], edges[:, 1], 5_000)
    ref = serial_connected_components(edges, 5_000)
    np.testing.assert_array_equal(
        canonicalize_labels(np.asarray(labels)), canonicalize_labels(ref)
    )
    assert num_components(labels) >= 25  # singletons may add more


def test_lm_training_loss_decreases():
    """Few-step LM training on the gemma smoke config: loss must drop on a
    repeated batch (end-to-end: data pipeline -> model -> optimizer)."""
    from repro.models.transformer import init_params, loss_fn

    arch = get_arch("gemma-2b")
    cfg = arch.smoke_config
    params = init_params(jax.random.PRNGKey(0), cfg)
    raw = lm_batch(4, 32, cfg.vocab_size, seed=0, step=0)
    batch = {k: jnp.asarray(v) for k, v in raw.items()}

    def data():
        while True:
            yield batch

    opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.0, warmup_steps=2)
    loop_cfg = LoopConfig(total_steps=25, checkpoint_dir=None, log_every=100)
    _, out = train(
        params,
        lambda p, b: loss_fn(p, cfg, b),
        data(),
        opt_cfg,
        loop_cfg,
    )
    first = out["history"][0]["loss"]
    last = out["final_loss"]
    assert last < first * 0.7, (first, last)


def test_serve_after_train_roundtrip(tmp_path):
    """Train briefly, checkpoint, restore into a fresh process-state, and
    decode a few tokens -- the deployment loop in miniature."""
    from repro.models.transformer import (
        init_kv_cache,
        init_params,
        loss_fn,
        serve_step,
    )
    from repro.train.checkpoint import CheckpointManager

    arch = get_arch("qwen3-4b")
    cfg = arch.smoke_config
    params = init_params(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(1, {"params": params}, blocking=True)
    restored = mgr.restore(1, {"params": params})["params"]
    restored = jax.tree.map(jnp.asarray, restored)

    cache = init_kv_cache(cfg, 1, 8)
    tok = jnp.zeros((1, 1), jnp.int32)
    for i in range(8):
        logits, cache = serve_step(restored, cfg, cache, tok, jnp.int32(i))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    assert bool(jnp.isfinite(logits).all())
