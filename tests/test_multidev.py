"""Multi-device behaviour (8 fake CPU devices) via fresh subprocesses --
the pytest process is pinned to 1 device and jax locks the count at import."""
import os
import subprocess
import sys

import pytest

_SCRIPT = os.path.join(os.path.dirname(__file__), "multidev_scripts.py")


def _run(name: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]
    )
    proc = subprocess.run(
        [sys.executable, _SCRIPT, name],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MULTIDEV_OK" in proc.stdout


@pytest.mark.slow
def test_moe_expert_parallel_schedules():
    _run("moe_ep")


@pytest.mark.slow
def test_pipeline_parallelism_matches_sequential():
    _run("pipeline_pp")


@pytest.mark.slow
def test_sharded_embedding_lookup():
    _run("sharded_lookup")


@pytest.mark.slow
def test_gnn_edge_parallel_loss_matches():
    _run("gnn_edge_parallel")


@pytest.mark.slow
def test_sharded_cc_matches_single_device():
    _run("sharded_cc")


@pytest.mark.slow
def test_sharded_rank_matches_single_device():
    _run("sharded_rank")


@pytest.mark.slow
def test_sharded_cc_sparse_exchange_bit_exact():
    _run("sharded_cc_sparse")


@pytest.mark.slow
def test_sharded_rank_pallas_kernels():
    _run("sharded_rank_pallas")


@pytest.mark.slow
def test_sharded_frontier_cc_bit_exact():
    _run("sharded_frontier")


@pytest.mark.slow
def test_sharded_trees_forest_and_tour():
    _run("sharded_trees")
